"""Quickstart: the paper's Fig. 1/Fig. 3 flow through the `repro.db` facade.

A BIC core turns records into a key-major bitmap index so that
multi-dimensional queries become streaming bitwise passes.  `repro.db`
wraps that silicon-shaped core in a database port: a `Schema` names the
key rows, `col(...)` expressions compile to fused bitmap passes, and one
`BitmapDB` session owns ingest, durability, and query serving.

Run:  PYTHONPATH=src python examples/quickstart.py

Hacking on the tree?  `PYTHONPATH=src python -m repro.analysis` runs the
domain lint (lock hierarchy, fault-seam coverage, jit hygiene,
span/metric taxonomy, wire exhaustiveness — see the "Static analysis"
section of ARCHITECTURE.md); CI fails on any unbaselined finding, and
`REPRO_LOCK_WITNESS=1 pytest` cross-checks the lock hierarchy at
runtime.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

import repro  # noqa: E402
from repro.db import col  # noqa: E402
from repro.engine import key  # noqa: E402

DOMAINS = ["web", "code", "math", "news"]
LANGS = ["en", "de", "ja"]
TEMP_EDGES = [-10.0, 0.0, 10.0, 20.0, 30.0, 45.0]


def make_rows(rng, n):
    return {
        "domain": [DOMAINS[i] for i in rng.integers(0, len(DOMAINS), n)],
        "lang": [LANGS[i] for i in rng.integers(0, len(LANGS), n)],
        "temp": rng.uniform(-10, 45, n).round(2).tolist(),
        "flagged": [bool(b) for b in rng.random(n) < 0.1],
    }


def brute(rows, i):
    """The quickstart query, evaluated by brute force per record."""
    return (rows["domain"][i] in ("code", "math")
            and rows["lang"][i] == "en"
            and 10.0 <= rows["temp"][i]
            and not rows["flagged"][i])


def main():
    rng = np.random.default_rng(0)
    schema = repro.Schema([
        repro.Column.categorical("domain", DOMAINS),
        repro.Column.categorical("lang", LANGS),
        repro.Column.binned("temp", edges=TEMP_EDGES),
        repro.Column.categorical("flagged", [False, True]),
    ])
    print(schema)

    # ---- ingest: structured rows -> streaming bitmap index -------------
    db = repro.BitmapDB(schema)
    n = 4096
    rows = make_rows(rng, n)
    db.ingest(rows)
    print(f"ingested {db.num_records} records over {db.num_keys} key rows")

    # ---- query: typed expressions compile to fused bitmap passes -------
    q = (col("domain").isin(["code", "math"]) & (col("lang") == "en")
         & (col("temp") >= 10.0) & ~(col("flagged") == True))  # noqa: E712
    res = db.query(q)
    want = [i for i in range(n) if brute(rows, i)]
    assert list(res.ids) == want, "bitmap query must match brute force"
    print(f"query code|math & en & temp>=10 & ~flagged -> {res.count} "
          f"records: {[int(i) for i in res.ids[:8]]} ... "
          "(verified by brute force)")

    # raw integer key rows still work (the engine predicate surface)
    k = schema.key_of("domain", "code")
    res2 = db.query(key(k) & ~key(schema.key_of("flagged", True)))
    print(f"raw predicate key({k}) & ~flagged -> {res2.count} records")

    # ---- stats feed the planner's cheapest-first clause ordering -------
    st = db.stats
    labels = [schema.key_label(i) for i in range(3)]
    print(f"per-key selectivity stats: {labels} -> {st.counts[:3]}")

    # ---- explain: how a query WOULD run, without running it ------------
    # The session serves with backend="auto": a measured cost model picks
    # the cheapest execution backend per dispatch (the fused bulk-bitwise
    # sweep vs the per-pass paths) from a persisted calibration of this
    # host.  explain() surfaces that decision: the lowered pass program,
    # its padded bucket shape, the selectivity estimate, and the
    # per-candidate time estimates behind the backend choice.
    ex = db.explain(q)
    est = {k: f"{v * 1e6:.0f}us" for k, v in ex["decision"]["estimates"]
           .items()} if ex["decision"] else {}
    print(f"explain: bucket_shape={ex['bucket_shape']} "
          f"backend={ex['backend']} est_matches={ex['est_matches']:.0f} "
          f"(actual {res.count}) candidates={est}")

    # ---- durability: spill to a store, crash, recover ------------------
    with tempfile.TemporaryDirectory() as root:
        path = os.path.join(root, "idx")
        durable = repro.BitmapDB(schema, path=path, spill_records=1024)
        cut = n - 500                   # last 500 stay under the threshold
        durable.ingest({k2: v[:cut] for k2, v in rows.items()})
        durable.append({k2: v[cut:] for k2, v in rows.items()})
        segs = len(durable.store.segments)
        wal_blocks = len(durable.store.replay_wal())
        assert wal_blocks, "the final sub-threshold block must be WAL-only"
        # "crash": reopen from disk — manifest + WAL replay, bit-identical
        recovered = repro.open(path)
        assert recovered.num_records == n
        assert list(recovered.query(q).ids) == want
        print(f"recovered {recovered.num_records} records from {segs} "
              f"segments + a {wal_blocks}-block WAL tail; query results "
              "bit-identical")

        # ---- serving: one step function over the bucketed executor ----
        step = recovered.serve_step()
        batch = [q, col("lang") == "de", key(k),
                 col("temp").between(0, 20) & (col("domain") == "web")]
        rows_out, counts = step(batch)
        print(f"served a {len(batch)}-query batch in bucketed dispatches: "
              f"counts={[int(c) for c in counts]}")

        # ---- the service port: micro-batching + standby duty cycle ----
        # submit() from any number of threads returns a future; the
        # scheduler coalesces everything inside the delay window into ONE
        # bucketed dispatch, then duty-cycles into standby when idle —
        # the paper's operating model as an API.
        with recovered.serve(max_delay_ms=2.0, idle_after_ms=10.0) as svc:
            futs = [svc.submit(qq) for qq in batch * 8]   # 32 requests
            svc.drain()
            assert [int(f.count) for f in futs[:4]] == \
                [int(c) for c in counts]
            deadline = time.time() + 5        # idle past the threshold
            while svc.state != "standby" and time.time() < deadline:
                time.sleep(0.01)
            m = svc.metrics()
            print(f"service: {m.served} queries in {m.batches} coalesced "
                  f"batch(es), p50={m.latency_p50_ms:.2f}ms, "
                  f"state={m.state}, active={m.active_joules:.2e}J "
                  f"standby={m.standby_joules:.2e}J")
            assert m.state == "standby", "idle service must clock-gate"

    # ---- the fabric: the same query plane over N shard stores ----------
    # A ShardMap hash-partitions records by their domain key; each shard
    # is a full BitmapDB+BitmapService stack behind a transport (loopback
    # here — `repro.fabric.worker.spawn_shards` runs the identical stack
    # as real processes, see benchmarks/fabric.py).  The FabricClient
    # keeps the submit()/future surface, scatters each query to the
    # shards that can own it, and merges rows bit-identically.
    from repro.db.expr import lower as lower_expr
    from repro.fabric import FabricClient, ShardMap
    sm = ShardMap(num_shards=3, strategy="hash", column_index=0,
                  base=0, cardinality=len(DOMAINS), seed=1)
    with FabricClient.local([repro.BitmapDB(schema) for _ in range(3)],
                            sm) as fc:
        fc.append(rows)
        fut = fc.submit(q)
        assert list(fut.ids) == want, "fabric must merge bit-identically"
        served = [h["served"] for h in fc.metrics()["shards"]]
        owners = sorted(sm.owners(lower_expr(q, schema)))
        print(f"fabric: 3 hash shards served {fut.count} matches "
              f"(per-shard served={served}, query pruned to "
              f"shards {owners})")

    print("quickstart OK")


if __name__ == "__main__":
    main()
