"""Quickstart: the paper's Fig. 1/Fig. 3 flow end-to-end.

Creates a bitmap index over records with the BIC core (CAM match -> buffer
-> transpose), then answers the paper's example query
"all objects containing A2 AND A4 but NOT A5" with one fused bitwise pass.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.bic import BICConfig, BICCore  # noqa: E402
from repro.engine import key, plan  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    # 256 records ("objects"), each holding 32 8-bit attribute words,
    # indexed by 64 keys — a scaled-up version of the fabricated core.
    n, w, m = 256, 32, 64
    records = jnp.asarray(rng.integers(0, 128, (n, w), dtype=np.int32))
    keys = jnp.arange(m, dtype=jnp.int32)

    core = BICCore(BICConfig(num_keys=m, num_records=n, words_per_record=w))
    index = core.create(records, keys)
    print(f"bitmap index: {index.num_keys} keys x {index.num_records} "
          f"records, packed {index.packed.shape} uint32")

    # "find all objects containing A2 and A4, but not A5" (paper §II-A)
    result, count = core.query(index, include=[2, 4], exclude=[5])
    hits = [j for j in range(n)
            if (int(result[j // 32]) >> (j % 32)) & 1]
    print(f"query A2 & A4 & ~A5 -> {int(count)} objects: {hits[:10]}"
          f"{' ...' if len(hits) > 10 else ''}")

    # cross-check against brute force
    rec = np.asarray(records)
    brute = [j for j in range(n)
             if 2 in rec[j] and 4 in rec[j] and 5 not in rec[j]]
    assert hits == brute, "bitmap query must match brute force"
    print("verified against brute-force scan.")

    # arbitrary boolean trees go through the engine's query planner:
    # "(A2 or A7) and A4, but not A5" compiles to fused bitmap passes
    pred = (key(2) | key(7)) & key(4) & ~key(5)
    pl = plan(pred)
    result, count = core.query(index, where=pred)
    hits = [j for j in range(n) if (int(result[j // 32]) >> (j % 32)) & 1]
    print(f"planner query (A2|A7) & A4 & ~A5 -> {int(count)} objects "
          f"in {pl.num_passes} fused passes (plan shape {pl.shape})")
    brute = [j for j in range(n)
             if (2 in rec[j] or 7 in rec[j]) and 4 in rec[j]
             and 5 not in rec[j]]
    assert hits == brute, "planner query must match brute force"
    print("planner query verified against brute-force scan.")


if __name__ == "__main__":
    main()
