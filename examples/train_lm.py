"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the bitmap-indexed data pipeline, with checkpoint/restart fault tolerance.

The data selection ("domain 3, high quality, not flagged") runs as bitmap
queries over BIC-built indexes — the paper's technique in the data plane.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.data.pipeline import BitmapIndexedDataset, DataConfig  # noqa: E402
from repro.engine.planner import key  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.optim.adamw import OptimConfig  # noqa: E402
from repro.train.loop import LoopConfig, train_loop  # noqa: E402
from repro.train.step import TrainConfig  # noqa: E402

# ~100M params: 12L x 768d, GQA 12/4, 32k vocab (qwen2-family reduced)
CFG = ModelConfig(
    name="lm-100m", family="dense", source="examples",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
    d_ff=3072, vocab_size=32000, rope="rope", tie_embeddings=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    print(f"model: {CFG.param_count()/1e6:.0f}M params")
    dcfg = DataConfig(vocab_size=CFG.vocab_size, seq_len=args.seq,
                      docs_per_shard=512, num_shards=4, num_attributes=32)
    ds = BitmapIndexedDataset(dcfg)
    # bitmap-query data selection: domain==3 AND quality==18, NOT flag 25
    sel = dict(where=key(3) & key(18) & ~key(25))
    n_sel = sum(len(ds.select(s, **sel)) for s in range(dcfg.num_shards))
    print(f"bitmap query selected {n_sel} / "
          f"{dcfg.num_shards * dcfg.docs_per_shard} documents")

    def batches(start_step: int):
        return ds.batches(args.batch, seed=0, start_step=start_step, **sel)

    out = train_loop(
        CFG,
        TrainConfig(OptimConfig(peak_lr=3e-4, warmup_steps=20,
                                decay_steps=args.steps)),
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                   ckpt_every=100, log_every=10),
        batches)
    print(f"done: final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
