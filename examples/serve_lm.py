"""Serving example: batched prefill + decode with a KV cache on a small LM,
with bitmap-indexed request routing — requests carry attribute tags (user
tier, task type) and a BIC index over the waiting queue lets the scheduler
pull matching batches with one bitwise query (the serving-plane analogue of
the paper's multi-dimensional queries).

The routing queries go through a :class:`repro.serve.BitmapService`: each
scheduling policy submits its selection concurrently, the service
coalesces them into one bucketed dispatch, and between request waves it
duty-cycles into standby — the paper's operating model applied to the
serving control plane.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.bic import BICConfig, BICCore  # noqa: E402
from repro.engine.planner import key  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.model import init_params  # noqa: E402
from repro.serve import BitmapService  # noqa: E402
from repro.serve.step import greedy_generate  # noqa: E402

CFG = ModelConfig(
    name="serve-demo", family="dense", source="examples",
    num_layers=4, d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
    d_ff=1024, vocab_size=8192, rope="rope", tie_embeddings=True,
)


def main():
    rng = np.random.default_rng(0)
    params = init_params(CFG, jax.random.PRNGKey(0))

    # --- request queue with attribute tags, indexed by a BIC core
    n_req, n_tags = 64, 16
    tags = rng.integers(0, n_tags, size=(n_req, 4)).astype(np.int32)
    bic = BICCore(BICConfig(num_keys=n_tags, num_records=n_req,
                            words_per_record=4))
    index = bic.create(jnp.asarray(tags), jnp.arange(n_tags, dtype=jnp.int32))
    # scheduling policies submit concurrently; the service coalesces them
    # into one bucketed dispatch and idles in standby between waves
    svc = BitmapService.open(index, max_delay_ms=2.0, idle_after_ms=25.0)
    policies = {
        # premium (tag 2) non-batch-exempt (not tag 7) requests first
        "premium": key(2) & ~key(7),
        "interactive": key(1) | key(3),
        "batch_tier": key(7) & ~key(2),
    }
    futs = {name: svc.submit(q) for name, q in policies.items()}
    svc.drain()
    ready = [int(i) for i in futs["premium"].ids]
    print(f"scheduler: {futs['premium'].count} premium / "
          f"{futs['interactive'].count} interactive / "
          f"{futs['batch_tier'].count} batch requests selected in "
          f"{svc.metrics().batches} coalesced dispatch(es): {ready[:8]}...")

    # --- batched prefill + decode on the selected batch
    batch = ready[:8] if len(ready) >= 8 else list(range(8))
    prompts = jnp.asarray(
        rng.integers(0, CFG.vocab_size, size=(len(batch), 32)))
    t0 = time.time()
    out = greedy_generate(params, CFG, prompts, steps=16)
    dt = time.time() - t0
    toks = out.size
    print(f"generated {toks} tokens for {len(batch)} requests "
          f"in {dt:.2f}s ({toks/dt:.0f} tok/s on CPU)")
    print("sample continuation:", np.asarray(out[0])[:8].tolist())

    # --- duty cycle: the routing service idled (or clock-gated) while the
    # LM generated; its meter shows the active/standby split
    m = svc.metrics()
    print(f"routing service: state={m.state} served={m.served} "
          f"active={m.active_joules:.2e}J standby={m.standby_joules:.2e}J")
    svc.close()


if __name__ == "__main__":
    main()
