"""Elastic multi-core BIC (paper Fig. 4 + §III-E): index a workload across
Z cores, activating only as many as the load needs; idle cores sit in
standby under CG / CG+RBB, with energy accounted by the calibrated silicon
model.  Also demonstrates straggler-aware (LPT) dispatch.

Run:  PYTHONPATH=src python examples/elastic_indexing.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.elastic import (ElasticScheduler, PowerState,  # noqa: E402
                                lpt_schedule, multicore_create_index,
                                static_schedule)


def main():
    rng = np.random.default_rng(0)

    # --- multi-core indexing on the available device mesh
    mesh = jax.make_mesh((len(jax.devices()),), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    records = jnp.asarray(rng.integers(0, 256, (8, 16, 32), dtype=np.int32))
    keys = jnp.asarray(rng.integers(0, 256, (8,), dtype=np.int32))
    out = multicore_create_index(records, keys, mesh)
    print(f"multi-core BIC: {records.shape[0]} batches -> "
          f"bitmap indexes {out.shape} (keys x packed records)")

    # --- diurnal workload: peak hours, off-peak, idle nights
    workload = [800] * 6 + [80] * 6 + [0] * 12      # batches per hour
    tick = 3600.0 / 24
    for name, state in [("CG only", PowerState(use_rbb=False)),
                        ("CG+RBB", PowerState(use_rbb=True))]:
        sch = ElasticScheduler(num_cores=8, state=state)
        rep = sch.run(workload, tick_seconds=tick)
        print(f"{name:8s}: active={rep.active_joules*1e3:9.4f} mJ  "
              f"standby={rep.standby_joules*1e3:9.6f} mJ  "
              f"(standby power {sch.p_standby*1e9:.2f} nW/core)")

    # --- straggler mitigation: one slow core (0.25x)
    costs = [1.0] * 64
    speeds = [1.0] * 7 + [0.25]
    mk_static = static_schedule(costs, speeds)
    mk_lpt, _ = lpt_schedule(costs, speeds)
    print(f"straggler: static round-robin makespan={mk_static:.1f}, "
          f"LPT work-stealing={mk_lpt:.1f} "
          f"({mk_static/mk_lpt:.1f}x better)")


if __name__ == "__main__":
    main()
