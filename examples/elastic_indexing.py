"""Elastic multi-core BIC (paper Fig. 4 + §III-E): index a workload across
Z cores, activating only as many as the load needs; idle cores sit in
standby under CG / CG+RBB, with energy accounted by the calibrated silicon
model.  Also demonstrates straggler-aware (LPT) dispatch.

Run:  PYTHONPATH=src python examples/elastic_indexing.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.elastic import (PowerState, lpt_schedule,  # noqa: E402
                                static_schedule)
from repro.engine.runtime import (MulticoreRuntime,  # noqa: E402
                                  StreamingIndexer)


def main():
    rng = np.random.default_rng(0)

    # --- fused runtime: sharded indexing + elastic energy in one place
    mesh = jax.make_mesh((len(jax.devices()),), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    keys = jnp.asarray(rng.integers(0, 256, (8,), dtype=np.int32))
    # diurnal workload: peak hours, off-peak, idle nights (batches per tick)
    workload = [8] * 6 + [4] * 6 + [0] * 12
    tick = 3600.0 / 24
    ticks = [None if wl == 0 else jnp.asarray(
        rng.integers(0, 256, (wl, 16, 32), dtype=np.int32))
        for wl in workload]
    for name, state in [("CG only", PowerState(use_rbb=False)),
                        ("CG+RBB", PowerState(use_rbb=True))]:
        rt = MulticoreRuntime(mesh, state=state)
        outs, rep = rt.index_stream(ticks, keys, tick_seconds=tick)
        built = sum(o.shape[0] for o in outs)
        print(f"{name:8s}: indexed {built} batches  "
              f"active={rep.active_joules*1e3:9.4f} mJ  "
              f"standby={rep.standby_joules*1e3:9.6f} mJ  "
              f"(standby power {rt.scheduler.p_standby*1e9:.2f} nW/core)")

    # --- streaming ingest: grow one index block-by-block, no rebuild
    si = StreamingIndexer(keys)
    for nblk in (100, 28, 60):
        si.append(jnp.asarray(rng.integers(0, 256, (nblk, 32),
                                           dtype=np.int32)))
    idx = si.index
    print(f"streaming ingest: {idx.num_records} records appended in 3 "
          f"blocks -> packed index {idx.packed.shape} (no full rebuild)")

    # --- straggler mitigation: one slow core (0.25x)
    costs = [1.0] * 64
    speeds = [1.0] * 7 + [0.25]
    mk_static = static_schedule(costs, speeds)
    mk_lpt, _ = lpt_schedule(costs, speeds)
    print(f"straggler: static round-robin makespan={mk_static:.1f}, "
          f"LPT work-stealing={mk_lpt:.1f} "
          f"({mk_static/mk_lpt:.1f}x better)")


if __name__ == "__main__":
    main()
