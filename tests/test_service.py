"""Acceptance suite for the `repro.serve.service` serving port.

Covers the micro-batch scheduler (threaded submit storm bit-identical to
sequential `serve_step` calls, per-caller ordering), `drain()`/`close()`
semantics (every accepted future answered exactly once), admission
control (block with timeout / reject), error isolation, the standby duty
cycle and its energy split, background maintenance (appends never block
on a spill, crash window between background segment write and manifest
swap recovers bit-exactly, the WAL carry-over of appends racing a
flush), the gc in-flight guard, compaction/gc stats, the bounded plan
caches, and the data-pipeline prefetch path.
"""
import os
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

import repro
from repro.db import BitmapDB, Column, Schema, col
from repro.engine import backends
from repro.engine.planner import key
from repro.serve.service import (BitmapService, ServiceClosed,
                                 ServiceConfig, ServiceOverloaded)
from repro.serve.step import make_bitmap_query_step
from repro.store import SegmentStore


# ----------------------------------------------------------------- fixtures
def _schema(m: int = 16) -> Schema:
    half = m // 2
    return Schema([Column.categorical("a", list(range(half))),
                   Column.categorical("b", list(range(half, m)))])


def _records(rng, n: int, m: int = 16) -> np.ndarray:
    half = m // 2
    return np.stack([rng.integers(0, half, n, dtype=np.int32),
                     rng.integers(half, m, n, dtype=np.int32)], axis=1)


def _mk_db(n: int = 2048, m: int = 16, seed: int = 0) -> BitmapDB:
    db = BitmapDB(_schema(m), backend="ref")
    db.append_encoded(_records(np.random.default_rng(seed), n, m))
    return db


def _mixed_queries(rng, m: int, count: int) -> list:
    half = m // 2
    qs = []
    for i in range(count):
        fam = i % 4
        if fam == 0:
            qs.append(col("a") == int(rng.integers(0, half)))
        elif fam == 1:
            qs.append((col("a") == int(rng.integers(0, half)))
                      & ~(col("b") == int(rng.integers(half, m))))
        elif fam == 2:
            qs.append(key(int(rng.integers(0, m)))
                      | key(int(rng.integers(0, m))))
        else:
            qs.append((key(int(rng.integers(0, m)))
                       | key(int(rng.integers(0, m))))
                      & key(int(rng.integers(0, m))))
    return qs


# ----------------------------------------------------- micro-batch identity
def test_threaded_storm_bit_identical_to_sequential_step():
    """Queries submitted concurrently from many threads coalesce into
    micro-batches whose results are bit-identical to one-at-a-time
    serve_step calls, and each caller's futures resolve in its
    submission order."""
    db = _mk_db()
    rng = np.random.default_rng(3)
    queries = _mixed_queries(rng, 16, 120)
    step = db.serve_step()
    seq = [step([q]) for q in queries]

    with db.serve(max_delay_ms=2.0, max_batch=32,
                  idle_after_ms=1000.0) as svc:
        lanes = [queries[t::4] for t in range(4)]
        outs: list[list] = [[] for _ in range(4)]

        def caller(t):
            for q in lanes[t]:
                outs[t].append(svc.submit(q))

        threads = [threading.Thread(target=caller, args=(t,))
                   for t in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert svc.drain(timeout=60)
        m = svc.metrics()
        assert m.served == len(queries)
        assert m.batches <= len(queries)   # coalesced, not per-query
        for t in range(4):
            seqs = [f.resolve_seq for f in outs[t]]
            assert seqs == sorted(seqs), "per-caller order violated"
            for q, f in zip(lanes[t], outs[t]):
                i = queries.index(q)
                rows, counts = seq[i]
                rr, cc = f.result()
                assert bool(jnp.all(rows[0] == rr))
                assert int(counts[0]) == int(cc)


def test_serve_step_shim_matches_query_many():
    """make_bitmap_query_step (now a one-shot service shim) stays
    bit-identical to the direct query_many path, including the empty
    batch."""
    db = _mk_db(n=512)
    rng = np.random.default_rng(5)
    queries = _mixed_queries(rng, 16, 40)
    step = make_bitmap_query_step(db)
    rows, counts = step(queries)
    want_r, want_c = db.query_many(queries).materialize()
    assert bool(jnp.all(rows == want_r)) and bool(jnp.all(counts == want_c))
    er, ec = step([])
    assert er.shape[0] == 0 and ec.shape[0] == 0
    with pytest.raises(Exception):      # bad query raises, like pre-shim
        step([key(999)])
    step.service.close()


def test_query_many_pad_output_semantics():
    """pad_output=True pads the materialized query axis to a power of
    two; the handles still cover exactly the submitted queries,
    bit-identical to the unpadded path."""
    db = _mk_db(n=512)
    qs = _mixed_queries(np.random.default_rng(41), 16, 10)
    rb = db.query_many(qs, pad_output=True)
    rows, counts = rb.materialize()
    assert rows.shape[0] == 16 and counts.shape[0] == 16
    want_r, want_c = db.query_many(qs).materialize()
    assert bool(jnp.all(rows[:10] == want_r))
    assert bool(jnp.all(counts[:10] == want_c))
    assert len(rb) == 10 and len(rb.all_ids()) == 10
    for i in range(10):
        assert int(rb[i].count) == int(want_c[i])


def test_service_warmup_counts_dispatches():
    db = _mk_db(n=256)
    with db.serve(max_batch=8, idle_after_ms=10_000.0) as svc:
        qs = _mixed_queries(np.random.default_rng(43), 16, 20)
        n1 = svc.warmup(qs)
        assert n1 > 0
        f = svc.submit(qs[0])
        assert int(f.count) == db.query(qs[0]).count


def test_future_surface():
    db = _mk_db(n=256)
    with db.serve(max_delay_ms=0.5) as svc:
        f = svc.submit(col("a") == 1)
        r, c = f.result(timeout=30)
        assert f.done() and f.exception() is None
        want = db.query(col("a") == 1)
        assert int(c) == want.count
        np.testing.assert_array_equal(f.ids, want.ids)
        assert f.count == want.count


# ------------------------------------------------------- drain/close/errors
def test_drain_and_close_answer_every_future_exactly_once():
    db = _mk_db(n=512)
    svc = db.serve(max_delay_ms=50.0, max_batch=64)
    futs = [svc.submit(q)
            for q in _mixed_queries(np.random.default_rng(7), 16, 90)]
    svc.close()                         # close implies drain
    seqs = sorted(f.resolve_seq for f in futs)
    assert all(f.done() for f in futs), "close() dropped futures"
    assert seqs == list(range(1, len(futs) + 1)), \
        "every future answered exactly once"
    with pytest.raises(ServiceClosed):
        svc.submit(col("a") == 0)
    svc.close()                         # idempotent
    assert svc.state == "closed"


def test_admission_reject_and_block_timeout():
    db = _mk_db(n=256)
    # a scheduler that never fires within the test window: the queue is
    # all admission control sees
    cfg = ServiceConfig(max_batch=10_000, max_delay_ms=60_000.0,
                        max_queue=4, admission="reject")
    svc = BitmapService(db, cfg)
    for i in range(4):
        svc.submit(col("a") == (i % 8))
    with pytest.raises(ServiceOverloaded):
        svc.submit(col("a") == 5)
    assert svc.metrics().rejected == 1
    svc.close()                         # still answers the queued four

    cfg = ServiceConfig(max_batch=10_000, max_delay_ms=60_000.0,
                        max_queue=2, admission="block")
    svc = BitmapService(db, cfg)
    svc.submit(col("a") == 0)
    svc.submit(col("a") == 1)
    t0 = time.perf_counter()
    with pytest.raises(ServiceOverloaded):
        svc.submit(col("a") == 2, timeout=0.05)
    assert time.perf_counter() - t0 >= 0.04
    svc.close()


def test_error_isolation_per_future():
    """One caller's bad query fails ITS future; everyone else's results
    are unaffected (and bit-identical to the sequential path)."""
    db = _mk_db(n=256)
    good1, bad, good2 = (col("a") == 2), key(999), (col("b") == 9)
    with db.serve(max_delay_ms=20.0, max_batch=16) as svc:
        f1, fb, f2 = svc.submit_many([good1, bad, good2])
        svc.drain(timeout=60)
        assert isinstance(fb.exception(), Exception)
        with pytest.raises(Exception):
            fb.result()
        assert int(f1.count) == db.query(good1).count
        assert int(f2.count) == db.query(good2).count


# ------------------------------------------------------------ standby cycle
def test_standby_transitions_and_energy_split():
    db = _mk_db(n=256)
    with db.serve(max_delay_ms=0.5, idle_after_ms=5.0) as svc:
        svc.submit(col("a") == 1)
        assert svc.drain(timeout=60)
        deadline = time.time() + 10
        while svc.state != "standby" and time.time() < deadline:
            time.sleep(0.005)
        assert svc.state == "standby"
        time.sleep(0.02)                # accrue standby joules
        m = svc.metrics()
        assert m.standby_entries >= 1
        assert m.standby_joules > 0.0
        assert m.active_joules > 0.0
        # standby power is orders of magnitude below active power
        assert (m.standby_joules / max(m.standby_seconds, 1e-9)
                < m.active_joules / max(m.busy_seconds
                                        + m.awake_idle_seconds, 1e-9) / 1e3)
        # a new submission wakes the scheduler
        f = svc.submit(col("a") == 2)
        f.result(timeout=30)
        assert svc.metrics().wakes >= 1


def test_explicit_standby_and_metrics_shape():
    db = _mk_db(n=256)
    svc = db.serve(max_delay_ms=0.5, idle_after_ms=10_000.0)
    f = svc.submit(col("a") == 0)
    f.result(timeout=30)
    svc.standby()
    assert svc.state == "standby"
    m = svc.metrics()
    assert m.served == 1 and m.batches >= 1
    assert m.plan_cache["misses"] >= 1
    svc.close()


# ----------------------------------------------------- background maintenance
def _append_blocks(db, rng, nblocks, block, m=16):
    blocks = [_records(rng, block, m) for _ in range(nblocks)]
    for b in blocks:
        db.append_encoded(b)
    return blocks


def test_background_maintenance_spills_compacts_and_recovers(tmp_path):
    path = os.path.join(str(tmp_path), "idx")
    db = BitmapDB(_schema(), path=path, spill_records=128, backend="ref")
    svc = db.serve(max_delay_ms=1.0)
    assert svc._maint is not None
    rng = np.random.default_rng(11)
    blocks = _append_blocks(db, rng, 16, 64)
    # serving stays correct while maintenance churns
    q = col("a") == 3
    want_ids = db.query(q).ids
    assert svc._maint_ex.flush(timeout=60)
    st = svc._maint_ex.stats()
    assert st["completed"].get("spill", 0) >= 1
    assert st["errors"] == 0
    assert db.store.durable_records > 0
    np.testing.assert_array_equal(svc.submit(q).ids, want_ids)
    svc.close()
    # restart: manifest + WAL recovery is bit-exact vs a full rebuild
    keys = jnp.arange(16, dtype=jnp.int32)
    want = backends.get_backend("ref").create_index(
        jnp.asarray(np.concatenate(blocks)), keys)
    db2 = repro.open(path, backend="ref")
    assert db2.num_records == 16 * 64
    assert bool(jnp.all(db2.index.packed == want))


def test_append_never_blocks_on_slow_spill(tmp_path, monkeypatch):
    """With background maintenance, append() latency is independent of
    segment-write latency: a spill artificially slowed to 600ms must not
    stall any append for even a third of that (appends do their own
    ~tens-of-ms of indexing work — the assertion is about not
    serializing behind the flush, so the simulated flush dwarfs it)."""
    slow = 0.6
    orig = SegmentStore.prepare_segment

    def slow_prepare(self, *a, **kw):
        time.sleep(slow)
        return orig(self, *a, **kw)

    monkeypatch.setattr(SegmentStore, "prepare_segment", slow_prepare)
    path = os.path.join(str(tmp_path), "idx")
    # capacity sized for the whole stream: append latency must measure
    # the spill interaction, not the (documented, pre-existing) capacity
    # growth retrace
    db = BitmapDB(_schema(), path=path, spill_records=64, backend="ref",
                  capacity_words=64)
    svc = db.serve()
    rng = np.random.default_rng(13)
    blocks = [_records(rng, 64) for _ in range(8)]
    db.append_encoded(blocks[0])        # warm the jit traces
    worst = 0.0
    for b in blocks[1:]:
        t0 = time.perf_counter()
        db.append_encoded(b)
        worst = max(worst, time.perf_counter() - t0)
    assert worst < slow / 3, \
        f"append blocked {worst:.3f}s on a {slow}s background spill"
    assert svc._maint_ex.flush(timeout=60)
    assert db.store.durable_records > 0   # the slow spills DID land
    svc.close()


def test_crash_between_background_spill_and_manifest_swap(tmp_path):
    """Kill between the background segment-file write and the manifest
    swap: the orphan file is ignored, the WAL still covers every block,
    recovery is bit-exact."""
    path = os.path.join(str(tmp_path), "idx")
    db = BitmapDB(_schema(), path=path, spill_records=None, backend="ref")
    rng = np.random.default_rng(17)
    blocks = _append_blocks(db, rng, 5, 64)
    token = db.indexer.prepare_spill()
    assert token is not None            # segment file written...
    # ...and the "process dies" here: no commit_spill.
    keys = jnp.arange(16, dtype=jnp.int32)
    want = backends.get_backend("ref").create_index(
        jnp.asarray(np.concatenate(blocks)), keys)
    db2 = repro.open(path, backend="ref")
    assert db2.num_records == 5 * 64
    assert bool(jnp.all(db2.index.packed == want))
    # the orphan segment is gc fodder in the recovered store
    st = db2.store.gc()
    assert token[0].file in st


def test_wal_carry_over_append_racing_background_flush(tmp_path):
    """A block appended BETWEEN prepare_spill and commit_spill lands in
    the outgoing WAL generation; the commit's rotation must carry it
    into the fresh generation — crash after the commit, recover, and the
    racing block must still be there bit-exactly."""
    path = os.path.join(str(tmp_path), "idx")
    db = BitmapDB(_schema(), path=path, spill_records=None, backend="ref")
    rng = np.random.default_rng(19)
    blocks = _append_blocks(db, rng, 3, 64)
    si = db.indexer
    token = si.prepare_spill()
    racing = _records(rng, 48)          # appended mid-flush
    db.append_encoded(racing)
    blocks.append(racing)
    si.commit_spill(token)              # rotates + carries the racing block
    # crash NOW: drop the in-memory index entirely, recover from disk
    keys = jnp.arange(16, dtype=jnp.int32)
    want = backends.get_backend("ref").create_index(
        jnp.asarray(np.concatenate(blocks)), keys)
    db2 = repro.open(path, backend="ref")
    assert db2.num_records == 3 * 64 + 48
    assert bool(jnp.all(db2.index.packed == want))
    # and a recovery of the recovery (the carried WAL must itself be
    # intact after reopening)
    db3 = repro.open(path, backend="ref")
    assert bool(jnp.all(db3.index.packed == want))


def test_failed_manifest_commit_then_retry_recovers(tmp_path, monkeypatch):
    """Phase-C (manifest swap) failure mid-flush: the WAL handle has
    already switched to the fresh generation.  Post-failure appends,
    crash recovery, and a same-session retry of the spill must all stay
    bit-exact (the retry must NOT truncate the generation holding live
    blocks)."""
    from repro.store import store as store_mod

    path = os.path.join(str(tmp_path), "idx")
    db = BitmapDB(_schema(), path=path, spill_records=None, backend="ref")
    rng = np.random.default_rng(37)
    blocks = _append_blocks(db, rng, 3, 64)
    si = db.indexer
    token = si.prepare_spill()
    monkeypatch.setattr(store_mod, "commit",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            OSError("disk full (simulated)")))
    with pytest.raises(OSError):
        si.commit_spill(token)
    si.abort_spill(token)
    monkeypatch.undo()
    racing = _records(rng, 48)          # lands in the switched generation
    db.append_encoded(racing)
    blocks.append(racing)
    keys = jnp.arange(16, dtype=jnp.int32)
    want = backends.get_backend("ref").create_index(
        jnp.asarray(np.concatenate(blocks)), keys)
    # crash now: recovery must see every block exactly once
    db2 = repro.open(path, backend="ref")
    assert db2.num_records == 3 * 64 + 48
    assert bool(jnp.all(db2.index.packed == want))
    # live-session retry: the flush must skip the truncating rotation
    db.snapshot()
    db3 = repro.open(path, backend="ref")
    assert db3.num_records == 3 * 64 + 48
    assert bool(jnp.all(db3.index.packed == want))


# ---------------------------------------------------------- store satellites
def test_gc_inflight_guard_and_dry_run(tmp_path):
    path = os.path.join(str(tmp_path), "idx")
    db = BitmapDB(_schema(), path=path, spill_records=None, backend="ref")
    rng = np.random.default_rng(23)
    _append_blocks(db, rng, 2, 64)
    token = db.indexer.prepare_spill()
    store = db.store
    st = store.gc()                     # concurrent with the in-flight flush
    assert token[0].file in st.skipped_inflight
    assert token[0].file not in st
    db.indexer.commit_spill(token)      # file survives to become live
    assert any(s.file == token[0].file for s in store.segments)
    dry = store.gc(dry_run=True)
    assert dry.dry_run
    for name in dry:                    # nothing actually deleted
        assert os.path.exists(os.path.join(path, name))
    wet = store.gc()
    assert tuple(wet) == tuple(dry)
    for name in wet:
        assert not os.path.exists(os.path.join(path, name))
    assert wet.bytes_reclaimed == dry.bytes_reclaimed


def test_compact_stats_and_dry_run(tmp_path):
    rng = np.random.default_rng(29)
    keys = np.arange(8, dtype=np.int32)
    store = SegmentStore(str(tmp_path), compact_fanout=2,
                         auto_compact=False)
    store.ensure_keys(keys)
    at = 0
    for _ in range(4):                  # four same-tier segments
        rec = rng.integers(0, 8, (16, 2), dtype=np.int32)
        packed = np.asarray(backends.get_backend("ref").create_index(
            jnp.asarray(rec), jnp.asarray(keys)))
        store.write_segment(packed, 16, at)
        at += 16
    dry = store.compact(dry_run=True)
    assert dry.dry_run and dry.merges >= 1 and dry.segments_merged >= 2
    assert len(store.segments) == 4     # dry run touched nothing
    wet = store.compact()
    assert wet == dry.merges            # int comparison compatibility
    assert wet.segments_merged == dry.segments_merged
    assert wet.bytes_written > 0 and wet.bytes_reclaimed > 0
    assert len(store.segments) < 4
    assert store.compact() == 0         # idempotent


def test_plan_cache_bounds_and_stats():
    db = _mk_db(n=256)
    db._VALUE_CACHE_LIMIT = 8           # instance override for the test
    rng = np.random.default_rng(31)
    qs = _mixed_queries(rng, 16, 40)
    for q in qs:
        db.query(q)
    st = db.cache_stats()
    assert st["value_size"] <= 8
    assert st["value_evictions"] > 0
    assert st["misses"] > 0
    # resubmitting the same OBJECT is an identity hit
    before = db.cache_stats()["id_hits"]
    db.query(qs[-1])
    assert db.cache_stats()["id_hits"] == before + 1
    # structurally equal fresh object: value hit (if not evicted)
    db.replan()
    q = col("a") == 1
    db.query(q)
    db.query(col("a") == 1)
    assert db.cache_stats()["value_hits"] >= 1


# ------------------------------------------------------------- data pipeline
def test_pipeline_prefetch_matches_sync():
    from repro.data.pipeline import BitmapIndexedDataset, DataConfig

    cfg = DataConfig(vocab_size=64, seq_len=8, docs_per_shard=64,
                     num_shards=2, num_attributes=32)
    ds = BitmapIndexedDataset(cfg)
    w = (col("domain").isin([0, 1])) & ~(col("quality") == 4)
    try:
        futs = ds.select_many_async(0, [w, col("lang") == 1])
        sync = ds.select_many(0, [w, col("lang") == 1])
        for f, ids in zip(futs, sync):
            np.testing.assert_array_equal(f.ids, ids)
        b1 = next(ds.batches(4, where=w, seed=3, prefetch=True))
        b2 = next(ds.batches(4, where=w, seed=3, prefetch=False))
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        np.testing.assert_array_equal(np.asarray(b1["labels"]),
                                      np.asarray(b2["labels"]))
    finally:
        ds.close()
