"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle,
across shapes and dtypes.  The hypothesis property tests on the bit-level
invariants live in tests/test_kernels_properties.py (they skip when
hypothesis is absent; these differential tests never do)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bit_transpose import bit_transpose
from repro.kernels.bitmap_ops import bitmap_query
from repro.kernels.cam_match import cam_match

RNG = np.random.default_rng(42)


# ------------------------------------------------------------- cam_match
@pytest.mark.parametrize("n,w,m,bn,bm", [
    (8, 32, 32, 4, 32),          # paper-like core geometry
    (16, 8, 64, 8, 32),
    (64, 32, 128, 16, 64),
    (256, 16, 256, 64, 128),
])
def test_cam_match_kernel_shapes(n, w, m, bn, bm):
    records = jnp.asarray(RNG.integers(0, 256, (n, w), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(0, 256, (m,), dtype=np.int32))
    got = cam_match(records, keys, block_n=bn, block_m=bm)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.cam_match(records, keys)))


@pytest.mark.parametrize("dtype", [np.int32, np.uint8, np.int16])
def test_cam_match_dtypes(dtype):
    records = jnp.asarray(RNG.integers(0, 120, (16, 8)).astype(dtype))
    keys = jnp.asarray(RNG.integers(0, 120, (32,)).astype(dtype))
    got = ops.cam_match(records, keys)
    want = ref.cam_match(records.astype(jnp.int32), keys.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cam_match_odd_shapes_padding():
    records = jnp.asarray(RNG.integers(0, 256, (19, 7), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(0, 256, (37,), dtype=np.int32))
    got = ops.cam_match(records, keys)
    dense = np.asarray(ref.cam_match_unpacked(records, keys))
    got_dense = np.asarray(ref.unpack_bits(got, 37))
    np.testing.assert_array_equal(got_dense, dense)


# --------------------------------------------------------- bit_transpose
@pytest.mark.parametrize("r,cw,bc", [
    (32, 1, 1), (64, 4, 2), (128, 8, 8), (256, 16, 4),
])
def test_bit_transpose_kernel(r, cw, bc):
    x = jnp.asarray(RNG.integers(0, 2 ** 32, (r, cw), dtype=np.uint32))
    got = bit_transpose(x, block_c=bc)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.bit_transpose(x)))


# ----------------------------------------------------------- bitmap query
@pytest.mark.parametrize("k,nw,bn", [(1, 8, 8), (3, 64, 32), (5, 256, 128)])
def test_bitmap_query_kernel(k, nw, bn):
    rows = jnp.asarray(RNG.integers(0, 2 ** 32, (k, nw), dtype=np.uint32))
    inv = jnp.asarray(RNG.integers(0, 2, (k,), dtype=np.int32))
    res, cnt = bitmap_query(rows, inv, block_n=bn)
    wres, wcnt = ref.bitmap_query(rows, inv)
    np.testing.assert_array_equal(np.asarray(res), np.asarray(wres))
    assert int(cnt) == int(wcnt)


# -------------------------------------------------- pallas flash attention
@pytest.mark.parametrize("causal,s,bq,bk", [
    (True, 256, 64, 64), (False, 300, 64, 96), (True, 128, 128, 32),
])
def test_pallas_flash_fwd_vs_naive(causal, s, bq, bk):
    from repro.kernels.attention import flash_attention_fwd
    rng = np.random.default_rng(1)
    BH, hd = 3, 32
    q = jnp.asarray(rng.standard_normal((BH, s, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, s, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, s, hd)), jnp.float32)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=bq, block_k=bk)
    scores = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(hd)
    if causal:
        mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
        scores = jnp.where(mask[None], scores, -1e30)
    want = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
