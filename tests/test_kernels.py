"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle,
across shapes and dtypes, plus hypothesis property tests on the bit-level
invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bit_transpose import bit_transpose
from repro.kernels.bitmap_ops import bitmap_query
from repro.kernels.cam_match import cam_match

RNG = np.random.default_rng(42)


# ------------------------------------------------------------- cam_match
@pytest.mark.parametrize("n,w,m,bn,bm", [
    (8, 32, 32, 4, 32),          # paper-like core geometry
    (16, 8, 64, 8, 32),
    (64, 32, 128, 16, 64),
    (256, 16, 256, 64, 128),
])
def test_cam_match_kernel_shapes(n, w, m, bn, bm):
    records = jnp.asarray(RNG.integers(0, 256, (n, w), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(0, 256, (m,), dtype=np.int32))
    got = cam_match(records, keys, block_n=bn, block_m=bm)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.cam_match(records, keys)))


@pytest.mark.parametrize("dtype", [np.int32, np.uint8, np.int16])
def test_cam_match_dtypes(dtype):
    records = jnp.asarray(RNG.integers(0, 120, (16, 8)).astype(dtype))
    keys = jnp.asarray(RNG.integers(0, 120, (32,)).astype(dtype))
    got = ops.cam_match(records, keys)
    want = ref.cam_match(records.astype(jnp.int32), keys.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cam_match_odd_shapes_padding():
    records = jnp.asarray(RNG.integers(0, 256, (19, 7), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(0, 256, (37,), dtype=np.int32))
    got = ops.cam_match(records, keys)
    dense = np.asarray(ref.cam_match_unpacked(records, keys))
    got_dense = np.asarray(ref.unpack_bits(got, 37))
    np.testing.assert_array_equal(got_dense, dense)


# --------------------------------------------------------- bit_transpose
@pytest.mark.parametrize("r,cw,bc", [
    (32, 1, 1), (64, 4, 2), (128, 8, 8), (256, 16, 4),
])
def test_bit_transpose_kernel(r, cw, bc):
    x = jnp.asarray(RNG.integers(0, 2 ** 32, (r, cw), dtype=np.uint32))
    got = bit_transpose(x, block_c=bc)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.bit_transpose(x)))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.integers(0, 2 ** 32 - 1))
def test_bit_transpose_involution(rw, cw, seed):
    """Property: transpose(transpose(X)) == X for 32-aligned matrices."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 2 ** 32, (32 * rw, cw), dtype=np.uint32))
    tt = ops.transpose(ops.transpose(x))
    np.testing.assert_array_equal(np.asarray(tt), np.asarray(x))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_transpose_moves_bits(seed):
    """Property: bit (r, c) lands at (c, r)."""
    rng = np.random.default_rng(seed)
    r, c = int(rng.integers(0, 64)), int(rng.integers(0, 64))
    x = np.zeros((64, 2), np.uint32)
    x[r, c // 32] = np.uint32(1) << (c % 32)
    y = np.asarray(ops.transpose(jnp.asarray(x)))
    assert (y[c, r // 32] >> np.uint32(r % 32)) & 1 == 1
    assert y.sum() == y[c, r // 32]      # exactly one bit set


# ----------------------------------------------------------- bitmap query
@pytest.mark.parametrize("k,nw,bn", [(1, 8, 8), (3, 64, 32), (5, 256, 128)])
def test_bitmap_query_kernel(k, nw, bn):
    rows = jnp.asarray(RNG.integers(0, 2 ** 32, (k, nw), dtype=np.uint32))
    inv = jnp.asarray(RNG.integers(0, 2, (k,), dtype=np.int32))
    res, cnt = bitmap_query(rows, inv, block_n=bn)
    wres, wcnt = ref.bitmap_query(rows, inv)
    np.testing.assert_array_equal(np.asarray(res), np.asarray(wres))
    assert int(cnt) == int(wcnt)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
def test_query_matches_set_semantics(k, nw, seed):
    """Property: the query result equals python-set evaluation."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, 2 ** 32, (k, nw), dtype=np.uint32)
    inv = rng.integers(0, 2, (k,), dtype=np.int32)
    res, cnt = ops.query(jnp.asarray(rows), jnp.asarray(inv))
    n = nw * 32
    want = np.ones(n, bool)
    dense = np.asarray(ref.unpack_bits(jnp.asarray(rows), n)).astype(bool)
    for i in range(k):
        want &= ~dense[i] if inv[i] else dense[i]
    got = np.asarray(ref.unpack_bits(res[None], n))[0].astype(bool)
    np.testing.assert_array_equal(got, want)
    assert int(cnt) == int(want.sum())


# ------------------------------------------------------------ end-to-end
@settings(max_examples=15, deadline=None)
@given(st.integers(2, 40), st.integers(1, 12), st.integers(2, 50),
       st.integers(0, 2 ** 31 - 1))
def test_create_index_property(n, w, m, seed):
    """Property: BI(i, j) == 1 iff record j contains key i (paper Fig. 1)."""
    rng = np.random.default_rng(seed)
    records = rng.integers(0, 64, (n, w), dtype=np.int32)
    keys = rng.integers(0, 64, (m,), dtype=np.int32)
    bi = ops.create_index(jnp.asarray(records), jnp.asarray(keys))
    dense = np.asarray(ref.unpack_bits(bi, n))
    for i in range(m):
        for j in range(n):
            assert dense[i, j] == int(keys[i] in records[j])


# -------------------------------------------------- pallas flash attention
@pytest.mark.parametrize("causal,s,bq,bk", [
    (True, 256, 64, 64), (False, 300, 64, 96), (True, 128, 128, 32),
])
def test_pallas_flash_fwd_vs_naive(causal, s, bq, bk):
    from repro.kernels.attention import flash_attention_fwd
    rng = np.random.default_rng(1)
    BH, hd = 3, 32
    q = jnp.asarray(rng.standard_normal((BH, s, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, s, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, s, hd)), jnp.float32)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=bq, block_k=bk)
    scores = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(hd)
    if causal:
        mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
        scores = jnp.where(mask[None], scores, -1e30)
    want = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
