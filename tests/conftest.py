import os
import sys

# Tests run on the real single CPU device — the 512-device override is
# strictly for the dry-run (see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture(scope="session", autouse=True)
def _lock_witness():
    """Runtime lock-order witness (REPRO_LOCK_WITNESS=1): wraps every
    lock created from repro source for the whole session, records the
    observed (held, acquired) nestings, and fails the run if any
    contradicts the ARCHITECTURE.md lock hierarchy.  Off by default so
    local `pytest -x -q` stays full speed; CI turns it on."""
    if os.environ.get("REPRO_LOCK_WITNESS") != "1":
        yield
        return
    from repro.analysis import witness
    wit = witness.install()
    yield
    wit.uninstall()
    violations = wit.violations()
    assert not violations, (
        "lock-order witness observed nestings that contradict the "
        "documented hierarchy:\n  " + "\n  ".join(violations))


@pytest.fixture(autouse=True)
def _lock_witness_isolation():
    """Between tests, clear the probing thread's witness context: a
    crash-simulation test that abandons an open two-phase flush leaves
    that discarded store's lock 'held', which would otherwise poison
    every nesting observed afterwards on this thread."""
    yield
    if os.environ.get("REPRO_LOCK_WITNESS") == "1":
        from repro.analysis import witness
        wit = witness.current()
        if wit is not None:
            wit.reset_thread()
