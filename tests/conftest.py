import os
import sys

# Tests run on the real single CPU device — the 512-device override is
# strictly for the dry-run (see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
