"""repro.store: durable segment store, WAL recovery, segment-parallel
serving.

The acceptance bar (ISSUE 3):
  * recovery is bit-exact — an index spilled mid-stream, "crashed", and
    recovered from manifest + WAL equals the never-spilled in-memory
    packed index word for word;
  * segment-parallel ``query_many`` over a spilled index matches in-memory
    results for the same predicate trees;
  * torn WAL tails and corrupt segment files fail loudly (CRC), never
    silently feed garbage bits.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.engine import backends, batch, policy
from repro.engine.planner import execute, key
from repro.engine.runtime import MulticoreRuntime, StreamingIndexer
from repro.store import (CorruptFileError, SegmentStore, np_splice,
                         open_index, recover_index)
from repro.store import format as fmt
from repro.store import wal as wal_mod

RNG = np.random.default_rng(77)


def _keys(m=11, hi=32):
    return jnp.asarray(RNG.integers(0, hi, (m,), dtype=np.int32))


def _blocks(sizes, w=5, hi=32):
    return [jnp.asarray(RNG.integers(0, hi, (n, w), dtype=np.int32))
            for n in sizes]


def _rebuild(blocks, keys):
    return backends.get_backend("ref").create_index(
        jnp.concatenate(blocks, axis=0), keys)


# -------------------------------------------------------- format substrate
def test_array_file_roundtrip(tmp_path):
    arrays = {"a": np.arange(12, dtype=np.uint32).reshape(3, 4),
              "b": np.linspace(0, 1, 5, dtype=np.float32)}
    path = str(tmp_path / "x.seg")
    fmt.write_array_file(path, arrays, meta={"n": 7})
    out, meta = fmt.read_array_file(path)
    assert meta == {"n": 7}
    for k in arrays:
        np.testing.assert_array_equal(out[k], arrays[k])
        assert out[k].dtype == arrays[k].dtype


def test_array_file_detects_corruption(tmp_path):
    path = str(tmp_path / "x.seg")
    fmt.write_array_file(path, {"a": np.arange(64, dtype=np.uint32)})
    raw = bytearray(open(path, "rb").read())
    raw[-5] ^= 0x10                       # flip one payload bit
    open(path, "wb").write(bytes(raw))
    with pytest.raises(CorruptFileError, match="CRC"):
        fmt.read_array_file(path)
    open(path, "wb").write(bytes(raw[: len(raw) // 2]))   # truncation
    with pytest.raises(CorruptFileError):
        fmt.read_array_file(path)
    open(path, "wb").write(b"JUNKJUNKJUNK")
    with pytest.raises(CorruptFileError, match="magic"):
        fmt.read_array_file(path)
    open(path, "wb").write(fmt.ARRAY_MAGIC + b"\x01\x00")   # 6-byte stump
    with pytest.raises(CorruptFileError, match="preamble"):
        fmt.read_array_file(path)


def test_wal_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "wal.log")
    w = wal_mod.WriteAheadLog(path)
    b1 = RNG.integers(0, 99, (4, 3)).astype(np.int32)
    b2 = RNG.integers(0, 99, (7, 3)).astype(np.int32)
    w.append_block(b1, 0)
    w.append_block(b2, 4, tick=5)
    w.close()
    got = wal_mod.replay(path)
    assert [(s, r.shape, t) for s, r, t in got] == [
        (0, (4, 3), None), (4, (7, 3), 5)]
    np.testing.assert_array_equal(got[1][1], b2)
    # torn tail: cut mid-second-entry -> only the first survives, no raise
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 9)
    got = wal_mod.replay(path)
    assert len(got) == 1
    np.testing.assert_array_equal(got[0][1], b1)


# ------------------------------------------------------ spill + recovery
@pytest.mark.parametrize("sizes,flush", [
    ([17, 33, 5, 64, 9], 40),        # unaligned segment boundaries + tail
    ([16, 16, 16], 16),              # aligned, every append spills
    ([7, 3, 2], 1000),               # nothing ever spills: pure WAL replay
    ([50], 10),                      # single oversized block
])
def test_crash_recovery_bit_exact(tmp_path, sizes, flush):
    """Acceptance: kill after N appends, recover from manifest + WAL,
    assert bit-identical packed words vs the never-spilled index."""
    keys = _keys()
    blocks = _blocks(sizes)
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(SegmentStore(str(tmp_path)), flush_records=flush)
    for b in blocks:
        si.append(b)
    want = _rebuild(blocks, keys)
    np.testing.assert_array_equal(np.asarray(si.index.packed),
                                  np.asarray(want))
    # "crash": the object dies; a fresh store over the same dir recovers
    si2 = StreamingIndexer.restore(SegmentStore(str(tmp_path)), keys,
                                   backend="ref")
    assert si2.num_records == sum(sizes)
    np.testing.assert_array_equal(np.asarray(si2.index.packed),
                                  np.asarray(want))
    # and the recovered indexer keeps appending correctly
    extra = _blocks([21])[0]
    si2.append(extra)
    want2 = _rebuild(blocks + [extra], keys)
    np.testing.assert_array_equal(np.asarray(si2.index.packed),
                                  np.asarray(want2))


def test_recovery_drops_torn_wal_tail(tmp_path):
    keys = _keys()
    blocks = _blocks([11, 13])
    si = StreamingIndexer(keys, backend="ref")
    store = SegmentStore(str(tmp_path))
    si.attach_store(store, flush_records=None)
    for b in blocks:
        si.append(b)
    wal = store.wal_path()
    with open(wal, "r+b") as f:          # crash mid-append of block 2
        f.truncate(os.path.getsize(wal) - 7)
    si2 = StreamingIndexer.restore(SegmentStore(str(tmp_path)), keys,
                                   backend="ref")
    assert si2.num_records == 11
    np.testing.assert_array_equal(np.asarray(si2.index.packed),
                                  np.asarray(_rebuild(blocks[:1], keys)))


def test_recovery_ignores_orphan_segment(tmp_path):
    """Crash between segment-file write and manifest commit: the orphan
    file is invisible (CURRENT still points at the old set) and the WAL
    still covers its records."""
    keys = _keys()
    blocks = _blocks([9, 14])
    store = SegmentStore(str(tmp_path))
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(store, flush_records=None)
    for b in blocks:
        si.append(b)
    # simulated half-flush: segment file exists, manifest never committed
    tail = policy.extract_packed(si.index.packed, 0, 23)
    fmt.write_array_file(str(tmp_path / "seg-00000099.seg"),
                         {"packed": np.asarray(jax.device_get(tail))},
                         meta={"segment_id": 99, "start_record": 0,
                               "num_records": 23})
    si2 = StreamingIndexer.restore(SegmentStore(str(tmp_path)), keys,
                                   backend="ref")
    assert si2.num_records == 23
    np.testing.assert_array_equal(np.asarray(si2.index.packed),
                                  np.asarray(_rebuild(blocks, keys)))
    assert "seg-00000099.seg" in SegmentStore(str(tmp_path)).gc()


def test_segment_crc_detects_bit_flip(tmp_path):
    keys = _keys()
    store = SegmentStore(str(tmp_path))
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(store, flush_records=None)
    si.append(_blocks([40])[0])
    si.spill()
    seg = store.segments[0]
    path = store.segment_path(seg)
    raw = bytearray(open(path, "rb").read())
    raw[-2] ^= 0x04
    open(path, "wb").write(bytes(raw))
    with pytest.raises(CorruptFileError):
        SegmentStore(str(tmp_path)).load_packed()


def test_spill_is_idempotent_and_attach_validates(tmp_path):
    keys = _keys()
    store = SegmentStore(str(tmp_path))
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(store, flush_records=None)
    si.append(_blocks([10])[0])
    si.spill()
    v = store.manifest.version
    si.spill()                            # nothing new: no commit
    assert store.manifest.version == v
    # a fresh empty indexer cannot claim a non-empty store
    with pytest.raises(ValueError, match="restore"):
        StreamingIndexer(keys, backend="ref").attach_store(store)
    # and a different key set (any length) is rejected
    with pytest.raises(ValueError, match="key set"):
        StreamingIndexer(_keys(m=5), backend="ref").attach_store(store)


def test_appends_after_torn_tail_recovery_survive_next_recovery(tmp_path):
    """Regression: reopening a torn WAL must truncate the torn frame
    BEFORE appending — bytes after a torn frame are unreachable to
    readers, so a post-recovery append would otherwise vanish on the
    second recovery."""
    keys = _keys()
    b1, b2, b3 = _blocks([11, 9, 9])
    si = StreamingIndexer(keys, backend="ref")
    store = SegmentStore(str(tmp_path))
    si.attach_store(store, flush_records=None)
    si.append(b1)
    si.append(b2)
    wal = store.wal_path()
    with open(wal, "r+b") as f:          # crash mid-append of b2
        f.truncate(os.path.getsize(wal) - 7)
    si2 = StreamingIndexer.restore(SegmentStore(str(tmp_path)), keys,
                                   backend="ref")
    assert si2.num_records == 11
    si2.append(b3)
    si3 = StreamingIndexer.restore(SegmentStore(str(tmp_path)), keys,
                                   backend="ref")
    assert si3.num_records == 20
    np.testing.assert_array_equal(np.asarray(si3.index.packed),
                                  np.asarray(_rebuild([b1, b3], keys)))


def test_attach_rejects_store_with_wal_tail(tmp_path):
    """Regression: a store that crashed before its first spill has no
    durable records but DOES have WAL blocks; a fresh indexer attaching
    to it would log conflicting blocks at already-claimed offsets."""
    keys = _keys()
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(SegmentStore(str(tmp_path)), flush_records=None)
    si.append(_blocks([11])[0])          # crash: WAL tail, zero segments
    with pytest.raises(ValueError, match="WAL tail"):
        StreamingIndexer(keys, backend="ref").attach_store(
            SegmentStore(str(tmp_path)))
    # restore remains the sanctioned resume path
    si2 = StreamingIndexer.restore(SegmentStore(str(tmp_path)), keys,
                                   backend="ref")
    assert si2.num_records == 11


def test_attach_spills_pre_existing_prefix(tmp_path):
    """Regression: records indexed BEFORE the attach were never
    WAL-logged; attach must flush them immediately or a crash before the
    first threshold spill would leave an unrecoverable gap below the WAL
    floor."""
    keys = _keys()
    blocks = _blocks([40, 9])
    si = StreamingIndexer(keys, backend="ref")
    si.append(blocks[0])                 # in-memory only, no store yet
    store = SegmentStore(str(tmp_path))
    si.attach_store(store, flush_records=None)
    assert store.durable_records == 40   # prefix flushed at attach
    si.append(blocks[1])                 # WAL-logged; crash here
    si2 = StreamingIndexer.restore(SegmentStore(str(tmp_path)), keys,
                                   backend="ref")
    assert si2.num_records == 49
    np.testing.assert_array_equal(np.asarray(si2.index.packed),
                                  np.asarray(_rebuild(blocks, keys)))


def test_empty_stored_index_serves_zero_results(tmp_path):
    stored = open_index(SegmentStore(str(tmp_path)))
    assert stored.num_records == 0 and stored.num_segments == 0
    rows, counts = stored.query_many([key(0), key(3) & ~key(1)],
                                     backend="ref")
    assert rows.shape == (2, 0)
    np.testing.assert_array_equal(np.asarray(counts), [0, 0])


def test_pipeline_rejects_stale_key_count(tmp_path):
    from repro.data.pipeline import BitmapIndexedDataset, DataConfig
    cfg = DataConfig(vocab_size=64, seq_len=8, docs_per_shard=64,
                     num_shards=1, num_attributes=32)
    BitmapIndexedDataset(cfg, store_dir=str(tmp_path)).select(0, include=[1])
    cfg2 = DataConfig(vocab_size=64, seq_len=8, docs_per_shard=64,
                      num_shards=1, num_attributes=40)
    with pytest.raises(ValueError, match="stale store_dir"):
        BitmapIndexedDataset(cfg2, store_dir=str(tmp_path)).select(
            0, include=[1])


def test_gc_collects_stale_tmp_files(tmp_path):
    keys = _keys()
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(SegmentStore(str(tmp_path)), flush_records=None)
    si.append(_blocks([11])[0])
    si.spill()
    (tmp_path / "seg-00000099.seg.tmp").write_bytes(b"half-written")
    (tmp_path / "CURRENT.tmp").write_bytes(b"half")
    removed = SegmentStore(str(tmp_path)).gc()
    assert "seg-00000099.seg.tmp" in removed
    assert "CURRENT.tmp" in removed
    assert SegmentStore(str(tmp_path)).durable_records == 11


def test_restore_rejects_same_length_different_keys(tmp_path):
    """Regression: the store persists the key VALUES (KEYS.arr), so a
    restart that passes a different same-length key set fails fast
    instead of recovering a silently inconsistent index (segments built
    under old keys + WAL re-indexed under new ones)."""
    keys = _keys()
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(SegmentStore(str(tmp_path)), flush_records=None)
    si.append(_blocks([20])[0])          # crash with a WAL tail
    other = jnp.asarray(np.asarray(keys) + 1)
    with pytest.raises(ValueError, match="different key set"):
        StreamingIndexer.restore(SegmentStore(str(tmp_path)), other,
                                 backend="ref")
    # the true key set still restores
    assert StreamingIndexer.restore(SegmentStore(str(tmp_path)), keys,
                                    backend="ref").num_records == 20


def test_run_tick_replay_is_idempotent(tmp_path):
    """Regression: re-feeding the tick that was in flight at crash time
    must append only the blocks each core had NOT yet absorbed — the
    (tick, blocks) watermark survives restart, so nothing duplicates and
    nothing is lost."""
    mesh = _one_device_mesh()
    keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    rt = MulticoreRuntime(mesh, backend="ref", store_dir=str(tmp_path),
                          flush_records=1000)
    t0 = jnp.asarray(RNG.integers(0, 256, (3, 16, 32), dtype=np.int32))
    t1 = jnp.asarray(RNG.integers(0, 256, (3, 16, 32), dtype=np.int32))
    rt.run_tick(t0, keys, 0.01, tick_id=0)
    # crash mid-tick-1: the core absorbed only the first of its 3 batches
    be = backends.get_backend("ref")
    rt.core_indexers(keys)[0].append_indexed(
        t1[0], be.create_index(t1[0], keys), tick=1)
    # restart + at-least-once replay of tick 1, then a duplicate replay
    rt2 = MulticoreRuntime(mesh, backend="ref", store_dir=str(tmp_path),
                           flush_records=1000)
    rt2.run_tick(t1, keys, 0.01, tick_id=1)
    rt2.run_tick(t1, keys, 0.01, tick_id=1)      # full duplicate: no-op
    rec = rt2.core_indexes(keys)[0]
    assert rec.num_records == 96                 # 6 batches x 16, no dupes
    want = be.create_index(
        jnp.concatenate([t0.reshape(-1, 32), t1.reshape(-1, 32)]), keys)
    np.testing.assert_array_equal(np.asarray(rec.packed), np.asarray(want))


def test_runtime_core_indexers_reject_changed_keys(tmp_path):
    mesh = _one_device_mesh()
    keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    rt = MulticoreRuntime(mesh, backend="ref", store_dir=str(tmp_path))
    records = jnp.asarray(RNG.integers(0, 256, (2, 16, 32), dtype=np.int32))
    rt.run_tick(records, keys, 0.01)
    other = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    with pytest.raises(ValueError, match="different key set"):
        rt.run_tick(records, other, 0.01)


# ---------------------------------------------------------- compaction
def test_tiered_compaction_merges_and_preserves_bits(tmp_path):
    keys = _keys()
    store = SegmentStore(str(tmp_path), compact_fanout=3)
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(store, flush_records=None)
    blocks = _blocks([7] * 9)
    for b in blocks:
        si.append(b)
        si.spill()
    # 9 x 7-record segments under fanout 3 cascade into one 63-record one
    assert len(store.segments) < 9
    assert store.durable_records == 63
    si2 = StreamingIndexer.restore(SegmentStore(str(tmp_path)), keys,
                                   backend="ref")
    np.testing.assert_array_equal(np.asarray(si2.index.packed),
                                  np.asarray(_rebuild(blocks, keys)))


def test_compaction_disabled_keeps_segments(tmp_path):
    keys = _keys()
    store = SegmentStore(str(tmp_path), auto_compact=False)
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(store, flush_records=None)
    for b in _blocks([5] * 6):
        si.append(b)
        si.spill()
    assert len(store.segments) == 6
    assert store.compact() > 0           # explicit compact still works
    assert len(store.segments) < 6


# ------------------------------------------- segment-parallel query serving
def _random_pred(rng, m, depth=3):
    from repro.engine.planner import And, Or
    if depth == 0 or rng.random() < 0.3:
        leaf = key(int(rng.integers(0, m)))
        return ~leaf if rng.random() < 0.4 else leaf
    arity = int(rng.integers(2, 4))
    children = tuple(_random_pred(rng, m, depth - 1) for _ in range(arity))
    node = And(children) if rng.random() < 0.5 else Or(children)
    return ~node if rng.random() < 0.2 else node


def test_execute_many_segments_matches_whole_index():
    """The batch layer itself: random split points over one index, results
    bit-identical to execute_many over the unsplit packed array."""
    n, m = 181, 16
    records = jnp.asarray(RNG.integers(0, 48, (n, 8), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(0, 48, (m,), dtype=np.int32))
    full = backends.get_backend("ref").create_index(records, keys)
    rng = np.random.default_rng(5)
    preds = [_random_pred(rng, m) for _ in range(20)]
    preds.append(key(0) & ~key(0))        # contradiction
    want_r, want_c = batch.execute_many(full, preds, num_records=n,
                                        backend="ref")
    for cuts in ([60, 61, 60], [181], [1, 90, 90], [32, 149]):
        assert sum(cuts) == n
        parts, at = [], 0
        for c in cuts:
            parts.append((backends.get_backend("ref").create_index(
                records[at:at + c], keys), c))
            at += c
        rows, counts = batch.execute_many_segments(parts, preds,
                                                   backend="ref")
        np.testing.assert_array_equal(np.asarray(rows), np.asarray(want_r))
        np.testing.assert_array_equal(np.asarray(counts),
                                      np.asarray(want_c))


def test_execute_many_segments_stacks_uniform_word_counts():
    """Satellite: segments sharing one word count serve each bucket in a
    single vmapped dispatch (stacked over segments) — bit-identical to the
    per-segment dispatch path and to the unsplit index."""
    n, m = 191, 16
    records = jnp.asarray(RNG.integers(0, 48, (n, 8), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(0, 48, (m,), dtype=np.int32))
    full = backends.get_backend("ref").create_index(records, keys)
    rng = np.random.default_rng(21)
    preds = [_random_pred(rng, m) for _ in range(25)]
    preds.append(key(0) & ~key(0))            # contradiction (zeros path)
    # an adversarial deep tree exercising the composite fallback per segment
    deep = key(0) | key(1)
    for i in range(2, 18):
        deep = (key(i % m) | key((i + 1) % m)) & deep
    preds.append(deep)
    want_r, want_c = batch.execute_many(full, preds, num_records=n,
                                        backend="ref")
    # 64/63/64: all three segments pack into 2 words (uniform) with a
    # non-32-aligned interior offset (the third starts at record 127)
    parts, at = [], 0
    for c in (64, 63, 64):
        parts.append((backends.get_backend("ref").create_index(
            records[at:at + c], keys), c))
        at += c
    assert len({p.shape[1] for p, _ in parts}) == 1
    stacked = batch.execute_many_segments(parts, preds, backend="ref",
                                          stack_uniform=True)
    per_seg = batch.execute_many_segments(parts, preds, backend="ref",
                                          stack_uniform=False)
    np.testing.assert_array_equal(np.asarray(stacked[0]),
                                  np.asarray(per_seg[0]))
    np.testing.assert_array_equal(np.asarray(stacked[1]),
                                  np.asarray(per_seg[1]))
    np.testing.assert_array_equal(np.asarray(stacked[0]),
                                  np.asarray(want_r))
    np.testing.assert_array_equal(np.asarray(stacked[1]),
                                  np.asarray(want_c))


def test_stored_index_query_many_matches_in_memory(tmp_path):
    """Acceptance: segment-parallel query_many over a spilled index ==
    in-memory results for the same predicate trees."""
    keys = _keys(m=16, hi=48)
    blocks = _blocks([33, 17, 50, 9], w=8, hi=48)
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(SegmentStore(str(tmp_path)), flush_records=30)
    for b in blocks:
        si.append(b)
    full = _rebuild(blocks, keys)
    # recover_index serves the FULL stream (segments + WAL tail)
    rec = recover_index(SegmentStore(str(tmp_path)), keys, backend="ref")
    np.testing.assert_array_equal(np.asarray(rec.packed), np.asarray(full))
    # open_index serves the durable prefix, segment-parallel
    stored = open_index(SegmentStore(str(tmp_path)))
    assert stored.num_segments >= 2
    nd = stored.num_records
    prefix = policy.extract_packed(full, 0, nd)
    rng = np.random.default_rng(9)
    preds = [_random_pred(rng, 16) for _ in range(12)]
    rows, counts = stored.query_many(preds, backend="ref")
    for i, p in enumerate(preds):
        r, c = execute(prefix, p, num_records=nd, backend="ref")
        np.testing.assert_array_equal(np.asarray(rows[i]), np.asarray(r))
        assert int(counts[i]) == int(c)


def test_stored_index_with_tail_serves_full_stream(tmp_path):
    keys = _keys(m=16, hi=48)
    blocks = _blocks([33, 17, 50, 9], w=8, hi=48)
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(SegmentStore(str(tmp_path)), flush_records=30)
    for b in blocks:
        si.append(b)
    full = _rebuild(blocks, keys)
    n = si.num_records
    store = SegmentStore(str(tmp_path))
    si2 = StreamingIndexer.restore(store, keys, backend="ref")
    tc = si2.num_records - store.durable_records
    tail = (policy.extract_packed(si2.index.packed, store.durable_records,
                                  tc), tc) if tc else None
    stored = open_index(store, tail=tail)
    assert stored.num_records == n
    rng = np.random.default_rng(10)
    preds = [_random_pred(rng, 16) for _ in range(12)]
    rows, counts = stored.query_many(preds, backend="ref")
    for i, p in enumerate(preds):
        r, c = execute(full, p, num_records=n, backend="ref")
        np.testing.assert_array_equal(np.asarray(rows[i]), np.asarray(r))
        assert int(counts[i]) == int(c)


def test_serve_step_accepts_stored_index(tmp_path):
    from repro.serve.step import make_bitmap_query_step
    keys = _keys(m=9)
    blocks = _blocks([20, 30])
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(SegmentStore(str(tmp_path)), flush_records=20)
    for b in blocks:
        si.append(b)
    si.spill()
    stored = open_index(SegmentStore(str(tmp_path)))
    step = make_bitmap_query_step(stored, backend="ref")
    preds = [key(0), key(1) & ~key(2)]
    rows, counts = step(preds)
    full = _rebuild(blocks, keys)
    for i, p in enumerate(preds):
        r, c = execute(full, p, num_records=50, backend="ref")
        np.testing.assert_array_equal(np.asarray(rows[i]), np.asarray(r))
        assert int(counts[i]) == int(c)


# ----------------------------------------------------- runtime integration
def _one_device_mesh():
    return jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def test_multicore_runtime_checkpoints_and_restarts(tmp_path):
    mesh = _one_device_mesh()
    keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    rt = MulticoreRuntime(mesh, backend="ref", store_dir=str(tmp_path),
                          flush_records=20)
    ticks = [jnp.asarray(RNG.integers(0, 256, (3, 16, 32), dtype=np.int32))
             for _ in range(3)]
    for t in ticks:
        res = rt.run_tick(t, keys, 0.01)
        # the per-core append loop must not clobber the active-core count
        assert res.active_cores == rt.scheduler.cores_needed(3, 0.01)
    want = backends.get_backend("ref").create_index(
        jnp.concatenate([t.reshape(-1, 32) for t in ticks], axis=0), keys)
    live = rt.core_indexes(keys)[0]
    np.testing.assert_array_equal(np.asarray(live.packed), np.asarray(want))
    # crash + restart: a new runtime over the same store_dir recovers
    rt2 = MulticoreRuntime(mesh, backend="ref", store_dir=str(tmp_path),
                           flush_records=20)
    rec = rt2.core_indexes(keys)[0]
    assert rec.num_records == 144
    np.testing.assert_array_equal(np.asarray(rec.packed), np.asarray(want))
    # explicit checkpoint makes everything durable (WAL tail -> segments)
    rt2.run_tick(ticks[0], keys, 0.01)
    rt2.checkpoint()
    store = SegmentStore(str(tmp_path / "core-0"))
    assert store.durable_records == 192
    assert store.replay_wal() == []


def test_runtime_measured_energy_calibration():
    mesh = _one_device_mesh()
    keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    rt = MulticoreRuntime(mesh, backend="ref", calibrate_energy=True)
    records = jnp.asarray(RNG.integers(0, 256, (2, 16, 32), dtype=np.int32))
    paper_bs = rt.scheduler.batch_seconds
    res = rt.run_tick(records, keys, 0.5)
    assert res.measured_seconds > 0
    assert res.measured_mbps > 0
    assert rt.measured_mbps > 0
    # the elastic model now runs on the measured device throughput
    assert rt.scheduler.batch_seconds != paper_bs
    assert rt.report.active_joules > 0
    assert rt.report.batches == 2
    # uncalibrated runtime still measures but keeps the paper clock
    rt2 = MulticoreRuntime(mesh, backend="ref")
    res2 = rt2.run_tick(records, keys, 0.5)
    assert res2.measured_seconds > 0
    assert rt2.scheduler.batch_seconds == paper_bs


# ------------------------------------------------------- data plane
def test_pipeline_store_backed_index_reloads(tmp_path):
    from repro.data.pipeline import BitmapIndexedDataset, DataConfig
    cfg = DataConfig(vocab_size=64, seq_len=8, docs_per_shard=64,
                     num_shards=2, num_attributes=32)
    w = (key(0) | key(1)) & ~key(20)
    ds = BitmapIndexedDataset(cfg, store_dir=str(tmp_path))
    ids = ds.select(0, where=w)
    ds2 = BitmapIndexedDataset(cfg, store_dir=str(tmp_path))   # reload
    np.testing.assert_array_equal(ds2.select(0, where=w), ids)
    _, idx_a = ds._ensure_shard(0)
    _, idx_b = ds2._ensure_shard(0)
    np.testing.assert_array_equal(np.asarray(idx_a.packed),
                                  np.asarray(idx_b.packed))
    # plain dataset agrees (the store never changes results)
    ds3 = BitmapIndexedDataset(cfg)
    np.testing.assert_array_equal(ds3.select(0, where=w), ids)


def test_pipeline_select_many_matches_select(tmp_path):
    from repro.data.pipeline import BitmapIndexedDataset, DataConfig
    cfg = DataConfig(vocab_size=64, seq_len=8, docs_per_shard=64,
                     num_shards=1, num_attributes=32)
    ds = BitmapIndexedDataset(cfg)
    preds = [key(3), (key(0) | key(4)) & ~key(17), key(9) & key(20)]
    many = ds.select_many(0, preds)
    for p, ids in zip(preds, many):
        np.testing.assert_array_equal(ds.select(0, where=p), ids)
    np.testing.assert_array_equal(ds.select(0, include=[9], exclude=[20]),
                                  ds.select_many(
                                      0, [key(9) & ~key(20)])[0])


# --------------------------------------------------- low-level primitives
def test_np_splice_matches_engine_splice():
    m = 6
    for start, count in [(0, 32), (13, 40), (31, 1), (45, 90)]:
        bits = RNG.integers(0, 2, (m, count)).astype(np.uint32)
        pad = -count % 32
        from repro.kernels import ref
        block = np.asarray(ref.pack_bits(
            jnp.asarray(np.pad(bits, ((0, 0), (0, pad))))))
        total_w = -(-(start + count) // 32)
        dst = np.zeros((m, total_w), np.uint32)
        np_splice(dst, start, block, count)
        want = np.zeros((m, total_w + block.shape[1] + 1), np.uint32)
        want = np.asarray(policy.splice_packed(
            jnp.asarray(want), jnp.int32(start),
            jnp.asarray(block)))[:, :total_w]
        np.testing.assert_array_equal(dst, want)


def test_extract_packed_inverts_splice():
    m = 4
    for start, count in [(0, 7), (29, 64), (32, 32), (45, 13)]:
        total = start + count + 11
        bits = RNG.integers(0, 2, (m, total)).astype(np.uint32)
        from repro.kernels import ref
        pad = -total % 32
        packed = jnp.asarray(np.asarray(ref.pack_bits(
            jnp.asarray(np.pad(bits, ((0, 0), (0, pad)))))))
        got = policy.extract_packed(packed, start, count)
        dense = np.asarray(ref.unpack_bits(got, count))
        np.testing.assert_array_equal(dense, bits[:, start:start + count])
        # tail bits past count are zero
        full = np.asarray(ref.unpack_bits(got, got.shape[1] * 32))
        assert full[:, count:].sum() == 0
