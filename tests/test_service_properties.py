"""Hypothesis properties for the micro-batch scheduler: over randomized
caller counts, per-caller query lists, and scheduler knobs —

  * every submitted query is answered exactly once (no drops, no
    duplicates, a strictly increasing global resolve sequence);
  * each caller's futures resolve in its submission order;
  * every result is bit-identical to the sequential serve_step path.

Skips cleanly when hypothesis is not installed.
"""
import threading

import numpy as np
import pytest

import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.db import BitmapDB, Column, Schema  # noqa: E402
from repro.engine.planner import key  # noqa: E402

M = 12


@pytest.fixture(scope="module")
def db():
    schema = Schema([Column.categorical("a", list(range(M // 2))),
                     Column.categorical("b", list(range(M // 2, M)))])
    rng = np.random.default_rng(0)
    enc = np.stack([rng.integers(0, M // 2, 512, dtype=np.int32),
                    rng.integers(M // 2, M, 512, dtype=np.int32)], axis=1)
    d = BitmapDB(schema, backend="ref")
    d.append_encoded(enc)
    return d


def _pred(spec: tuple[int, int, int]):
    kind, i, j = spec
    i, j = i % M, j % M
    if kind % 3 == 0:
        return key(i)
    if kind % 3 == 1:
        return key(i) & ~key(j)
    return key(i) | key(j)


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.function_scoped_fixture])
@given(lanes=st.lists(
    st.lists(st.tuples(st.integers(0, 2), st.integers(0, M - 1),
                       st.integers(0, M - 1)), min_size=1, max_size=12),
    min_size=1, max_size=4),
    max_batch=st.integers(1, 16),
    max_delay_ms=st.sampled_from([0.0, 0.5, 2.0]))
def test_scheduler_batching_invariants(db, lanes, max_batch, max_delay_ms):
    queries = [[_pred(s) for s in lane] for lane in lanes]
    step = db.serve_step()
    want = {}
    for lane in queries:
        for q in lane:
            if q not in want:
                want[q] = step([q])
    svc = db.serve(max_batch=max_batch, max_delay_ms=max_delay_ms,
                   idle_after_ms=10_000.0)
    try:
        outs = [[] for _ in lanes]

        def caller(t):
            for q in queries[t]:
                outs[t].append(svc.submit(q))

        threads = [threading.Thread(target=caller, args=(t,))
                   for t in range(len(lanes))]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert svc.drain(timeout=60)
        total = sum(len(lane) for lane in queries)
        seqs = sorted(f.resolve_seq for lane in outs for f in lane)
        # exactly once: the global resolve sequence is a permutation
        assert seqs == list(range(1, total + 1))
        for t, lane in enumerate(outs):
            per = [f.resolve_seq for f in lane]
            assert per == sorted(per), "per-caller order violated"
            for q, f in zip(queries[t], lane):
                rows, counts = want[q]
                rr, cc = f.result()
                assert bool(jnp.all(rows[0] == rr))
                assert int(counts[0]) == int(cc)
        assert svc.metrics().served == total
    finally:
        svc.close()
