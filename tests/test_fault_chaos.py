"""Chaos harness: seeded fault schedules against the full
ingest + serve + maintenance stack.

The fabric (:mod:`repro.fault`) injects torn writes, dropped fsyncs,
ENOSPC, EIO, read-side bit rot, and transient dispatch/task errors
through the seams the store and serving layers carry; these tests assert
the system's survival contract:

  * **bit-identical results** — a workload run under a seeded fault
    schedule returns exactly the clean run's bits (retries, fallback
    backends, and repairs are invisible in the data);
  * **zero acknowledged-write loss** — an append that returned is
    recoverable across any injected crash instant (kill the maintenance
    worker, drop the session, reopen from disk);
  * **corruption is survived, not served** — a CRC-failing segment is
    quarantined, repaired from the live in-memory replica, and no
    in-flight query ever sees a wrong bit.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.db import BitmapDB
from repro.db.session import open_db
from repro.engine import planner
from repro.fault import FaultInjector, FaultPlan, FaultSpec, InjectedFault
from repro.serve.resilience import CircuitBreaker, RetryPolicy, is_transient
from repro.serve.service import DeadlineExceeded, ServiceOverloaded
from repro.store import SegmentStore
from repro.store import format as fmt

key = planner.key

M = 12                    # key rows
BLOCK = 96                # records per appended block
WORDS = 3                 # key words per record
APPEND_RETRIES = 12       # harness-level: an append that fails is retried;
                          # only a RETURNED append counts as acknowledged


def _blocks(seed, n_blocks=8):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, M, (BLOCK, WORDS), dtype=np.int32)
            for _ in range(n_blocks)]


def _append_acked(db, block):
    """Append with harness-level retries; returns True iff acknowledged.
    A failed attempt must leave the index exactly where it was (the WAL
    logs before the in-memory splice) — asserted here on every retry."""
    before = db.num_records
    for _ in range(APPEND_RETRIES):
        try:
            db.append_encoded(block)
            return True
        except OSError:
            assert db.num_records == before, \
                "failed append mutated the index"
    return False


def _run_workload(root, plan, *, data_seed=7):
    """Ingest + serve + maintenance under an (optional) fault schedule.
    Returns (per-block count matrix, final counts, injector-or-None)."""
    db = BitmapDB(num_keys=M, path=root, spill_records=256)
    svc = db.serve(background=True, max_delay_ms=1.0, wave_retries=3,
                   breaker_cooldown_s=0.05, idle_after_ms=50.0)
    inj = FaultInjector(plan).install() if plan is not None else None
    try:
        waves = []
        for block in _blocks(data_seed):
            assert _append_acked(db, block)
            futs = [svc.submit(key(i)) for i in range(M)]
            waves.append([f.count for f in futs])
        # a concurrent storm over the settled index: per-caller ordering
        # and identical bits regardless of how waves coalesce
        storm_counts = [None] * 4

        def caller(slot):
            futs = [svc.submit(key(i)) for i in range(M)]
            seqs = [f.resolve_seq for f in futs]
            assert seqs == sorted(seqs), "futures resolved out of order"
            storm_counts[slot] = [f.count for f in futs]

        threads = [threading.Thread(target=caller, args=(s,))
                   for s in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for got in storm_counts:
            assert got == waves[-1]
    finally:
        if inj is not None:
            inj.uninstall()
    assert svc._maint_ex.flush(30)
    health = svc.health()
    svc.close()
    return waves, health, inj


@pytest.mark.parametrize("fault_seed", [11, 23, 47])
def test_chaos_bit_identical_under_faults(tmp_path, fault_seed):
    """A seeded randomized fault schedule (every kind, every site) does
    not change a single served bit, and the recovered on-disk state is
    bit-identical to the clean run's."""
    clean_root = str(tmp_path / "clean")
    chaos_root = str(tmp_path / "chaos")
    plan = FaultPlan.random(fault_seed, profile="all")

    clean_waves, _, _ = _run_workload(clean_root, None)
    chaos_waves, health, inj = _run_workload(chaos_root, plan)

    assert chaos_waves == clean_waves, \
        f"fault schedule changed served bits: {inj.report_json()}"

    # recovered state: segment/WAL split may differ (fault-delayed
    # spills leave a longer WAL tail) but segments + replay must
    # reconstruct the identical record stream
    a = open_db(clean_root, num_keys=M)
    b = open_db(chaos_root, num_keys=M)
    try:
        assert a.num_records == b.num_records
        ra = a.query_many([key(i) for i in range(M)])
        rb = b.query_many([key(i) for i in range(M)])
        for i in range(M):
            assert ra[i].count == rb[i].count
            np.testing.assert_array_equal(np.asarray(ra[i].rows),
                                          np.asarray(rb[i].rows))
    finally:
        a.store.close()
        b.store.close()
    # nothing left degraded once the schedule drained
    assert health["store"]["quarantined"] == {}


@pytest.mark.parametrize("fault_seed", [5, 31])
def test_chaos_crash_instant_no_acked_loss(tmp_path, fault_seed):
    """Kill the maintenance worker mid-schedule, drop the session cold
    (no close, no flush), reopen from disk: every acknowledged append is
    there, bit for bit; every unacknowledged one is not."""
    root = str(tmp_path / "store")
    plan = FaultPlan.random(fault_seed, profile="storage", n_faults=8)
    db = BitmapDB(num_keys=M, path=root, spill_records=256)
    svc = db.serve(background=True, max_delay_ms=1.0)

    acked = []
    with FaultInjector(plan):
        for bi, block in enumerate(_blocks(fault_seed, n_blocks=6)):
            if _append_acked(db, block):
                acked.append(block)
            if bi == 3:                 # crash instant: mid-ingest
                break
        svc._maint_ex.kill()            # maintenance dies with the process
    # the session is dropped WITHOUT close(): no final spill, no WAL
    # close — recovery has only what was durable at the crash instant
    del svc, db

    db2 = open_db(root, num_keys=M)
    try:
        want = (np.concatenate(acked, axis=0) if acked
                else np.zeros((0, WORDS), np.int32))
        assert db2.num_records == want.shape[0], \
            "acknowledged appends lost (or phantom records recovered)"
        # content check: recovered counts == counts of a fresh index
        # built from exactly the acknowledged blocks
        ref = BitmapDB(num_keys=M)
        if want.shape[0]:
            ref.append_encoded(want)
        for i in range(M):
            assert db2.query(key(i)).count == ref.query(key(i)).count
    finally:
        db2.store.close()


def test_chaos_crc_quarantine_repair_in_flight(tmp_path):
    """Persistent on-disk corruption: the segment is quarantined and
    repaired from the in-memory replica by the standby scrubber while
    queries keep serving correct bits throughout; health tells the
    story."""
    root = str(tmp_path / "store")
    db = BitmapDB(num_keys=M, path=root, spill_records=256)
    svc = db.serve(background=True, max_delay_ms=1.0, idle_after_ms=5000.0)
    for block in _blocks(3, n_blocks=6):
        db.append_encoded(block)
    assert svc._maint_ex.flush(30)
    assert len(db.store.segments) >= 1

    clean = [svc.submit(key(i)).count for i in range(M)]
    meta = db.store.segments[0]
    path = db.store.segment_path(meta)
    raw = bytearray(open(path, "rb").read())
    raw[-4] ^= 0x08                      # rot one payload bit on disk
    open(path, "wb").write(bytes(raw))
    with pytest.raises(fmt.CorruptFileError):
        db.store.read_segment(meta)

    # quarantine first (dry of a replica), queries keep serving
    db.store.quarantine(meta, "test rot")
    assert db.store.quarantined == {meta.file: "test rot"}
    assert svc.health()["degraded"]
    mid = [svc.submit(key(i)).count for i in range(M)]
    assert mid == clean, "in-flight queries saw quarantined corruption"

    # standby entry schedules the scrub; the live index is the replica
    svc.standby()
    assert svc._maint_ex.flush(30)
    h = svc.health()
    assert h["store"]["quarantined"] == {}
    assert h["store"]["repairs"] >= 1
    assert not h["degraded"]
    db.store.read_segment(meta)          # the file itself is healed
    post = [svc.submit(key(i)).count for i in range(M)]
    assert post == clean
    svc.close()


def test_enospc_mid_prepare_clean_abort(tmp_path):
    """Satellite: ENOSPC inside ``prepare_segment`` aborts cleanly —
    flush lock released, no orphan ``.tmp`` that ``gc()`` misses, and
    the very next spill succeeds."""
    root = str(tmp_path / "store")
    store = SegmentStore(root, auto_compact=False)
    rng = np.random.default_rng(0)
    packed = rng.integers(0, 2**32, (M, 2), dtype=np.uint32)

    plan = FaultPlan((FaultSpec("format.write", "enospc",
                                path_substr="seg-"),))
    with FaultInjector(plan) as inj:
        with pytest.raises(OSError):
            store.write_segment(packed, 64, 0)
        assert inj.fired("format.write")
    assert store.segments == ()
    # ENOSPC fires before any byte lands: nothing for gc, nothing stray
    assert not [f for f in os.listdir(root) if f.endswith(".tmp")]
    assert not store.gc()   # GCStats is falsy when nothing was removed

    # flush lock must be free: the next spill goes through immediately
    meta = store.write_segment(packed, 64, 0)
    assert store.durable_records == 64
    np.testing.assert_array_equal(store.read_segment(meta), packed)
    store.close()


def test_torn_segment_write_debris_collected(tmp_path):
    """Satellite: a TORN segment write (crash mid-write) leaves exactly
    one ``.tmp`` debris file; it is invisible under the final name,
    ``gc()`` collects it, and the next spill succeeds."""
    root = str(tmp_path / "store")
    store = SegmentStore(root, auto_compact=False)
    rng = np.random.default_rng(1)
    packed = rng.integers(0, 2**32, (M, 2), dtype=np.uint32)

    plan = FaultPlan((FaultSpec("format.write", "torn",
                                path_substr="seg-", torn_frac=0.4),))
    with FaultInjector(plan):
        with pytest.raises(OSError):
            store.write_segment(packed, 64, 0)
    debris = [f for f in os.listdir(root) if f.endswith(".tmp")]
    assert len(debris) == 1 and debris[0].startswith("seg-")
    removed = store.gc()
    assert debris[0] in removed
    assert not [f for f in os.listdir(root) if f.endswith(".tmp")]

    meta = store.write_segment(packed, 64, 0)
    np.testing.assert_array_equal(store.read_segment(meta), packed)
    store.close()


def test_enospc_mid_commit_manifest_swap(tmp_path):
    """Satellite: ENOSPC during the COMMIT's manifest write fails the
    two-phase op without losing the manifest, the WAL, or the lock; the
    orphan segment file becomes ordinary gc fodder and the next spill
    succeeds."""
    root = str(tmp_path / "store")
    store = SegmentStore(root, auto_compact=False)
    rng = np.random.default_rng(2)
    packed = rng.integers(0, 2**32, (M, 2), dtype=np.uint32)
    store.log_block(rng.integers(0, M, (64, WORDS), dtype=np.int32), 0)

    plan = FaultPlan((FaultSpec("format.write", "enospc",
                                path_substr="MANIFEST"),))
    with FaultInjector(plan):
        with pytest.raises(OSError):
            store.write_segment(packed, 64, 0)
    assert store.segments == ()          # swap never happened
    orphans = [f for f in os.listdir(root) if f.startswith("seg-")]
    assert orphans                       # prepared file is an orphan now
    assert orphans[0] in store.gc()

    meta = store.write_segment(packed, 64, 0)
    assert store.durable_records == 64
    assert meta.file not in store.gc()   # live segments are never garbage
    store.close()


def test_wal_append_enospc_not_acknowledged(tmp_path):
    """An ENOSPC'd WAL append is NOT acknowledged and NOT recovered —
    but the appends around it all are (the handle rewinds past nothing)."""
    root = str(tmp_path / "store")
    db = BitmapDB(num_keys=M, path=root, spill_records=None)
    b1, b2, b3 = _blocks(9, n_blocks=3)
    db.append_encoded(b1)
    plan = FaultPlan((FaultSpec("log.append", "enospc",
                                path_substr="wal-"),))
    with FaultInjector(plan):
        with pytest.raises(OSError):
            db.append_encoded(b2)
    assert db.num_records == BLOCK       # b2 not acked, not spliced
    db.append_encoded(b3)
    db.store.close()

    db2 = open_db(root, num_keys=M)
    try:
        assert db2.num_records == 2 * BLOCK
        ref = BitmapDB(num_keys=M)
        ref.append_encoded(np.concatenate([b1, b3], axis=0))
        for i in range(M):
            assert db2.query(key(i)).count == ref.query(key(i)).count
    finally:
        db2.store.close()


def test_breaker_trips_falls_back_recovers():
    """Dispatch faults on the preferred backend: retried, then confirmed
    against the fallback, breaker trips, degraded waves serve identical
    bits, cooldown probe closes it again."""
    db = BitmapDB(num_keys=M, backend="bulk")
    rng = np.random.default_rng(4)
    db.append_encoded(rng.integers(0, M, (300, WORDS), dtype=np.int32))
    svc = db.serve(background=True, max_delay_ms=1.0, wave_retries=1,
                   breaker_threshold=2, breaker_cooldown_s=0.05)
    qs = [key(i) for i in range(M)]
    clean = [svc.submit(q).count for q in qs]

    plan = FaultPlan(tuple(
        FaultSpec("engine.dispatch", "dispatch_error", occurrence=i,
                  match=(("backend", "bulk"),)) for i in range(1, 60)))
    with FaultInjector(plan):
        degraded = [svc.submit(q).count for q in qs]
        h = svc.health()
        assert degraded == clean, "fallback wave changed bits"
        assert h["breaker"]["trips"] >= 1
        assert h["degraded_waves"] >= 1 and h["wave_retries"] >= 1
        assert h["degraded"]

    time.sleep(0.1)                      # past the cooldown
    post = [svc.submit(q).count for q in qs]
    assert post == clean
    h = svc.health()
    assert h["breaker"]["state"] == "closed" and not h["degraded"]
    m = svc.metrics()
    assert m.health["breaker"]["trips"] == h["breaker"]["trips"]
    svc.close()


def test_deadline_budget_rejects_late_queries():
    db = BitmapDB(num_keys=M)
    rng = np.random.default_rng(5)
    db.append_encoded(rng.integers(0, M, (100, WORDS), dtype=np.int32))
    svc = db.serve(background=False)     # one-shot: we control dispatch
    doomed = svc.submit(key(0), deadline_ms=0.01)
    fine = svc.submit(key(1))
    time.sleep(0.005)
    svc.drain()
    with pytest.raises(DeadlineExceeded):
        doomed.result()
    assert doomed.resolve_seq >= 0       # sequenced with the wave
    assert fine.count >= 0               # wave-mates are untouched
    assert svc.health()["deadline_rejected"] == 1
    svc.close()


def test_overload_error_carries_admission_fields():
    db = BitmapDB(num_keys=M)
    db.append_encoded(np.zeros((32, WORDS), np.int32))
    svc = db.serve(background=True, max_queue=1, admission="reject",
                   max_delay_ms=500.0)
    try:
        with pytest.raises(ServiceOverloaded) as ei:
            for _ in range(200):
                svc.submit(key(0))
        e = ei.value
        assert e.limit == 1 and e.admission == "reject"
        assert e.queue_depth >= 1
        assert "limit=1" in str(e) and "admission='reject'" in str(e)
    finally:
        svc.close()


def test_maintenance_failure_accounting_in_metrics(tmp_path):
    """Satellite: per-task failure counts + last exception flow from the
    executor through ``service.metrics()``."""
    root = str(tmp_path / "store")
    db = BitmapDB(num_keys=M, path=root, spill_records=256)
    svc = db.serve(background=True, idle_after_ms=5000.0)
    db.append_encoded(_blocks(6, n_blocks=1)[0])

    plan = FaultPlan((FaultSpec("maintenance.task", "task_error",
                                count=10, match=(("kind", "gc"),)),))
    with FaultInjector(plan):
        svc._maint.schedule_gc()
        assert svc._maint_ex.flush(30)
    st = svc._maint_ex.stats()
    assert st["failures"]["gc"] == 1     # retried, then finally failed
    assert st["retries"]["gc"] >= 1
    assert "InjectedFault" in st["last_failure"]["gc"]
    assert isinstance(st["errors"], int)

    m = svc.metrics()
    assert m.maintenance["failures"]["gc"] == 1
    assert m.health["maintenance_failures"]["failures"]["gc"] == 1
    # transient blips do NOT land in failures
    plan = FaultPlan((FaultSpec("maintenance.task", "task_error",
                                match=(("kind", "compact"),)),))
    with FaultInjector(plan):
        svc._maint.schedule_compact()
        assert svc._maint_ex.flush(30)
    st = svc._maint_ex.stats()
    assert st["failures"].get("compact", 0) == 0
    assert st["retries"]["compact"] >= 1
    svc.close()


# --------------------------------------------------------- fabric unit tests
def test_fault_plan_seeded_and_serializable():
    p1 = FaultPlan.random(99)
    p2 = FaultPlan.random(99)
    p3 = FaultPlan.random(100)
    assert p1 == p2 and p1 != p3
    assert FaultPlan.from_json(p1.to_json()) == p1


def test_injector_occurrence_determinism(tmp_path):
    """Same plan + same call sequence = same fired events (the schedule
    is a function of the seed, not the wall clock)."""
    plan = FaultPlan((FaultSpec("format.write", "enospc", occurrence=2),))

    def run():
        inj = FaultInjector(plan)
        with inj:
            for i in range(4):
                try:
                    fmt.write_bytes_atomic(
                        str(tmp_path / f"f{i}"), b"x" * 64)
                except OSError:
                    pass
        return [(e["site"], e["kind"], e["occurrence"])
                for e in inj.events]

    assert run() == run() == [("format.write", "enospc", 2)]


def test_retry_policy_deterministic_jitter():
    p = RetryPolicy(max_attempts=4, base_delay_s=0.01, jitter=0.5)
    assert list(p.delays(3)) == list(p.delays(3))
    assert list(p.delays(3)) != list(p.delays(4))
    calls = []
    with pytest.raises(InjectedFault):
        p.call(lambda: (_ for _ in ()).throw(InjectedFault("x")),
               seed=1, retryable=is_transient,
               on_retry=lambda a, e: calls.append(a),
               sleep=lambda s: None)
    assert calls == [1, 2, 3]            # 1 try + 3 retries, then raise


def test_circuit_breaker_state_machine():
    t = [0.0]
    br = CircuitBreaker(failure_threshold=2, cooldown_s=1.0,
                        clock=lambda: t[0])
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed"          # below threshold
    br.record_failure()
    assert br.state == "open" and br.trips == 1
    assert not br.allow()                # cooling down
    t[0] = 1.5
    assert br.allow()                    # THE probe slot
    assert br.state == "half-open"
    assert not br.allow()                # only one probe
    br.record_failure()                  # probe failed -> re-open
    assert br.state == "open" and br.trips == 2
    t[0] = 3.0
    assert br.allow()
    br.record_success()                  # probe succeeded -> closed
    assert br.state == "closed" and br.allow()
    snap = br.snapshot()
    assert snap["trips"] == 2 and snap["failures"] == 3
