"""Hypothesis property tests for the store substrate: serialization
round-trips and the WAL-replay recovery invariant over arbitrary block
streams.  Skips entirely when hypothesis is absent (same policy as
tests/test_kernels_properties.py)."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.engine import backends, policy  # noqa: E402
from repro.engine.runtime import StreamingIndexer  # noqa: E402
from repro.store import SegmentStore  # noqa: E402
from repro.store import format as fmt  # noqa: E402

_DTYPES = [np.uint32, np.int32, np.float32, np.uint8]


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(0, 2 ** 31 - 1))
def test_array_file_roundtrip_property(n_arrays, seed, tmp_path_factory):
    """Property: write_array_file . read_array_file is the identity on
    arbitrary named array sets (dtype, shape, and bytes all survive)."""
    rng = np.random.default_rng(seed)
    arrays = {}
    for i in range(n_arrays):
        dt = _DTYPES[int(rng.integers(0, len(_DTYPES)))]
        shape = tuple(int(s) for s in rng.integers(0, 9, rng.integers(1, 4)))
        arrays[f"a{i}"] = (rng.integers(0, 255, shape).astype(dt)
                           if dt != np.float32
                           else rng.random(shape, dtype=np.float32))
    path = str(tmp_path_factory.mktemp("af") / "x.seg")
    meta = {"seed": int(seed)}
    fmt.write_array_file(path, arrays, meta=meta)
    out, got_meta = fmt.read_array_file(path)
    assert got_meta == meta
    assert set(out) == set(arrays)
    for k, v in arrays.items():
        assert out[k].dtype == v.dtype and out[k].shape == v.shape
        np.testing.assert_array_equal(out[k], v)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(1, 60), min_size=1, max_size=6),
       st.integers(1, 80), st.integers(0, 2 ** 31 - 1))
def test_spill_recover_roundtrip_property(block_sizes, flush, seed,
                                          tmp_path_factory):
    """Property: for ANY block-size stream and ANY flush threshold, a
    recovered index (segments + WAL replay) is word-for-word identical to
    the never-spilled in-memory index."""
    rng = np.random.default_rng(seed)
    m, w = 7, 3
    keys = jnp.asarray(rng.integers(0, 32, (m,), dtype=np.int32))
    root = str(tmp_path_factory.mktemp("st"))
    si = StreamingIndexer(keys, backend="ref")
    si.attach_store(SegmentStore(root), flush_records=flush)
    blocks = []
    for n in block_sizes:
        blk = jnp.asarray(rng.integers(0, 32, (n, w), dtype=np.int32))
        blocks.append(blk)
        si.append(blk)
    want = backends.get_backend("ref").create_index(
        jnp.concatenate(blocks, axis=0), keys)
    si2 = StreamingIndexer.restore(SegmentStore(root), keys, backend="ref")
    assert si2.num_records == sum(block_sizes)
    np.testing.assert_array_equal(np.asarray(si2.index.packed),
                                  np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 130), st.integers(1, 100), st.integers(0, 2 ** 31 - 1))
def test_extract_packed_roundtrip_property(start, count, seed):
    """Property: extract_packed reads back exactly the bits splice_packed
    wrote, at any unaligned offset, with a zeroed tail."""
    from repro.kernels import ref
    rng = np.random.default_rng(seed)
    m = 3
    total = start + count + int(rng.integers(0, 40))
    bits = rng.integers(0, 2, (m, total)).astype(np.uint32)
    pad = -total % 32
    packed = jnp.asarray(ref.pack_bits(
        jnp.asarray(np.pad(bits, ((0, 0), (0, pad))))))
    got = policy.extract_packed(packed, start, count)
    assert got.shape == (m, -(-count // 32))
    dense = np.asarray(ref.unpack_bits(got, count))
    np.testing.assert_array_equal(dense, bits[:, start:start + count])
    tail = np.asarray(ref.unpack_bits(got, got.shape[1] * 32))
    assert tail[:, count:].sum() == 0
