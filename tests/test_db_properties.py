"""Hypothesis property tests for the `repro.db` facade (skipped when
hypothesis is not installed — the randomized seeded equivalents in
tests/test_db.py always run).

Properties:
  * expr -> Pred -> plan -> packed execution == the NumPy reference
    evaluator over encoded records, for arbitrary schemas/data/expressions;
  * Schema JSON round-trips preserve the key-row mapping;
  * binned key_of/keys_between agree pointwise.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.db import BitmapDB, Column, Schema, col  # noqa: E402
from repro.db import expr as expr_mod  # noqa: E402
from repro.engine import planner  # noqa: E402

from test_db import _ref_eval  # noqa: E402


@st.composite
def schemas(draw):
    cols = []
    ncols = draw(st.integers(1, 3))
    for i in range(ncols):
        if draw(st.booleans()):
            card = draw(st.integers(1, 5))
            cols.append(Column.categorical(f"c{i}", list(range(card))))
        else:
            edges = sorted(draw(st.sets(
                st.integers(-40, 40), min_size=2, max_size=6)))
            cols.append(Column.binned(f"c{i}", [float(e) for e in edges]))
    return Schema(cols)


def _rows_for(draw, schema, n):
    rows = {}
    for c in schema.columns:
        if c.kind == "categorical":
            rows[c.name] = [c.values[draw(st.integers(0, len(c.values) - 1))]
                            for _ in range(n)]
        else:
            lo, hi = c.edges[0], c.edges[-1]
            rows[c.name] = [float(draw(st.floats(lo, hi, allow_nan=False)))
                            for _ in range(n)]
    return rows


def _expr_for(draw, schema, depth):
    if depth == 0 or draw(st.booleans()):
        c = schema.columns[draw(st.integers(0, len(schema.columns) - 1))]
        if c.kind == "categorical":
            choice = draw(st.integers(0, 2))
            if choice == 0:
                v = c.values[draw(st.integers(0, len(c.values) - 1))]
                return col(c.name) == v
            if choice == 1:
                picks = draw(st.sets(st.integers(0, len(c.values) - 1),
                                     max_size=len(c.values)))
                return col(c.name).isin([c.values[i] for i in sorted(picks)])
            return planner.key(draw(st.integers(0, schema.num_keys - 1)))
        lo, hi = c.edges[0] - 5, c.edges[-1] + 5
        a = draw(st.floats(lo, hi, allow_nan=False))
        b = draw(st.floats(lo, hi, allow_nan=False))
        return col(c.name).between(min(a, b), max(a, b))
    left = _expr_for(draw, schema, depth - 1)
    right = _expr_for(draw, schema, depth - 1)
    op = draw(st.integers(0, 2))
    out = left & right if op == 0 else left | right if op == 1 else ~left
    return out


@st.composite
def db_cases(draw):
    schema = draw(schemas())
    n = draw(st.integers(1, 60))
    rows = _rows_for(draw, schema, n)
    exprs = [_expr_for(draw, schema, draw(st.integers(0, 2)))
             for _ in range(draw(st.integers(1, 5)))]
    return schema, rows, exprs


@settings(max_examples=40, deadline=None)
@given(db_cases())
def test_expr_plan_execute_round_trip(case):
    schema, rows, exprs = case
    n = len(next(iter(rows.values())))
    db = BitmapDB(schema, backend="ref")
    db.ingest(rows)
    enc = schema.encode(rows)
    for q, res in zip(exprs, db.query_many(exprs)):
        want = np.flatnonzero(_ref_eval(q, enc, schema))
        np.testing.assert_array_equal(res.ids, want)
        assert res.count == len(want) <= n


@settings(max_examples=60, deadline=None)
@given(schemas())
def test_schema_json_round_trip(schema):
    s2 = Schema.from_json(schema.to_json())
    assert s2 == schema
    assert s2.num_keys == schema.num_keys
    for c in schema.columns:
        if c.kind == "categorical":
            for v in c.values:
                assert s2.key_of(c.name, v) == schema.key_of(c.name, v)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_binned_key_of_consistent_with_keys_between(data):
    edges = sorted(data.draw(st.sets(st.integers(-30, 30),
                                     min_size=2, max_size=8)))
    c = Schema([Column.binned("t", [float(e) for e in edges])])["t"]
    v = data.draw(st.floats(float(edges[0]), float(edges[-1]),
                            allow_nan=False))
    k = c.key_of(v)
    # the point interval [v, v] must select exactly bins that can hold v
    ks = c.keys_between(v, v)
    assert k in ks
    assert len(ks) <= 2          # v on an interior edge touches two bins
