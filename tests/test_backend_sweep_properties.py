"""Hypothesis property tests for the backend sweep (skipped when
hypothesis is not installed — the fixed-seed differential sweep in
tests/test_backend_sweep.py always runs).

Property: for ARBITRARY predicate trees, record counts (32-aligned or
not), and index contents, every registered execution backend — ``ref``,
``bulk``, ``pallas`` — and the cost model's ``auto`` produce bit-identical
result rows and counts from ``engine.batch.execute_many``; and the bulk
sweep equals a dense NumPy evaluation of the same boolean algebra.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.engine import batch as engine_batch  # noqa: E402
from repro.engine import planner, policy  # noqa: E402
from repro.engine.planner import And, Not, Or, key  # noqa: E402

M = 8           # keys: tiny on purpose — collisions stress dedup paths


def preds(depth=3):
    leaf = st.integers(0, M - 1).map(key)
    return st.recursive(
        leaf,
        lambda kids: st.one_of(
            st.tuples(kids, kids).map(lambda ab: And(ab)),
            st.tuples(kids, kids).map(lambda ab: Or(ab)),
            kids.map(lambda c: Not(c)),
        ),
        max_leaves=6)


def _dense_eval(pred, bits: np.ndarray) -> np.ndarray:
    if isinstance(pred, planner.Key):
        return bits[pred.index]
    if isinstance(pred, Not):
        return ~_dense_eval(pred.child, bits)
    if isinstance(pred, And):
        out = np.ones(bits.shape[1], bool)
        for c in pred.children:
            out &= _dense_eval(c, bits)
        return out
    out = np.zeros(bits.shape[1], bool)
    for c in pred.children:
        out |= _dense_eval(c, bits)
    return out


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2 ** 31), preds(), preds())
def test_all_backends_match_dense_eval(n, seed, p1, p2):
    rng = np.random.default_rng(seed)
    bits = rng.random((M, n)) < 0.4               # dense truth table
    packed = np.zeros((M, policy.num_words(n)), np.uint32)
    for i in range(n):                            # LSB-first packing
        packed[:, i // 32] |= bits[:, i].astype(np.uint32) << (i % 32)
    packed = jnp.asarray(packed)
    want = np.stack([_dense_eval(p, bits) for p in (p1, p2)])
    outs = {name: engine_batch.execute_many(packed, [p1, p2],
                                            num_records=n, backend=name)
            for name in ("ref", "bulk", "pallas", "auto")}
    r0, c0 = outs["ref"]
    got = np.zeros((2, n), bool)
    rows = np.asarray(r0)
    for i in range(n):
        got[:, i] = (rows[:, i // 32] >> (i % 32)) & 1
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(np.asarray(c0), want.sum(axis=1))
    for name, (r, c) in outs.items():
        np.testing.assert_array_equal(np.asarray(r), np.asarray(r0),
                                      err_msg=f"rows differ: {name}")
        np.testing.assert_array_equal(np.asarray(c), np.asarray(c0),
                                      err_msg=f"counts differ: {name}")
