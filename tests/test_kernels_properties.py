"""Hypothesis property tests on the bit-level kernel invariants.

Kept separate from tests/test_kernels.py so the differential (pallas vs
oracle) sweeps stay runnable when hypothesis is not installed — this whole
module skips instead."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.integers(0, 2 ** 32 - 1))
def test_bit_transpose_involution(rw, cw, seed):
    """Property: transpose(transpose(X)) == X for 32-aligned matrices."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 2 ** 32, (32 * rw, cw), dtype=np.uint32))
    tt = ops.transpose(ops.transpose(x))
    np.testing.assert_array_equal(np.asarray(tt), np.asarray(x))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_transpose_moves_bits(seed):
    """Property: bit (r, c) lands at (c, r)."""
    rng = np.random.default_rng(seed)
    r, c = int(rng.integers(0, 64)), int(rng.integers(0, 64))
    x = np.zeros((64, 2), np.uint32)
    x[r, c // 32] = np.uint32(1) << (c % 32)
    y = np.asarray(ops.transpose(jnp.asarray(x)))
    assert (y[c, r // 32] >> np.uint32(r % 32)) & 1 == 1
    assert y.sum() == y[c, r // 32]      # exactly one bit set


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
def test_query_matches_set_semantics(k, nw, seed):
    """Property: the query result equals python-set evaluation."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, 2 ** 32, (k, nw), dtype=np.uint32)
    inv = rng.integers(0, 2, (k,), dtype=np.int32)
    res, cnt = ops.query(jnp.asarray(rows), jnp.asarray(inv))
    n = nw * 32
    want = np.ones(n, bool)
    dense = np.asarray(ref.unpack_bits(jnp.asarray(rows), n)).astype(bool)
    for i in range(k):
        want &= ~dense[i] if inv[i] else dense[i]
    got = np.asarray(ref.unpack_bits(res[None], n))[0].astype(bool)
    np.testing.assert_array_equal(got, want)
    assert int(cnt) == int(want.sum())


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(1, 100), min_size=1, max_size=6),
       st.integers(0, 2 ** 31 - 1))
def test_append_packed_matches_rebuild_property(block_sizes, seed):
    """Property: splicing blocks of arbitrary sizes (including blocks that
    cross several 32-bit word boundaries, blocks larger than the whole
    existing index, and repeated non-aligned appends) is bit-identical to
    rebuilding the index from all records at once, after EVERY append."""
    from repro.engine import backends
    from repro.engine.runtime import append_packed

    rng = np.random.default_rng(seed)
    m, w = 9, 4
    keys = jnp.asarray(rng.integers(0, 32, (m,), dtype=np.int32))
    be = backends.get_backend("ref")
    packed = jnp.zeros((m, 0), jnp.uint32)
    n = 0
    all_records = []
    for size in block_sizes:
        rec = jnp.asarray(rng.integers(0, 32, (size, w), dtype=np.int32))
        packed = append_packed(packed, n, be.create_index(rec, keys), size)
        n += size
        all_records.append(rec)
        rebuilt = be.create_index(jnp.concatenate(all_records, axis=0), keys)
        np.testing.assert_array_equal(np.asarray(packed), np.asarray(rebuilt))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 200), st.integers(1, 150), st.integers(0, 2 ** 31 - 1))
def test_append_packed_preserves_both_sides(n_a, n_b, seed):
    """Property: after a splice at any (unaligned) offset, the original
    bits and the appended bits both read back exactly."""
    from repro.engine.runtime import append_packed

    rng = np.random.default_rng(seed)
    m = 3
    a_bits = rng.integers(0, 2, (m, n_a)).astype(np.uint32)
    b_bits = rng.integers(0, 2, (m, n_b)).astype(np.uint32)

    def packed(bits, n):
        pad = -n % 32
        return ref.pack_bits(jnp.asarray(np.pad(bits, ((0, 0), (0, pad)))))

    out = append_packed(packed(a_bits, n_a), n_a, packed(b_bits, n_b), n_b)
    assert out.shape == (m, (n_a + n_b + 31) // 32)
    dense = np.asarray(ref.unpack_bits(out, n_a + n_b))
    np.testing.assert_array_equal(dense[:, :n_a], a_bits)
    np.testing.assert_array_equal(dense[:, n_a:], b_bits)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 40), st.integers(1, 12), st.integers(2, 50),
       st.integers(0, 2 ** 31 - 1))
def test_create_index_property(n, w, m, seed):
    """Property: BI(i, j) == 1 iff record j contains key i (paper Fig. 1)."""
    rng = np.random.default_rng(seed)
    records = rng.integers(0, 64, (n, w), dtype=np.int32)
    keys = rng.integers(0, 64, (m,), dtype=np.int32)
    bi = ops.create_index(jnp.asarray(records), jnp.asarray(keys))
    dense = np.asarray(ref.unpack_bits(bi, n))
    for i in range(m):
        for j in range(n):
            assert dense[i, j] == int(keys[i] in records[j])
