"""Differential backend sweep + cost-model acceptance suite.

The ``bulk`` backend's whole-program sweep, the per-pass ``ref``/``pallas``
paths, and whatever ``auto`` picks must be BIT-identical on every plan the
planner can produce — padded and unpadded record counts, segment chains
stacked and unstacked, composite fallbacks and contradictions.  The cost
model may only ever choose which executor a wave lands on.

Also covered: calibration JSON round-trips and persistence, candidate
cutoff, decision memoization/factoring/stacking, and the backend-keyed
service warmup (an ``auto`` session pre-compiles every candidate backend
so a mid-traffic cost-model switch never stalls on jit).
"""
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.db import BitmapDB, Column, Schema, col
from repro.engine import (backends, batch as engine_batch, bulk, costmodel,
                          planner)
from repro.engine.planner import And, Key, Not, Or, QueryPlan, key, plan

RNG = np.random.default_rng(20260807)

SWEEP_BACKENDS = ("ref", "bulk", "pallas")


def _random_pred(rng, m, depth):
    if depth == 0 or rng.random() < 0.3:
        leaf = key(int(rng.integers(0, m)))
        return ~leaf if rng.random() < 0.4 else leaf
    arity = int(rng.integers(2, 4))
    children = tuple(_random_pred(rng, m, depth - 1) for _ in range(arity))
    node = And(children) if rng.random() < 0.5 else Or(children)
    return ~node if rng.random() < 0.2 else node


def _packed(n, m, seed=7):
    rng = np.random.default_rng(seed)
    from repro.engine import policy
    nw = policy.num_words(n)
    packed = jnp.asarray(rng.integers(0, 2 ** 32, (m, nw), dtype=np.uint32))
    # leave tail bits arbitrary: the planner masks once, backends must not
    return packed


def _wave(seed, m, count, depth=3):
    rng = np.random.default_rng(seed)
    preds = [_random_pred(rng, m, depth) for _ in range(count)]
    # salt in a contradiction and a tautology-ish inversion
    preds.append(key(1) & ~key(1))
    preds.append(~(key(2) & ~key(2)))
    return preds


def _run_all(packed, preds, n, **kw):
    outs = {}
    for name in SWEEP_BACKENDS:
        outs[name] = engine_batch.execute_many(packed, preds,
                                               num_records=n,
                                               backend=name, **kw)
    outs["auto"] = engine_batch.execute_many(packed, preds, num_records=n,
                                             backend="auto", **kw)
    return outs


def _assert_identical(outs):
    r0, c0 = outs["ref"]
    for name, (r, c) in outs.items():
        np.testing.assert_array_equal(np.asarray(r), np.asarray(r0),
                                      err_msg=f"rows differ: {name}")
        np.testing.assert_array_equal(np.asarray(c), np.asarray(c0),
                                      err_msg=f"counts differ: {name}")


# ------------------------------------------------------- differential sweep
def test_bulk_backend_registered():
    assert "bulk" in backends.available_backends()
    b = backends.get_backend("bulk")
    assert b.run_program is not None


@pytest.mark.parametrize("n", [512, 1000, 37])   # aligned, unpadded, tiny
@pytest.mark.parametrize("seed", [11, 12])
def test_sweep_bit_identical_all_backends(n, seed):
    m = 24
    packed = _packed(n, m, seed)
    preds = _wave(seed, m, 12)
    _assert_identical(_run_all(packed, preds, n))


def test_sweep_bit_identical_factored_and_padded_output():
    n, m = 800, 16
    packed = _packed(n, m, 3)
    preds = _wave(3, m, 10)
    _assert_identical(_run_all(packed, preds, n, factor=True))
    _assert_identical(_run_all(packed, preds, n, pad_output=True))


def test_sweep_bit_identical_composite_fallback():
    n, m = 320, 12
    packed = _packed(n, m, 5)
    rng = np.random.default_rng(5)
    preds = [_random_pred(rng, m, 4) for _ in range(6)]
    # max_clauses=2 forces composite sub-plans for the wide trees
    outs = {name: engine_batch.execute_many(packed, preds, num_records=n,
                                            backend=name, max_clauses=2)
            for name in (*SWEEP_BACKENDS, "auto")}
    assert any(isinstance(pl, planner.CompositePlan)
               for pl in (planner.plan(p, max_clauses=2) for p in preds))
    _assert_identical(outs)


def _clean_packed(n, m, seed):
    """Packed segment with ZERO tail bits — the engine invariant durable
    segments carry (and ``append_packed``'s documented precondition)."""
    from repro.engine import policy
    raw = np.array(_packed(n, m, seed))
    pad = policy.num_words(n) * 32 - n
    if pad:
        raw[:, -1] &= np.uint32(0xFFFFFFFF >> pad)
    return jnp.asarray(raw)


@pytest.mark.parametrize("stack", [True, False, None])
def test_sweep_bit_identical_segments(stack):
    m = 20
    parts = [(_clean_packed(n, m, 40 + i), n)
             for i, n in enumerate((512, 370, 96))]
    n_total = sum(n for _, n in parts)
    preds = _wave(21, m, 8)
    ref_rows, ref_counts = engine_batch.execute_many_segments(
        parts, preds, backend="ref", stack_uniform=bool(stack))
    for name in ("bulk", "pallas", "auto"):
        rows, counts = engine_batch.execute_many_segments(
            parts, preds, backend=name, stack_uniform=stack)
        np.testing.assert_array_equal(np.asarray(rows), np.asarray(ref_rows))
        np.testing.assert_array_equal(np.asarray(counts),
                                      np.asarray(ref_counts))
    # and the segment chain agrees with the spliced monolith
    from repro.engine import runtime
    packed_all, n_acc = parts[0]
    for p, n in parts[1:]:
        packed_all = runtime.append_packed(packed_all, n_acc, p, n)
        n_acc += n
    rows2, counts2 = engine_batch.execute_many(packed_all, preds,
                                               num_records=n_total,
                                               backend="bulk")
    np.testing.assert_array_equal(np.asarray(rows2), np.asarray(ref_rows))
    np.testing.assert_array_equal(np.asarray(counts2),
                                  np.asarray(ref_counts))


def test_bulk_pallas_program_interpret_bit_identical():
    """The word-tiled Pallas realization of the bulk sweep (interpret mode
    off-TPU) matches the pure-jnp sweep on one lowered bucket."""
    n, m = 256, 10
    packed = _packed(n, m, 9)
    preds = _wave(9, m, 6)
    by_shape = {}
    for p in preds:
        pl = planner.plan(p)
        if not (isinstance(pl, QueryPlan) and pl.clauses):
            continue
        prog, shape, _, _ = engine_batch._lowered(pl)
        if shape is not None:
            by_shape.setdefault(shape, []).append(prog)
    shape, progs = max(by_shape.items(), key=lambda kv: len(kv[1]))
    sels, invs, post = engine_batch._bucket_arrays(progs, shape, m)
    sels, invs = jnp.asarray(sels), jnp.asarray(invs)
    post = jnp.asarray(post)
    aug = engine_batch._augmented(packed)
    want = bulk.run_program(aug, n, sels, invs, post)
    got = bulk.run_program_pallas(aug, n, sels, invs, post, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


# ------------------------------------------------------------- cost model
def _cal(bulk_wps=4e9, ref_wps=2e9, pallas_wps=5e5, copy=1e10,
         bulk_oh=5e-5, ref_oh=4e-5):
    return costmodel.Calibration((
        ("bulk", costmodel.BackendProfile(bulk_wps, bulk_oh)),
        ("pallas", costmodel.BackendProfile(pallas_wps, 2e-3)),
        ("ref", costmodel.BackendProfile(ref_wps, ref_oh)),
    ), copy, "cpu", "measured")


def test_calibration_json_roundtrip(tmp_path):
    cal = _cal()
    again = costmodel.Calibration.from_json(cal.to_json())
    assert again == cal
    p = costmodel.save_calibration(cal, str(tmp_path / "cal.json"))
    assert costmodel.load_calibration(p) == cal
    with open(p) as f:
        assert json.load(f)["version"] == costmodel.CALIBRATION_VERSION


def test_calibration_env_path_and_reset(tmp_path, monkeypatch):
    p = str(tmp_path / "cal.json")
    costmodel.save_calibration(_cal(bulk_wps=7.5e9), p)
    monkeypatch.setenv(costmodel.ENV_PATH, p)
    costmodel.set_calibration(None)          # drop the cached calibration
    try:
        got = costmodel.get_calibration()
        assert got.source == "measured"
        assert got.profile("bulk").words_per_sec == 7.5e9
    finally:
        monkeypatch.delenv(costmodel.ENV_PATH)
        costmodel.set_calibration(None)


def test_candidates_cutoff_drops_interpreted_pallas():
    names = costmodel.candidates(_cal())
    assert "pallas" not in names             # 5e5 wps vs 4e9: way past 32x
    assert set(names) == {"bulk", "ref"}


def test_decide_picks_calibrated_fastest():
    preds = [plan(key(i) & ~key(i + 1)) for i in range(8)]
    fast_bulk = costmodel.decide(preds, num_words=1 << 14,
                                 cal=_cal(bulk_wps=8e9, ref_wps=1e9))
    assert fast_bulk.backend == "bulk"
    fast_ref = costmodel.decide(preds, num_words=1 << 14,
                                cal=_cal(bulk_wps=1e9, ref_wps=8e9))
    assert fast_ref.backend == "ref"
    assert dict(fast_ref.estimates)["ref"] < dict(fast_ref.estimates)["bulk"]
    assert fast_ref.terms["streamed_words"] > 0


def test_decide_memoizes_on_wave():
    preds = tuple(plan(key(i)) for i in range(4))
    cal = _cal()
    a = costmodel.decide(list(preds), num_words=4096, cal=cal)
    b = costmodel.decide(list(preds), num_words=4096, cal=cal)
    assert a is b                            # same cached Decision object
    c = costmodel.decide(list(preds), num_words=8192, cal=cal)
    assert c is not a


def test_decide_factoring_only_on_word_reduction():
    # many clauses sharing a 3-literal prefix: plain DNF streams one
    # wide group per clause; factoring hoists the prefix into one pass
    shared = key(0) & key(1) & key(2)
    wide = Or(tuple(shared & key(3 + i) for i in range(8)))
    preds = [plan(wide)]
    d = costmodel.decide(preds, num_words=1 << 14, cal=_cal())
    assert d.factor
    # single-clause plans: factoring can't help
    flat = [plan(key(i)) for i in range(6)]
    assert not costmodel.decide(flat, num_words=1 << 14, cal=_cal()).factor


def test_decide_stacking_tradeoff():
    preds = [plan(key(i % 8)) for i in range(16)]
    # huge dispatch overhead, fat copy pipe: stacking wins
    d = costmodel.decide(preds, num_words=256, num_segments=12, num_keys=32,
                         cal=_cal(bulk_oh=5e-3, ref_oh=5e-3, copy=1e12))
    assert d.stack_uniform
    # negligible overhead, starved copy pipe: stacking loses
    d2 = costmodel.decide(preds, num_words=256, num_segments=12,
                          num_keys=32,
                          cal=_cal(bulk_oh=1e-9, ref_oh=1e-9, copy=1e6))
    assert not d2.stack_uniform


def test_measure_calibration_tiny_smoke():
    cal = costmodel.measure_calibration(num_records=1 << 12, num_keys=16,
                                        num_queries=4, reps=1,
                                        backend_names=("ref", "bulk"),
                                        probe_seconds=10.0)
    assert cal.source == "measured"
    assert cal.copy_bytes_per_sec > 0
    for name in ("ref", "bulk"):
        prof = cal.profile(name)
        assert prof.words_per_sec > 0 and prof.dispatch_overhead_s > 0


# ------------------------------------------------- explain + warmup wiring
def _mk_db(n=512, m=16, backend="auto"):
    half = m // 2
    schema = Schema([Column.categorical("a", list(range(half))),
                     Column.categorical("b", list(range(half, m)))])
    rng = np.random.default_rng(0)
    db = BitmapDB(schema, backend=backend)
    db.append_encoded(np.stack([rng.integers(0, half, n, dtype=np.int32),
                                rng.integers(half, m, n, dtype=np.int32)],
                               axis=1))
    return db


def test_db_explain_surfaces_decision():
    db = _mk_db()
    q = (col("a") == 1) | ((col("a") == 2) & ~(col("b") == 9))
    ex = db.explain(q)
    assert ex["backend"] in backends.available_backends()
    assert ex["bucket_shape"] is not None
    assert ex["num_records"] == 512
    assert ex["est_matches"] is not None and ex["est_matches"] >= 0
    assert 0.0 <= ex["est_selectivity"] <= 1.0
    d = ex["decision"]
    assert d is not None and d["backend"] == ex["backend"]
    assert set(d["estimates"]) >= {"ref"}
    assert d["terms"]["streamed_words"] > 0
    # a pinned session reports its pinned backend, no decision
    db_ref = _mk_db(backend="ref")
    ex2 = db_ref.explain(q)
    assert ex2["backend"] == "ref" and ex2["decision"] is None
    # contradiction short-circuits
    ex3 = db.explain((col("a") == 1) & ~(col("a") == 1))
    assert ex3.get("fallback") == "contradiction"
    assert db.query((col("a") == 1) & ~(col("a") == 1)).count == 0


def test_service_warmup_is_backend_keyed():
    db_auto = _mk_db(backend="auto")
    db_ref = _mk_db(backend="ref")
    qs = [col("a") == 1, (col("a") == 2) & ~(col("b") == 9)]
    with db_auto.serve(max_batch=4, idle_after_ms=10_000.0) as svc:
        n_auto = svc.warmup(qs)
    with db_ref.serve(max_batch=4, idle_after_ms=10_000.0) as svc:
        n_ref = svc.warmup(qs)
    n_cands = len(costmodel.candidates())
    assert n_cands >= 2                      # bulk + ref at least, on CPU
    assert n_auto == n_ref * n_cands         # one warm pass per candidate


def test_auto_switch_mid_traffic_is_bit_exact():
    """Flipping the calibration (hence the chosen backend) between waves
    never changes result bits — the executor caches are backend-keyed."""
    db = _mk_db(n=700, backend="auto")
    q = [(col("a") == 1) | (col("b") == 9), ~(col("a") == 3)]
    try:
        costmodel.set_calibration(_cal(bulk_wps=9e9, ref_wps=1e9))
        r1, c1 = db.query_many(q).materialize()
        costmodel.set_calibration(_cal(bulk_wps=1e9, ref_wps=9e9))
        r2, c2 = db.query_many(q).materialize()
    finally:
        costmodel.set_calibration(None)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
