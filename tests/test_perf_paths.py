"""Perf-path equivalence: the §Perf optimizations must be bit-compatible
with the baselines they replace."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention_vjp, _tri_pairs
from repro.launch.dryrun import collective_bytes


@pytest.mark.parametrize("window", [None, 48])
def test_block_skip_flash_bitexact(window):
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 200, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    f_d = lambda q, k, v: (flash_attention_vjp(
        q, k, v, causal=True, window=window, q_chunk=64, kv_chunk=64) ** 2).sum()
    f_t = lambda q, k, v: (flash_attention_vjp(
        q, k, v, causal=True, window=window, q_chunk=64, kv_chunk=64,
        block_skip=True) ** 2).sum()
    assert float(f_d(q, k, v)) == float(f_t(q, k, v))
    gd = jax.grad(f_d, (0, 1, 2))(q, k, v)
    gt = jax.grad(f_t, (0, 1, 2))(q, k, v)
    for a, b in zip(gd, gt):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tri_pairs_counts():
    # equal chunks: nq(nq+1)/2 pairs; covers exactly the causal block set
    i, j = _tri_pairs(8, 64, 64)
    assert len(i) == 8 * 9 // 2
    assert all(jj * 64 < (ii + 1) * 64 for ii, jj in zip(i, j))
    # savings vs dense grid
    assert len(i) / (8 * 8) == pytest.approx(0.5625)


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[2048,512]{1,0} all-gather(bf16[128,512]{1,0} %x), dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%add
  %rs = bf16[128,512]{1,0} reduce-scatter(bf16[2048,512]{1,0} %z), dimensions={0}
  %a2a = s32[64,64]{1,0} all-to-all(s32[64,64]{1,0} %w), dimensions={0}
"""
    c = collective_bytes(hlo)
    assert c["all-gather"] == 2048 * 512 * 2
    assert c["all-reduce"] == 1024 * 4 * 2          # x2 ring factor
    assert c["reduce-scatter"] == 2048 * 512 * 2    # operand bytes
    assert c["all-to-all"] == 64 * 64 * 4
