"""End-to-end system tests: bitmap-indexed data pipeline, training loop with
checkpoint/restart (fault tolerance), optimizer behaviour, serving loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (CheckpointManager, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.configs import get_smoke_config
from repro.data.pipeline import BitmapIndexedDataset, DataConfig
from repro.launch.shapes import demo_batch
from repro.models.model import init_params
from repro.optim.adamw import (OptimConfig, apply_updates, init_opt_state,
                               learning_rate)
from repro.serve.step import greedy_generate
from repro.train.step import TrainConfig, make_train_step

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ data plane
def test_bitmap_pipeline_selection_correctness():
    """Query-driven selection == brute-force attribute filtering."""
    dcfg = DataConfig(vocab_size=128, seq_len=16, docs_per_shard=64,
                      num_shards=2, num_attributes=32)
    ds = BitmapIndexedDataset(dcfg)
    _, attrs = ds.corpus.shard(0)
    ids = ds.select(0, include=[3, 10], exclude=[17])
    want = [j for j in range(64)
            if 3 in attrs[j] and 10 in attrs[j] and 17 not in attrs[j]]
    assert list(ids) == want


def test_bitmap_pipeline_batches_deterministic_resume():
    dcfg = DataConfig(vocab_size=64, seq_len=8, docs_per_shard=128,
                      num_shards=2, num_attributes=16)
    ds = BitmapIndexedDataset(dcfg)
    it1 = ds.batches(4, include=[1], seed=7)
    ref = [next(it1) for _ in range(6)]
    it2 = ds.batches(4, include=[1], seed=7, start_step=3)
    for i in range(3):
        b = next(it2)
        np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                      np.asarray(ref[3 + i]["tokens"]))


# ------------------------------------------------------------- optimizer
def test_lr_schedule_shape():
    cfg = OptimConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100)
    lrs = [float(learning_rate(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100, 1000)]
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(cfg.min_lr_ratio, abs=0.01)


def test_adamw_reduces_loss():
    cfg = get_smoke_config("qwen2_7b")
    params = init_params(cfg, KEY)
    o = OptimConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=50)
    opt = init_opt_state(params, o)
    step = jax.jit(make_train_step(cfg, TrainConfig(o)))
    batch = demo_batch(cfg, "train", 4, 32, KEY)   # fixed batch: memorize it
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_grad_accumulation_matches_single_batch():
    cfg = get_smoke_config("granite_moe_3b_a800m")
    params = init_params(cfg, KEY)
    batch = demo_batch(cfg, "train", 8, 16, KEY)
    o = OptimConfig(peak_lr=1e-3)
    s1 = make_train_step(cfg, TrainConfig(o, accum_steps=1))
    s4 = make_train_step(cfg, TrainConfig(o, accum_steps=4))
    p1, _, m1 = jax.jit(s1)(params, init_opt_state(params, o), batch)
    p4, _, m4 = jax.jit(s4)(params, init_opt_state(params, o), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    for k in p1:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p4[k]),
                                   atol=3e-4)


def test_int8_grad_compression_still_learns():
    cfg = get_smoke_config("qwen2_7b")
    params = init_params(cfg, KEY)
    o = OptimConfig(peak_lr=3e-3, warmup_steps=2, grad_compression="int8",
                    moment_dtype="bfloat16")
    opt = init_opt_state(params, o)
    step = jax.jit(make_train_step(cfg, TrainConfig(o)))
    batch = demo_batch(cfg, "train", 4, 32, KEY)
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3


# ---------------------------------------------------- checkpoint / restart
def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("hymba_1_5b")
    params = init_params(cfg, KEY)
    opt = init_opt_state(params, OptimConfig())
    state = {"params": params, "opt": opt, "data_step": jnp.asarray(17)}
    save_checkpoint(str(tmp_path), 17, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 17
    for k in params:
        np.testing.assert_array_equal(np.asarray(restored["params"][k]),
                                      np.asarray(params[k]))


def test_restart_resumes_training_bitexact(tmp_path):
    """Kill-and-restart: train 4 steps; vs train 2, checkpoint, restore,
    train 2 more — identical params (the fault-tolerance contract)."""
    cfg = get_smoke_config("gemma3_4b")
    o = OptimConfig(peak_lr=1e-3)
    step = jax.jit(make_train_step(cfg, TrainConfig(o)))
    batches = [demo_batch(cfg, "train", 2, 16, jax.random.PRNGKey(i))
               for i in range(4)]

    p_a = init_params(cfg, KEY)
    s_a = init_opt_state(p_a, o)
    for b in batches:
        p_a, s_a, _ = step(p_a, s_a, b)

    p_b = init_params(cfg, KEY)
    s_b = init_opt_state(p_b, o)
    for b in batches[:2]:
        p_b, s_b, _ = step(p_b, s_b, b)
    save_checkpoint(str(tmp_path), 2, {"params": p_b, "opt": s_b})
    # simulated crash + restart
    like = {"params": jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), p_b),
        "opt": jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s_b)}
    restored, start = restore_checkpoint(str(tmp_path), like)
    p_c, s_c = restored["params"], restored["opt"]
    for b in batches[start:]:
        p_c, s_c, _ = step(p_c, s_c, b)
    for k in p_a:
        np.testing.assert_array_equal(np.asarray(p_a[k]), np.asarray(p_c[k]))


def test_checkpoint_manager_cadence_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every_steps=2, keep=2,
                            async_save=False)
    state = {"x": jnp.arange(4)}
    for s in range(1, 9):
        mgr.maybe_save(s, state)
    steps = sorted(int(d.split("-")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step-"))
    assert steps == [6, 8]


def test_checkpoint_atomicity(tmp_path):
    """A leftover tmp dir (crash mid-save) must not corrupt restore."""
    state = {"x": jnp.arange(3)}
    save_checkpoint(str(tmp_path), 1, state)
    os.makedirs(tmp_path / "tmp-2")          # simulated crashed save
    assert latest_step(str(tmp_path)) == 1
    like = {"x": jax.ShapeDtypeStruct((3,), jnp.int32)}
    _, step = restore_checkpoint(str(tmp_path), like)
    assert step == 1


# ---------------------------------------------------------------- serving
def test_greedy_generate_runs():
    cfg = get_smoke_config("qwen2_7b")
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    out = greedy_generate(params, cfg, toks, steps=5)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all()
    assert (np.asarray(out) < cfg.vocab_size).all()
