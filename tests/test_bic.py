"""BIC core behaviour: the paper's worked example, geometry accounting,
multi-core equivalence, elastic scheduling and the power model anchors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import power
from repro.core.bic import BICConfig, BICCore, PaperConfig
from repro.core.elastic import (ElasticScheduler, lpt_schedule,
                                multicore_create_index, static_schedule)


def test_paper_fig1_example():
    """Nine objects, five attributes, query A2 AND A4 AND NOT A5."""
    # records = objects; object j "contains" attribute value a
    objects = [
        [2, 4], [1, 2, 4], [2, 4, 5], [1, 5], [2, 3, 4],
        [3, 5], [1, 2, 4], [4, 5], [2, 4],
    ]
    rec = np.full((9, 4), -1, np.int32)
    for j, attrs in enumerate(objects):
        rec[j, :len(attrs)] = attrs
    keys = jnp.asarray([1, 2, 3, 4, 5], dtype=jnp.int32)
    core = BICCore(BICConfig(num_keys=5, num_records=9, words_per_record=4))
    bi = core.create(jnp.asarray(rec), keys)
    # rows are 1-indexed attributes: include A2(idx1), A4(idx3), not A5(idx4)
    res, cnt = core.query(bi, include=[1, 3], exclude=[4])
    want = [j for j, a in enumerate(objects) if 2 in a and 4 in a and 5 not in a]
    got = [j for j in range(9) if (int(res[j // 32]) >> (j % 32)) & 1]
    assert got == want
    assert int(cnt) == len(want)


def test_paper_memory_accounting():
    """Paper SIV: 32x32x8 = 8192 CAM bits + 16x8 buffer = 8320 bits."""
    assert PaperConfig.memory_bits == 8320
    assert abs(PaperConfig.memory_bits / 1024 - 8.125) < 1e-6


def test_ref_vs_pallas_backends_agree():
    rng = np.random.default_rng(0)
    rec = jnp.asarray(rng.integers(0, 256, (16, 32), dtype=np.int32))
    keys = jnp.asarray(rng.integers(0, 256, (8,), dtype=np.int32))
    a = BICCore(BICConfig(backend="pallas")).create(rec, keys)
    b = BICCore(BICConfig(backend="ref")).create(rec, keys)
    np.testing.assert_array_equal(np.asarray(a.packed), np.asarray(b.packed))


def test_multicore_matches_single_core():
    rng = np.random.default_rng(1)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rec = jnp.asarray(rng.integers(0, 256, (4, 16, 32), dtype=np.int32))
    keys = jnp.asarray(rng.integers(0, 256, (8,), dtype=np.int32))
    out = multicore_create_index(rec, keys, mesh, backend="ref")
    core = BICCore(PaperConfig)
    for z in range(4):
        want = core.create(rec[z], keys).packed
        np.testing.assert_array_equal(np.asarray(out[z]), np.asarray(want))


# ------------------------------------------------------------ power model
def test_power_model_anchors():
    a = power.PAPER_ANCHORS
    assert abs(power.frequency(0.4) / 1e6 - a["freq_mhz"][0.4]) < 0.2
    assert abs(power.frequency(1.2) / 1e6 - a["freq_mhz"][1.2]) < 0.2
    assert abs(power.active_power(1.2) * 1e3 - a["active_mw"][1.2]) < 0.05
    assert abs(power.energy_per_cycle(1.2) * 1e12 - a["energy_pj_12"]) < 1.0
    assert abs(power.standby_power(0.4) * 1e6 - a["standby_cg_uw_04"]) < 0.2
    rbb_nw = power.standby_power(0.4, -2.0) * 1e9
    assert abs(rbb_nw - a["standby_rbb_nw_04"]) < 0.3
    spb = power.standby_power_per_bit() * 1e12
    assert abs(spb - a["spb_pw_bit"]) < 0.05


def test_rbb_reduction_factor():
    """CG-only -> CG+RBB must drop standby power by ~3 orders of magnitude
    (paper: 10.6 uW -> 2.64 nW, i.e. ~4,000x)."""
    ratio = power.standby_power(0.4) / power.standby_power(0.4, -2.0)
    assert 3000 < ratio < 5000


def test_gidl_crossover():
    """Fig. 8: above ~0.8 V, deeper reverse bias stops helping (GIDL)."""
    assert power.standby_current(0.4, -2.0) < power.standby_current(0.4, -1.5)
    assert power.standby_current(1.2, -2.0) > power.standby_current(1.2, -1.5)


def test_decade_per_half_volt():
    """Fig. 8: each -0.5 V of V_bb cuts I_stb by ~10x (until the floor)."""
    i0 = power.standby_current(0.4, 0.0)
    i1 = power.standby_current(0.4, -0.5)
    i2 = power.standby_current(0.4, -1.0)
    assert 8 < i0 / i1 < 12
    assert 8 < i1 / i2 < 12


# --------------------------------------------------------------- elastic
def test_elastic_scheduler_energy_monotonicity():
    sch = ElasticScheduler(num_cores=8)
    lo = sch.run([10] * 5, tick_seconds=0.01)
    hi = sch.run([1000] * 5, tick_seconds=0.01)
    assert hi.active_joules > lo.active_joules
    assert lo.total_joules > 0


def test_elastic_standby_savings():
    """Idle cores under CG+RBB must cost ~4000x less than CG alone."""
    from repro.core.elastic import PowerState
    cg = ElasticScheduler(8, state=PowerState(use_rbb=False))
    rbb = ElasticScheduler(8, state=PowerState(use_rbb=True))
    r_cg = cg.run([0] * 10, 0.01)
    r_rbb = rbb.run([0] * 10, 0.01)
    assert r_cg.standby_joules / r_rbb.standby_joules > 1000


def test_straggler_mitigation_improves_makespan():
    costs = [1.0] * 64
    speeds = [1.0] * 7 + [0.25]
    assert lpt_schedule(costs, speeds)[0] < static_schedule(costs, speeds) * 0.5


def test_lpt_never_worse_than_static_on_heterogeneous_speeds():
    """Regression: for uniform batch costs (the BIC straggler scenario —
    every batch is the same pipeline, cores differ in speed), LPT's
    earliest-finish assignment must bound makespan at/below static striping.
    (With non-uniform costs greedy LPT carries no such guarantee, e.g.
    costs=[2,3,2,3,2] on two equal cores: LPT 7 vs round-robin 6.)"""
    rng = np.random.default_rng(7)
    for _ in range(20):
        n_batches = int(rng.integers(1, 96))
        n_cores = int(rng.integers(1, 9))
        costs = [1.0] * n_batches
        speeds = rng.uniform(0.2, 2.0, n_cores).tolist()
        makespan, assignment = lpt_schedule(costs, speeds)
        assert makespan <= static_schedule(costs, speeds) + 1e-9
        assert len(assignment) == n_batches
        assert all(0 <= c < n_cores for c in assignment)
