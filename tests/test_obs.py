"""Acceptance suite for the `repro.obs` observability layer.

Covers the span tracer (explicit clock, ambient nesting, cross-thread
parent handoff, bounded ring), the typed metric registry (counters /
gauges / histograms / reservoirs, registry grafting, Prometheus text),
the energy ledger (phase charging, per-query attribution, the two
reconciliation invariants), and the integration contract: a traced
1k-query / 8-caller storm through a live `BitmapService` yields a trace
that reconstructs every query's full span chain (admission -> queue ->
serve, joined to its wave's coalesce subtree), with per-query pJ that
sums back to the scheduler's energy total; `metrics()` / `health()` /
`cache_stats()` stay safe to call from reader threads mid-storm; fired
faults land as events inside the span they interrupted; and the
disabled path records nothing.
"""
import json
import threading

import numpy as np
import pytest

from repro.db import BitmapDB, Column, Schema, col
from repro.obs import energy as obs_energy
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


# ----------------------------------------------------------------- fixtures
@pytest.fixture
def fake_clock():
    t = [0.0]

    def clock():
        return t[0]

    clock.advance = lambda dt: t.__setitem__(0, t[0] + dt)
    return clock


@pytest.fixture
def installed_tracer():
    tracer = obs_trace.Tracer(capacity=1 << 17)
    obs_trace.install(tracer)
    try:
        yield tracer
    finally:
        obs_trace.uninstall(tracer)


def _schema(m: int = 16) -> Schema:
    half = m // 2
    return Schema([Column.categorical("a", list(range(half))),
                   Column.categorical("b", list(range(half, m)))])


def _mk_db(n: int = 2048, m: int = 16, seed: int = 0) -> BitmapDB:
    half = m // 2
    rng = np.random.default_rng(seed)
    enc = np.stack([rng.integers(0, half, n, dtype=np.int32),
                    rng.integers(half, m, n, dtype=np.int32)], axis=1)
    db = BitmapDB(_schema(m), backend="ref")
    db.append_encoded(enc)
    return db


def _mixed_queries(rng, m: int, count: int) -> list:
    half = m // 2
    qs = []
    for i in range(count):
        if i % 3 == 0:
            qs.append(col("a") == int(rng.integers(0, half)))
        elif i % 3 == 1:
            qs.append((col("a") == int(rng.integers(0, half)))
                      | (col("b") == int(rng.integers(half, m))))
        else:
            qs.append((col("a") == int(rng.integers(0, half)))
                      & ~(col("b") == int(rng.integers(half, m))))
    return qs


def _storm(svc, queries, callers: int = 8):
    futs = [None] * len(queries)
    errs = []

    def caller(lane):
        try:
            for i in range(lane, len(queries), callers):
                futs[i] = svc.submit(queries[i])
        except BaseException as e:              # noqa: BLE001 — reported
            errs.append(e)

    threads = [threading.Thread(target=caller, args=(c,))
               for c in range(callers)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert svc.drain(timeout=60)
    assert not errs
    return futs


# ------------------------------------------------------------------- tracer
def test_span_nesting_and_explicit_parents(fake_clock):
    tr = obs_trace.Tracer(fake_clock)
    with tr.span("outer", wave=3) as outer:
        fake_clock.advance(1.0)
        with tr.span("inner") as inner:
            fake_clock.advance(0.5)
        # cross-thread style: explicit (trace, span) tuple parent
        handed = tr.record("handoff", parent=outer.context,
                           t0=0.25, t1=0.75)
    spans = {s.name: s for s in tr.spans()}
    assert set(spans) == {"outer", "inner", "handoff"}
    assert spans["inner"].parent_id == outer.span_id
    assert spans["inner"].trace_id == outer.trace_id
    assert handed.parent_id == outer.span_id
    assert spans["outer"].duration_s == pytest.approx(1.5)
    assert spans["inner"].duration_s == pytest.approx(0.5)
    assert spans["outer"].attrs["wave"] == 3
    # roots have parent 0; nesting popped back out
    assert spans["outer"].parent_id == 0
    assert tr.current() is None


def test_span_error_annotation(fake_clock):
    tr = obs_trace.Tracer(fake_clock)
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("nope")
    (sp,) = tr.spans()
    assert "ValueError" in sp.attrs["error"]


def test_ring_bound_and_dropped(fake_clock):
    tr = obs_trace.Tracer(fake_clock, capacity=8)
    for i in range(20):
        tr.record(f"s{i}", t0=0.0, t1=1.0)
    assert len(tr) == 8
    assert tr.dropped == 12
    assert [s.name for s in tr.spans()] == [f"s{i}" for i in range(12, 20)]
    assert tr.drain() and len(tr) == 0


def test_install_ownership_and_maybe_span():
    assert obs_trace.TRACER is None
    assert obs_trace.current_context() is None
    cm = obs_trace.maybe_span("store.scrub")
    with cm as sp:
        assert sp is None                       # shared no-op when off
    a, b = obs_trace.Tracer(), obs_trace.Tracer()
    obs_trace.install(a)
    try:
        obs_trace.install(a)                    # idempotent re-install
        with pytest.raises(RuntimeError):
            obs_trace.install(b)
        with pytest.raises(RuntimeError):
            obs_trace.uninstall(b)
        with obs_trace.maybe_span("x") as sp:
            assert sp is not None
            assert obs_trace.current_context() == sp.context
    finally:
        obs_trace.uninstall(a)
    obs_trace.uninstall()                       # idempotent when off


def test_sink_receives_span_dicts(fake_clock):
    lines = []
    tr = obs_trace.Tracer(fake_clock, sink=lines.append)
    tr.record("a", t0=0.0, t1=2.0, k="v")
    assert lines == [tr.spans()[0].to_dict()]
    assert lines[0]["dur_ms"] == pytest.approx(2000.0)
    assert lines[0]["attrs"] == {"k": "v"}


# ------------------------------------------------------------------ metrics
def test_counter_gauge_histogram():
    reg = obs_metrics.Registry()
    c = reg.counter("served_total")
    c.inc()
    c.add(4)
    assert c.value == 5
    assert reg.counter("served_total") is c     # get-or-create
    with pytest.raises(TypeError):
        reg.gauge("served_total")               # kind mismatch
    g = reg.gauge("depth")
    g.set(7)
    g.inc(-2)
    assert g.value == 5
    h = reg.histogram("lat", (1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    assert h.count == 4
    snap = h.snapshot()
    assert snap["overflow"] == 1
    assert [n for _, n in snap["buckets"]] == [1, 1, 1]
    assert 0.0 <= h.quantile(0.5) <= 100.0


def test_reservoir_bounded_deterministic_exact_small():
    r = obs_metrics.Reservoir("lat", capacity=64, seed=3)
    for v in range(50):
        r.observe(float(v))
    # below capacity: lifetime-exact percentiles
    assert r.percentile(0) == 0.0
    assert r.percentile(100) == 49.0
    assert r.percentile(50) == pytest.approx(24.5)
    for v in range(50, 100_000):
        r.observe(float(v))
    assert len(r.values()) == 64                # memory stays flat
    assert r.count == 100_000
    r2 = obs_metrics.Reservoir("lat", capacity=64, seed=3)
    for v in range(100_000):
        r2.observe(float(v))
    assert r.values() == r2.values()            # seeded: deterministic


def test_registry_attach_collect_prometheus():
    root, child = obs_metrics.Registry(), obs_metrics.Registry()
    child.counter("repairs_total").add(2)
    root.counter("served_total").inc()
    root.attach("store", child)
    root.attach("store", child)                 # re-attach same: no-op
    with pytest.raises(ValueError):
        root.attach("store", obs_metrics.Registry())
    names = dict(root.collect())
    assert {"served_total", "store_repairs_total"} <= set(names)
    text = obs_export.prometheus_text(root, prefix="repro")
    assert "repro_served_total 1" in text
    assert "repro_store_repairs_total 2" in text
    snap = root.snapshot()
    assert snap["store_repairs_total"] == 2


def test_prometheus_histogram_and_reservoir_exposition():
    reg = obs_metrics.Registry()
    h = reg.histogram("lat_ms", (1.0, 10.0))
    h.observe(0.5)
    h.observe(99.0)
    r = reg.reservoir("rt", capacity=16)
    r.observe(4.0)
    text = obs_export.prometheus_text(reg)
    assert 'repro_lat_ms_bucket{le="+Inf"} 2' in text
    assert "repro_lat_ms_count 2" in text
    assert 'quantile="0.5"' in text


def test_write_jsonl(tmp_path, fake_clock):
    tr = obs_trace.Tracer(fake_clock)
    tr.record("a", t0=0.0, t1=1.0)
    tr.record("b", t0=1.0, t1=2.0)
    path = tmp_path / "out" / "trace.jsonl"
    assert obs_export.write_jsonl(tr.spans(), str(path)) == 2
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["name"] for r in rows] == ["a", "b"]


# ------------------------------------------------------------------- energy
def test_ledger_phases_attribution_reconcile():
    from repro.core.elastic import ElasticScheduler
    sched = ElasticScheduler(1)
    led = obs_energy.EnergyLedger(sched)
    led.charge("busy", 2.0)
    led.charge("awake_idle", 1.0)
    led.charge("standby", 10.0)
    led.charge("busy", -1.0)                    # ignored, not negative
    rep = led.report
    assert rep.active_joules == pytest.approx(3.0 * sched.p_active)
    assert rep.standby_joules == pytest.approx(10.0 * sched.p_standby)
    assert rep.busy_core_seconds == pytest.approx(2.0)
    pjs = led.attribute([101, 102, 103, 104])
    assert len(pjs) == 4 and len(set(pjs)) == 1     # even split
    assert sum(pjs) == pytest.approx(rep.total_joules * 1e12)
    rec = led.reconcile()
    assert rec["ok"]
    assert rec["attributed_plus_unattributed"] == pytest.approx(
        rec["total_joules"])
    led.charge("busy", 0.5)                     # new unattributed energy
    assert led.reconcile()["ok"]
    led.attribute_bits(1 << 20)
    snap = led.snapshot()
    assert snap["indexed_bits"] == 1 << 20
    assert snap["pj_per_indexed_bit"] > 0
    op = snap["operating_points"]
    assert op["standby_mode"] in ("rbb", "cg")
    assert op["standby_rbb_w"] < op["standby_cg_w"] < op["active_w"]


# -------------------------------------------------------------- integration
def test_traced_storm_reconstructs_every_span_chain(installed_tracer):
    tracer = installed_tracer
    db = _mk_db()
    nq = 1000
    queries = _mixed_queries(np.random.default_rng(1), 16, nq)
    svc = db.serve(max_batch=128, max_delay_ms=1.0, idle_after_ms=500.0)
    futs = _storm(svc, queries, callers=8)
    m = svc.metrics()
    ledger = svc.ledger
    svc.close()

    spans = tracer.spans()
    by_trace: dict = {}
    for s in spans:
        by_trace.setdefault(s.trace_id, {})[s.name] = s
    waves = {s.attrs["wave"]: s for s in spans if s.name == "coalesce"}
    assert waves                                # at least one wave ran
    for f in futs:
        assert f.trace_id is not None
        chain = by_trace[f.trace_id]
        # the full per-query chain, correctly parented
        assert {"admission", "queue", "serve"} <= set(chain)
        assert chain["admission"].parent_id == 0
        assert chain["queue"].parent_id == chain["admission"].span_id
        assert chain["serve"].parent_id == chain["queue"].span_id
        # ...and joined to its wave's coalesce subtree via the wave id
        wid = chain["serve"].attrs["wave"]
        assert chain["queue"].attrs["wave"] == wid
        assert wid in waves
        assert chain["serve"].attrs["mode"] in ("preferred", "fallback")
        assert chain["serve"].attrs["pj"] >= 0.0
    # the wave subtree nests device.execute/dispatch/reassembly under
    # coalesce in the wave's own trace
    for name in ("device.execute", "bucket.dispatch", "reassembly"):
        assert any(s.name == name and s.trace_id in
                   {w.trace_id for w in waves.values()} for s in spans)
    # per-query pJ + the not-yet-attributed remainder == scheduler total
    per_q = ledger.per_query_pj()
    assert len(per_q) == nq
    attributed_j = sum(pj for _, pj in per_q) * 1e-12
    rec = ledger.reconcile()
    assert rec["ok"], rec
    total = svc.energy.total_joules
    assert np.isclose(attributed_j + ledger.snapshot()
                      ["unattributed_joules"], total, rtol=1e-6)
    assert m.energy is not None
    assert m.energy["pj_per_query_mean"] > 0


def test_concurrent_telemetry_readers_never_tear(installed_tracer):
    db = _mk_db()
    nq = 1000
    queries = _mixed_queries(np.random.default_rng(2), 16, nq)
    svc = db.serve(max_batch=64, max_delay_ms=0.5, idle_after_ms=500.0)
    stop = threading.Event()
    errs = []

    def reader():
        try:
            while not stop.is_set():
                m = svc.metrics()
                assert m.served >= 0
                h = svc.health()
                assert "wave_retries" in h
                db.cache_stats()
                obs_export.prometheus_text(svc.registry)
        except BaseException as e:              # noqa: BLE001 — reported
            errs.append(e)

    readers = [threading.Thread(target=reader) for _ in range(3)]
    for th in readers:
        th.start()
    try:
        futs = _storm(svc, queries, callers=8)
    finally:
        stop.set()
        for th in readers:
            th.join()
    assert not errs
    resolved = sum(1 for f in futs if f.done() and f.exception() is None)
    assert resolved == nq
    # the counters reconcile with the futures that actually resolved
    assert svc.metrics().served == nq
    svc.close()


def test_fault_event_lands_inside_interrupted_span(installed_tracer):
    from repro.fault import FaultInjector, FaultPlan, FaultSpec
    tracer = installed_tracer
    db = _mk_db()
    queries = _mixed_queries(np.random.default_rng(3), 16, 64)
    svc = db.serve(max_batch=32, max_delay_ms=0.5, idle_after_ms=500.0,
                   retry_base_ms=0.5)
    plan = FaultPlan((FaultSpec("engine.dispatch", "dispatch_error",
                                occurrence=1),))
    with FaultInjector(plan) as inj:
        futs = _storm(svc, queries, callers=4)
    svc.close()
    assert inj.fired("engine.dispatch")
    assert all(f.exception() is None for f in futs)     # retried through
    events = [s for s in tracer.spans()
              if s.name == "fault.dispatch_error"]
    assert events
    by_id = {s.span_id: s for s in tracer.spans()}
    for ev in events:
        assert ev.duration_s == 0.0
        # parented to the live span it interrupted (the wave's dispatch
        # machinery on the scheduler thread), in that span's trace
        assert ev.parent_id != 0
        parent = by_id.get(ev.parent_id)
        if parent is not None:                  # parent may still be live
            assert parent.trace_id == ev.trace_id
    # the injector's own event log carries the trace/span join too
    ev = inj.events[0]
    assert ev.get("trace") and ev.get("span")


def test_maintenance_task_chains_to_submitter_context(installed_tracer,
                                                      tmp_path):
    tracer = installed_tracer
    db = BitmapDB(_schema(), path=str(tmp_path / "d"), spill_records=128,
                  backend="ref")
    rng = np.random.default_rng(4)
    svc = db.serve(max_delay_ms=0.5, idle_after_ms=500.0)
    half = 8
    for _ in range(4):
        enc = np.stack([rng.integers(0, half, 256, dtype=np.int32),
                        rng.integers(half, 16, 256, dtype=np.int32)],
                       axis=1)
        with tracer.span("ingest"):
            db.append_encoded(enc)
    assert svc._maint_ex.flush(30)
    svc.close()
    spans = tracer.spans()
    maint = [s for s in spans if s.name.startswith("maintenance.")]
    assert maint                                # spills ran in background
    ingest = {s.span_id: s for s in spans if s.name == "ingest"}
    # the background task's span is parented to the ingest span that
    # scheduled it (captured at submit time, crossed the worker thread)
    assert any(s.parent_id in ingest for s in maint)
    assert any(s.name.startswith("store.") or s.name.startswith("spill")
               for s in spans)


def test_disabled_path_records_nothing():
    assert obs_trace.TRACER is None
    db = _mk_db(n=512)
    queries = _mixed_queries(np.random.default_rng(5), 16, 32)
    svc = db.serve(max_delay_ms=0.5, idle_after_ms=500.0)
    futs = _storm(svc, queries, callers=2)
    assert all(f.trace_id is None for f in futs)
    m = svc.metrics()
    assert m.served == 32
    assert m.energy["total_joules"] > 0         # ledger runs regardless
    assert svc.ledger.reconcile()["ok"]
    svc.close()


def test_service_registry_grafts_lower_layers():
    db = _mk_db(n=512)
    svc = db.serve(max_delay_ms=0.5)
    _storm(svc, _mixed_queries(np.random.default_rng(6), 16, 16),
           callers=2)
    names = dict(svc.registry.collect())
    assert "served_total" in names
    assert "db_plan_cache_misses_total" in names
    assert any(n.startswith("engine_") for n in names)
    assert names["served_total"].value == 16
    # engine counters moved: waves/queries/dispatches all advanced
    assert names["engine_engine_queries_total"].value >= 16
    svc.close()
