"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU, shape + finiteness assertions) and component-level equivalence tests
(flash vs naive attention, SSD chunked vs sequential, fused CE vs naive,
prefill+decode vs full forward)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.launch.shapes import SHAPES, demo_batch, skip_reason
from repro.models.flash import flash_attention_vjp
from repro.models.loss import fused_ce_loss
from repro.models.model import (global_flags, init_params, lm_loss,
                                model_forward)
from repro.models.ssm import ssd_chunked, ssd_recurrent, ssd_sequential
from repro.optim.adamw import OptimConfig, init_opt_state
from repro.train.step import TrainConfig, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One real optimizer step on the reduced config: loss finite, params
    update, shapes preserved."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    opt = init_opt_state(params, OptimConfig())
    batch = demo_batch(cfg, "train", 2, 32, KEY)
    step = make_train_step(cfg, TrainConfig(OptimConfig(peak_lr=1e-3)))
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    moved = sum(
        float(jnp.abs(new_params[k] - params[k]).max()) > 0 for k in params)
    assert moved > len(params) * 0.5
    for k in params:
        assert new_params[k].shape == params[k].shape
        assert np.isfinite(np.asarray(new_params[k])).all(), k


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    batch = demo_batch(cfg, "prefill", 2, 16, KEY)
    logits, cache = model_forward(
        params, cfg, batch["tokens"], visual=batch.get("visual"),
        mrope_positions=batch.get("mrope_positions"),
        frames=batch.get("frames"), mode="prefill", max_len=20)
    assert logits.shape == (2, 1, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    db = demo_batch(cfg, "decode", 2, 20, KEY)
    dl, _ = model_forward(params, cfg, db["tokens"], cache=db["cache"],
                          mode="decode")
    assert dl.shape == (2, 1, cfg.vocab_padded)


@pytest.mark.parametrize("arch", ["qwen2_7b", "gemma3_4b", "mamba2_2_7b",
                                  "hymba_1_5b", "whisper_small",
                                  "qwen2_moe_a2_7b"])
def test_prefill_decode_matches_full(arch):
    """The serving path must reproduce the training-forward logits."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    B, S, extra = 2, 24, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + extra), 0,
                              cfg.vocab_size)
    kw = {}
    if cfg.enc_dec:
        kw["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.enc_frames, cfg.d_model)) * 0.02
    full, _ = model_forward(params, cfg, toks, mode="train", **kw)
    logits_p, cache = model_forward(params, cfg, toks[:, :S], mode="prefill",
                                    max_len=S + extra, **kw)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0], np.float32),
                               np.asarray(full[:, S - 1], np.float32),
                               atol=0.06)
    for t in range(extra):
        dl, cache = model_forward(params, cfg, toks[:, S + t:S + t + 1],
                                  cache=cache, mode="decode")
        np.testing.assert_allclose(np.asarray(dl[:, 0], np.float32),
                                   np.asarray(full[:, S + t], np.float32),
                                   atol=0.06)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3_4b")
    flags = global_flags(cfg)
    assert flags.sum() == 5                       # 34 layers, every 6th global
    assert all(flags[i] == ((i + 1) % 6 == 0) for i in range(34))


def test_param_counts_sane():
    """Published param counts within tolerance (validates exact geometry)."""
    expect = {
        "qwen2_7b": 7.6e9, "command_r_plus_104b": 104e9, "gemma3_4b": 4.3e9,
        "granite_20b": 20e9, "mamba2_2_7b": 2.7e9, "qwen2_moe_a2_7b": 14.3e9,
        "hymba_1_5b": 1.5e9,
    }
    for arch, want in expect.items():
        got = get_config(arch).param_count()
        assert 0.6 * want < got < 1.45 * want, (arch, got, want)


def test_moe_active_params():
    cfg = get_config("qwen2_moe_a2_7b")
    active = cfg.active_param_count()
    assert active < 0.45 * cfg.param_count()      # top-4 of 60 + shared


# -------------------------------------------------------------- components
def _naive_attn(q, k, v, causal, window):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= kp <= qp
        if window:
            ok &= kp > qp - window
    s = jnp.where(ok[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_flash_matches_naive_fwd_bwd(causal, window):
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 150, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    f = lambda q, k, v: (flash_attention_vjp(
        q, k, v, causal=causal, window=window, q_chunk=64, kv_chunk=48) ** 2).sum()
    fr = lambda q, k, v: (_naive_attn(q, k, v, causal, window) ** 2).sum()
    assert abs(float(f(q, k, v)) - float(fr(q, k, v))) < 2e-3
    g1 = jax.grad(f, (0, 1, 2))(q, k, v)
    g2 = jax.grad(fr, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_ssd_chunked_vs_sequential():
    rng = np.random.default_rng(0)
    B, S, nh, hp, ng, ds = 2, 64, 4, 8, 2, 16
    x = jnp.asarray(rng.standard_normal((B, S, nh, hp)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, nh)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2, (nh,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, ng, ds)), jnp.float32) * 0.3
    Cm = jnp.asarray(rng.standard_normal((B, S, ng, ds)), jnp.float32) * 0.3
    D = jnp.asarray(rng.standard_normal((nh,)), jnp.float32)
    y_ref, h_ref = ssd_sequential(x, dt, A, Bm, Cm, D)
    y_chk, h_chk = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_ref), atol=1e-5)
    # decode continuation
    y1, h1 = ssd_chunked(x[:, :48], dt[:, :48], A, Bm[:, :48], Cm[:, :48], D,
                         chunk=16)
    yt, _ = ssd_recurrent(h1, x[:, 48], dt[:, 48], A, Bm[:, 48], Cm[:, 48], D)
    np.testing.assert_allclose(np.asarray(yt), np.asarray(y_ref[:, 48]),
                               atol=1e-5)


def test_fused_ce_vs_naive():
    rng = np.random.default_rng(0)
    B, S, d, V, Vp = 2, 37, 16, 50, 64
    x = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((d, Vp)), jnp.float32) * 0.3
    labels = jnp.asarray(rng.integers(-1, V, (B, S)), jnp.int32)

    def naive(x, head):
        logits = (x @ head).astype(jnp.float32)
        logits = jnp.where(jnp.arange(Vp) < V, logits, -jnp.inf)
        lse = jax.nn.logsumexp(logits, -1)
        mask = labels >= 0
        gold = jnp.take_along_axis(
            logits, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
        return jnp.where(mask, lse - gold, 0.0).sum() / mask.sum()

    f = lambda x, h: fused_ce_loss(x, h, labels, valid_vocab=V, chunk=16)[0]
    assert abs(float(f(x, head)) - float(naive(x, head))) < 1e-5
    g1 = jax.grad(f, (0, 1))(x, head)
    g2 = jax.grad(naive, (0, 1))(x, head)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_shape_skip_rules():
    """long_500k runs only for sub-quadratic archs (assignment rule)."""
    runs = {a: skip_reason(get_config(a), SHAPES["long_500k"]) is None
            for a in ARCHS}
    assert runs["mamba2_2_7b"] and runs["hymba_1_5b"]
    for a in ("qwen2_7b", "command_r_plus_104b", "whisper_small",
              "gemma3_4b"):
        assert not runs[a]
