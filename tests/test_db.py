"""Acceptance suite for the `repro.db` facade.

Covers the schema/key-row mapping, the typed expression DSL (randomized
``expr -> Pred -> plan -> execute`` equivalence against a NumPy reference
evaluator over the encoded records), the legacy ``include=``/``exclude=``
deprecation shims (byte-identical results), lazy `Result` semantics, and
the end-to-end session lifecycle: schema ingest, streaming appends past
the spill threshold with ``path=``, crash recovery via ``repro.db.open``,
and a 1k-query mixed DSL batch served bit-identically to the raw
``engine.batch`` + `StoredIndex` path.
"""
import os
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

import repro
from repro.db import BitmapDB, Column, Schema, col
from repro.db import expr as expr_mod
from repro.engine import backends, batch as engine_batch, planner, policy
from repro.engine.planner import key
from repro.engine.runtime import StreamingIndexer


# ----------------------------------------------------------------- fixtures
def _weather_schema() -> Schema:
    return Schema([
        Column.categorical("city", ["SF", "NY", "LA"]),
        Column.binned("temp", edges=[-10.0, 0.0, 10.0, 20.0, 30.0, 45.0]),
        Column.categorical("tag", ["ok", "flagged", "dup"]),
    ])


def _weather_rows(rng, n):
    return {
        "city": [["SF", "NY", "LA"][i] for i in rng.integers(0, 3, n)],
        "temp": rng.uniform(-10, 45, n).tolist(),
        "tag": [["ok", "flagged", "dup"][i] for i in rng.integers(0, 3, n)],
    }


def _ref_eval(q, enc: np.ndarray, schema: Schema | None) -> np.ndarray:
    """NumPy reference semantics over encoded records: a leaf matches the
    records whose encoded words hit its lowered key set; combinators are
    boolean algebra.  Mirrors the DOCUMENTED bin-level semantics without
    touching planner, packing, or kernels."""
    if isinstance(q, planner.Key):
        return (enc == q.index).any(axis=1)
    if isinstance(q, (planner.Not, expr_mod.NotExpr)):
        return ~_ref_eval(q.child, enc, schema)
    if isinstance(q, (planner.And, expr_mod.AndExpr)):
        out = np.ones(enc.shape[0], bool)
        for c in q.children:
            out &= _ref_eval(c, enc, schema)
        return out
    if isinstance(q, (planner.Or, expr_mod.OrExpr)):
        out = np.zeros(enc.shape[0], bool)
        for c in q.children:
            out |= _ref_eval(c, enc, schema)
        return out
    if isinstance(q, expr_mod.Eq):
        keys = [schema.key_of(q.column, q.value)]
    elif isinstance(q, expr_mod.In):
        keys = [schema.key_of(q.column, v) for v in q.values]
    elif isinstance(q, expr_mod.Between):
        keys = list(schema[q.column].keys_between(q.lo, q.hi))
    else:
        raise TypeError(q)
    if not keys:
        return np.zeros(enc.shape[0], bool)
    return np.isin(enc, keys).any(axis=1)


# ------------------------------------------------------------------- schema
def test_schema_assigns_contiguous_key_rows():
    s = _weather_schema()
    assert s.num_keys == 3 + 5 + 3
    assert s.key_of("city", "SF") == 0
    assert s.key_of("city", "LA") == 2
    assert s.key_of("temp", -10.0) == 3       # first bin
    assert s.key_of("temp", 44.0) == 7        # last bin
    assert s.key_of("temp", 45.0) == 7        # right edge inclusive
    assert s.key_of("tag", "dup") == 10
    assert s.key_label(1) == "city='NY'"
    assert "temp" in s.key_label(4)


def test_schema_bin_boundaries():
    c = Schema([Column.binned("t", edges=[0, 10, 20, 30])])["t"]
    assert c.key_of(0) == 0 and c.key_of(9.99) == 0
    assert c.key_of(10) == 1 and c.key_of(29.9) == 2 and c.key_of(30) == 2
    with pytest.raises(KeyError):
        c.key_of(-0.01)
    with pytest.raises(KeyError):
        c.key_of(30.01)
    assert c.keys_between(-5, 5) == (0,)
    assert c.keys_between(5, 10) == (0, 1)     # 10 touches bin [10,20)
    assert c.keys_between(9.5, 25) == (0, 1, 2)
    assert c.keys_between(35, 40) == ()
    assert c.keys_between(-20, -11) == ()
    assert c.keys_between(30, 99) == (2,)      # right edge inclusive


def test_schema_encode_column_and_row_major():
    s = _weather_schema()
    cm = s.encode({"city": ["SF", "LA"], "temp": [5.0, 25.0],
                   "tag": ["ok", "dup"]})
    rm = s.encode([{"city": "SF", "temp": 5.0, "tag": "ok"},
                   {"city": "LA", "temp": 25.0, "tag": "dup"}])
    np.testing.assert_array_equal(cm, rm)
    np.testing.assert_array_equal(cm, [[0, 4, 8], [2, 6, 10]])
    with pytest.raises(KeyError, match="missing column"):
        s.encode({"city": ["SF"], "temp": [5.0]})
    with pytest.raises(KeyError, match="unknown columns"):
        s.encode({"city": ["SF"], "temp": [5.0], "tag": ["ok"],
                  "extra": [1]})
    with pytest.raises(KeyError):
        s.encode({"city": ["Atlantis"], "temp": [5.0], "tag": ["ok"]})


def test_schema_validation_errors():
    with pytest.raises(ValueError, match="duplicate column"):
        Schema([Column.categorical("a", [1]), Column.categorical("a", [2])])
    with pytest.raises(ValueError, match="duplicate values"):
        Column.categorical("a", [1, 1])
    with pytest.raises(ValueError, match="ascending"):
        Column.binned("t", edges=[0, 0, 10])
    with pytest.raises(ValueError, match="at least one column"):
        Schema([])


def test_schema_json_round_trip():
    s = _weather_schema()
    s2 = Schema.from_json(s.to_json())
    assert s2 == s and s2.num_keys == s.num_keys
    assert s2.key_of("temp", 15.0) == s.key_of("temp", 15.0)


def test_schema_count_keys_exact():
    s = _weather_schema()
    rng = np.random.default_rng(0)
    rows = _weather_rows(rng, 300)
    enc = s.encode(rows)
    counts = s.count_keys(enc)
    assert counts.sum() == 300 * 3            # one word per column
    assert counts[0] == rows["city"].count("SF")


# ---------------------------------------------------------------------- DSL
def test_expr_lowering_shapes():
    s = _weather_schema()
    assert expr_mod.lower(col("city") == "SF", s) == key(0)
    assert expr_mod.lower(col("city") != "SF", s) == ~key(0)
    low = expr_mod.lower(col("city").isin(["SF", "NY"]), s)
    assert isinstance(low, planner.Or)
    assert expr_mod.lower(col("city").isin(["SF"]), s) == key(0)
    # empty isin is a provable contradiction: zero clauses, zero passes
    pl = planner.plan(expr_mod.lower(col("city").isin([]), s))
    assert pl.clauses == ()
    # between lowers to the overlapping bins
    low = expr_mod.lower(col("temp").between(5, 25), s)
    assert {p.index for p in low.children} == {4, 5, 6}
    # comparison sugar
    low = expr_mod.lower(col("temp") >= 30.0, s)
    assert low == key(7)
    low = expr_mod.lower(col("temp") < 0.0, s)
    assert low == key(3)


def test_expr_mixed_raw_pred_trees():
    s = _weather_schema()
    mixed = key(3) & (col("city") == "NY")
    low = expr_mod.lower(mixed, s)
    assert low == planner.And((key(3), key(1)))
    # and the planner accepts the lowered result
    assert planner.plan(low).num_passes == 1


def test_expr_errors():
    s = _weather_schema()
    with pytest.raises(TypeError, match="column-to-column"):
        col("a") == col("b")
    with pytest.raises(KeyError, match="no column"):
        expr_mod.lower(col("nope") == 1, s)
    with pytest.raises(ValueError, match="need a Schema"):
        expr_mod.lower(col("city") == "SF", None)
    with pytest.raises(TypeError, match="combine an expression"):
        (col("city") == "SF") & "flagged"
    # raw predicates lower fine without a schema
    assert expr_mod.lower(key(1) & ~key(2), None) == key(1) & ~key(2)


def _random_expr(rng, schema: Schema, depth: int):
    if depth == 0 or rng.random() < 0.35:
        c = schema.columns[rng.integers(0, len(schema.columns))]
        kind = rng.integers(0, 4)
        if c.kind == "categorical":
            vals = list(c.values)
            if kind == 0:
                return col(c.name) == vals[rng.integers(0, len(vals))]
            if kind == 1:
                k = int(rng.integers(0, len(vals) + 1))
                pick = list(rng.choice(len(vals), size=k, replace=False))
                return col(c.name).isin([vals[i] for i in pick])
            if kind == 2:
                return col(c.name) != vals[rng.integers(0, len(vals))]
            return planner.key(int(rng.integers(0, schema.num_keys)))
        lo_e, hi_e = c.edges[0], c.edges[-1]
        if kind == 0:
            return col(c.name) == float(rng.uniform(lo_e, hi_e))
        if kind == 1:
            a, b = sorted(rng.uniform(lo_e - 5, hi_e + 5, 2))
            return col(c.name).between(float(a), float(b))
        if kind == 2:
            return col(c.name) >= float(rng.uniform(lo_e, hi_e))
        return col(c.name) < float(rng.uniform(lo_e, hi_e))
    arity = int(rng.integers(2, 4))
    children = [_random_expr(rng, schema, depth - 1) for _ in range(arity)]
    out = children[0]
    for c in children[1:]:
        out = (out & c) if rng.random() < 0.5 else (out | c)
    return ~out if rng.random() < 0.25 else out


@pytest.mark.parametrize("seed", range(6))
def test_random_exprs_match_numpy_reference(seed):
    """The DSL acceptance property: expr -> Pred -> plan -> packed
    execution == NumPy reference evaluation over the encoded records, for
    random schemas, data, and expression trees."""
    rng = np.random.default_rng(seed)
    cols = [Column.categorical("a", list(range(int(rng.integers(2, 6))))),
            Column.binned("b", edges=sorted(
                set(rng.uniform(-50, 50, int(rng.integers(3, 7)))))),
            Column.categorical("c", ["x", "y", "z", "w"])]
    schema = Schema(cols[: int(rng.integers(2, 4))])
    n = int(rng.integers(40, 220))
    rows = {}
    for c in schema.columns:
        if c.kind == "categorical":
            vals = list(c.values)
            rows[c.name] = [vals[i]
                            for i in rng.integers(0, len(vals), n)]
        else:
            rows[c.name] = rng.uniform(c.edges[0], c.edges[-1], n).tolist()
    db = BitmapDB(schema, backend="ref")
    db.ingest(rows)
    enc = schema.encode(rows)
    exprs = [_random_expr(rng, schema, depth=int(rng.integers(0, 3)))
             for _ in range(12)]
    results = db.query_many(exprs)
    for q, res in zip(exprs, results):
        want = np.flatnonzero(_ref_eval(q, enc, schema))
        np.testing.assert_array_equal(res.ids, want), q
        assert res.count == len(want)


# ------------------------------------------------------------ legacy shims
def test_include_exclude_shim_byte_identical():
    """The deprecated key-list surface must produce byte-identical results
    to what those callers always got from the planner directly."""
    rng = np.random.default_rng(3)
    records = jnp.asarray(rng.integers(0, 24, (77, 6), dtype=np.int32))
    keys = jnp.arange(24, dtype=jnp.int32)
    packed = backends.get_backend("ref").create_index(records, keys)
    bi = policy.BitmapIndex(packed, 77)
    from repro.core.bic import BICCore, BICConfig
    core = BICCore(BICConfig(num_keys=24, num_records=77,
                             words_per_record=6, backend="ref"))
    with pytest.warns(DeprecationWarning, match="include=/exclude="):
        r1, c1 = core.query(bi, include=[2, 4], exclude=[5])
    r2, c2 = planner.execute(
        packed, planner.from_include_exclude([2, 4], [5]),
        num_records=77, backend="ref")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert int(c1) == int(c2)


def test_pipeline_include_exclude_shim_byte_identical(tmp_path):
    from repro.data.pipeline import BitmapIndexedDataset, DataConfig
    cfg = DataConfig(vocab_size=64, seq_len=8, docs_per_shard=64,
                     num_shards=1, num_attributes=32)
    ds = BitmapIndexedDataset(cfg)
    with pytest.warns(DeprecationWarning):
        legacy = ds.select(0, include=[9], exclude=[20])
    modern = ds.select(0, where=key(9) & ~key(20))
    np.testing.assert_array_equal(legacy, modern)
    # and the DSL agrees with the raw key rows it maps onto
    dsl = ds.select(0, where=(col("lang") == 1) & ~(col("quality") == 4))
    raw = ds.select(0, where=key(9) & ~key(20))
    np.testing.assert_array_equal(dsl, raw)


# ------------------------------------------------------------- lazy results
def test_results_are_lazy_and_snapshot_query_time():
    s = _weather_schema()
    rng = np.random.default_rng(4)
    db = BitmapDB(s, backend="ref")
    db.ingest(_weather_rows(rng, 96))
    calls = []
    res = db.query(col("city") == "SF")
    assert not res._batch.executed
    n0 = res.count                       # materializes ONCE for the batch
    assert res._batch.executed
    db.append(_weather_rows(rng, 32))    # later append
    assert res.count == n0               # cached
    res2 = db.query(col("city") == "SF")
    assert res2.count >= n0 and db.num_records == 128
    del calls


def test_query_many_shares_one_batch():
    s = _weather_schema()
    db = BitmapDB(s, backend="ref")
    db.ingest(_weather_rows(np.random.default_rng(5), 64))
    rs = db.query_many([col("city") == "SF", col("tag") == "ok",
                        col("temp") >= 20.0])
    assert rs[0]._batch is rs[1]._batch is rs[2]._batch
    _ = rs[2].ids
    assert rs[0]._batch.executed


# ------------------------------------------------------------ session modes
def test_read_only_session_rejects_appends():
    s = _weather_schema()
    db = BitmapDB(s, backend="ref")
    db.ingest(_weather_rows(np.random.default_rng(6), 40))
    ro = BitmapDB.from_index(db.index, s, backend="ref")
    with pytest.raises(RuntimeError, match="read-only"):
        ro.append(_weather_rows(np.random.default_rng(7), 4))
    assert ro.query(col("city") == "SF").count == \
        db.query(col("city") == "SF").count
    # read-only stats popcount exactly
    assert ro.stats.counts == db.stats.counts


def test_constructor_and_open_errors(tmp_path):
    s = _weather_schema()
    with pytest.raises(ValueError, match="needs a Schema"):
        BitmapDB()
    with pytest.raises(ValueError, match="contradicts the schema"):
        BitmapDB(s, num_keys=5)
    p = os.path.join(str(tmp_path), "idx")
    db = BitmapDB(s, path=p, backend="ref", spill_records=None)
    db.ingest(_weather_rows(np.random.default_rng(8), 16))
    db.snapshot()
    with pytest.raises(ValueError, match="repro.db.open"):
        BitmapDB(s, path=p, backend="ref")
    with pytest.raises(ValueError, match="different schema"):
        BitmapDB.open(p, Schema([Column.categorical("other", [1])]),
                      backend="ref")
    with pytest.raises(FileNotFoundError, match="SCHEMA.json"):
        BitmapDB.open(os.path.join(str(tmp_path), "empty"), backend="ref")
    # schema persisted: open() without schema= recovers it
    db2 = repro.open(p, backend="ref")
    assert db2.schema == s and db2.num_records == 16


def test_top_level_lazy_exports():
    import repro as r
    assert r.BitmapDB is BitmapDB
    assert r.Schema is Schema and r.Column is Column
    assert r.col is col
    assert callable(r.open)
    assert "BitmapDB" in dir(r) and "engine" in dir(r)
    with pytest.raises(AttributeError):
        r.not_a_symbol


# ----------------------------------------------------- end-to-end acceptance
def _mixed_dsl_queries(schema: Schema, count: int, seed: int) -> list:
    rng = np.random.default_rng(seed)
    cities = list(schema["city"].values)
    tags = list(schema["tag"].values)
    out = []
    for i in range(count):
        fam = i % 7
        city = cities[rng.integers(0, len(cities))]
        tag = tags[rng.integers(0, len(tags))]
        lo, hi = sorted(rng.uniform(-10, 45, 2))
        if fam == 0:
            q = col("city") == city
        elif fam == 1:
            q = (col("city") == city) & ~(col("tag") == tag)
        elif fam == 2:
            q = col("temp").between(float(lo), float(hi))
        elif fam == 3:
            q = col("city").isin([city, cities[0]]) & (col("tag") == tag)
        elif fam == 4:
            q = (col("temp") >= float(lo)) & ~(col("city") == city)
        elif fam == 5:
            q = planner.key(int(rng.integers(0, schema.num_keys)))
        else:
            q = ((col("city") == city) & (col("tag") == tag)) | \
                (col("temp") < float(lo))
        out.append(q)
    return out


def test_bitmapdb_end_to_end_acceptance(tmp_path):
    """ISSUE acceptance: ingest with a Schema, stream appends past the
    spill threshold with path=, crash-recover via repro.db.open(), serve a
    1k-query mixed DSL batch — bit-identical to the raw engine.batch +
    StoredIndex path."""
    from repro.store import SegmentStore, open_index

    schema = _weather_schema()
    rng = np.random.default_rng(11)
    path = os.path.join(str(tmp_path), "db")
    db = BitmapDB(schema, path=path, backend="ref", spill_records=256)
    total = 0
    encoded_blocks = []
    for blk in (200, 150, 300, 90, 60):      # crosses the threshold twice
                                             # and leaves a 150-record tail
        rows_blk = _weather_rows(rng, blk)
        encoded_blocks.append(schema.encode(rows_blk))
        db.append(rows_blk)
        total += blk
    enc_all = np.concatenate(encoded_blocks)
    assert db.num_records == total
    store = db.store
    assert store.durable_records >= 256            # spilled segments
    assert store.durable_records < total           # and a live WAL tail
    live_packed = np.asarray(db.index.packed)

    # ---- crash: reopen from disk only -------------------------------
    rec = repro.open(path, backend="ref")
    assert rec.num_records == total
    np.testing.assert_array_equal(np.asarray(rec.index.packed), live_packed)

    # ---- serve a 1k mixed DSL batch through the facade ---------------
    queries = _mixed_dsl_queries(schema, 1000, seed=12)
    step = rec.serve_step()
    rows, counts = step(queries)
    assert rows.shape[0] == 1000

    # ---- raw path 1: engine.batch over the recovered contiguous index
    plans = [planner.plan(expr_mod.lower(q, schema)) for q in queries]
    want_r, want_c = engine_batch.execute_many(
        rec.index.packed, plans, num_records=total, backend="ref")
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(want_r))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(want_c))

    # ---- raw path 2: StoredIndex (segments + extracted WAL tail) -----
    st2 = SegmentStore(path)
    si = StreamingIndexer.restore(st2, jnp.arange(schema.num_keys,
                                                  dtype=jnp.int32),
                                  backend="ref")
    tail_n = si.num_records - st2.durable_records
    tail = (policy.extract_packed(si.index.packed, st2.durable_records,
                                  tail_n), tail_n)
    stored = open_index(st2, tail=tail if tail_n else None)
    sr, sc = stored.query_many(plans, backend="ref")
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(sc))

    # ---- and the numpy-reference ground truth ------------------------
    res = rec.query_many(queries[:50])
    for q, r in zip(queries[:50], res):
        want = np.flatnonzero(_ref_eval(q, enc_all, schema))
        np.testing.assert_array_equal(r.ids, want)


def test_stats_feed_clause_ordering():
    """A live session's plans order DNF clauses by the ingested data's
    selectivity, and results stay identical to unordered planning."""
    s = Schema([Column.categorical("a", [0, 1]),
                Column.categorical("b", [0, 1]),
                Column.categorical("c", [0, 1, 2])])
    # skew: a==1 is rare, b==1 is common
    rows = {"a": [1] * 5 + [0] * 95,
            "b": [1] * 90 + [0] * 10,
            "c": ([0, 1, 2] * 34)[:100]}
    db = BitmapDB(s, backend="ref")
    db.ingest(rows)
    q = ((col("b") == 1) & (col("c") == 0)) | ((col("a") == 1) &
                                              (col("c") == 1))
    pl_db = db._plan_for(q)
    pred = expr_mod.lower(q, s)
    pl_plain = planner.plan(pred)
    assert set(pl_db.clauses) == set(pl_plain.clauses)
    # the rare-key clause (a==1 ~ 5 records) must come first under stats
    first = pl_db.clauses[0]
    assert (s.key_of("a", 1), False) in first
    r1 = db.query(q)
    r2, c2 = planner.execute(db.index.packed, pl_plain,
                             num_records=100, backend="ref")
    np.testing.assert_array_equal(np.asarray(r1.rows), np.asarray(r2))
    assert r1.count == int(c2)
