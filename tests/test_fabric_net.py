"""Network-facing fabric behavior: framed sockets, hedged reads (seeded
determinism under a fake clock, loser-cancellation accounting, replica
divergence on layout but not content), primary-only writes, the network
chaos profile (drop/duplicate/delay/reorder at the rpc seams, zero
acknowledged writes lost), cross-process trace propagation, and the
multiprocess shard workers.
"""
import itertools
import random
import threading

import numpy as np
import pytest

from repro.db import BitmapDB, Column, Schema, col
from repro.engine.planner import key
from repro.fabric.client import FabricClient
from repro.fabric.envelope import Envelope
from repro.fabric.shardmap import ShardMap
from repro.fabric.transport import (LoopbackTransport, ReplyFuture,
                                    ReplyTimeout, SocketTransport,
                                    serve_socket)
from repro.fabric.protocol import ServiceHost
from repro.fault import FaultInjector, FaultPlan
from repro.obs import trace as obs_trace
from repro.serve.service import BitmapService, ServiceConfig

RNG = np.random.default_rng(21)
M = 16
HALF = M // 2


def _schema() -> Schema:
    return Schema([Column.categorical("a", list(range(HALF))),
                   Column.categorical("b", list(range(HALF, M)))])


def _records(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, HALF, n, dtype=np.int32),
                     rng.integers(HALF, M, n, dtype=np.int32)], axis=1)


def _queries():
    return [col("a") == 3, (col("a") == 1) & ~(col("b") == 9),
            (col("a") == 2) | (col("b") == 12), key(0),
            col("b").isin([8, 9, 10])]


def _trim(row, n: int) -> np.ndarray:
    w = (n + 31) >> 5
    out = np.zeros(w, np.uint32)
    row = np.asarray(row, np.uint32).reshape(-1)[:w]
    out[:row.shape[0]] = row
    return out


# --------------------------------------------------------- scripted replicas
class ScriptedReplica:
    """Transport stub for hedging tests: replies to anything after
    ``delay`` seconds (None = never replies)."""

    def __init__(self, name: str, delay: float | None = 0.0):
        self.name = name
        self.delay = delay
        self.requests = 0
        self._ids = itertools.count(1)

    def send(self, env: Envelope) -> ReplyFuture:
        self.requests += 1
        fut = ReplyFuture(next(self._ids))
        if self.delay is None:
            return fut
        reply = env.reply("pong", shard_id=0, via=self.name)
        if self.delay == 0:
            fut._resolve(reply)
        else:
            threading.Timer(self.delay,
                            lambda: fut._resolve(reply)).start()
        return fut

    def stats(self) -> dict:
        return {"name": self.name, "kind": "scripted", "pending": 0,
                "late_replies": 0}

    def close(self) -> None:
        pass


class FakeClock:
    """Monotone clock advancing a fixed step per read — hedging decisions
    become a pure function of call order."""

    def __init__(self, step: float = 0.01):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


def _first_done_waiter(futs, timeout):
    return next((f for f in futs if f.done()), None)


def _hedge_client(replicas, **kw) -> FabricClient:
    kw.setdefault("background", False)
    kw.setdefault("waiter", _first_done_waiter)
    return FabricClient([replicas], ShardMap.blocked(1, block_size=1),
                        **kw)


# ---------------------------------------------------------------- hedging
def test_hedge_permutation_is_seeded_and_deterministic():
    def first_receivers(seed: int, n: int = 20) -> list[str]:
        replicas = [ScriptedReplica(f"r{i}") for i in range(3)]
        fc = _hedge_client(replicas, hedge_seed=seed,
                           clock=FakeClock(), hedge_delay_ms=1e6)
        out = []
        for _ in range(n):
            before = [r.requests for r in replicas]
            fc._shard_request(0, Envelope("ping"), timeout=60)
            got = [r.name for r, b in zip(replicas, before)
                   if r.requests > b]
            assert len(got) == 1        # instant win: no hedges fired
            out.append(got[0])
        fc.close()
        return out

    a = first_receivers(seed=5)
    b = first_receivers(seed=5)
    c = first_receivers(seed=6)
    assert a == b                       # same seed -> same permutations
    assert len(set(a)) > 1              # it IS a spread, not a pin
    assert a != c                       # different seed -> different draw


def test_hedge_launches_loser_cancelled_and_counted():
    # find a seed whose first-request permutation puts the dead replica
    # first — the test then MUST hedge to succeed
    for seed in range(1000):
        order = [0, 1]
        random.Random(seed * 1_000_003 + 1).shuffle(order)
        if order == [0, 1]:
            break
    dead = ScriptedReplica("dead", delay=None)
    live = ScriptedReplica("live", delay=0.0)
    clock = FakeClock(step=0.01)
    fc = _hedge_client([dead, live], hedge_seed=seed, clock=clock,
                       hedge_delay_ms=10.0)
    reply = fc._shard_request(0, Envelope("ping"), timeout=60)
    assert reply.payload["via"] == "live"
    assert dead.requests == 1 and live.requests == 1
    assert fc._hedges_launched == 1
    assert fc._hedge_wins == 1
    assert fc._losers_cancelled == 1
    fc.close()


def test_hedge_all_replicas_dead_times_out_and_cancels():
    dead = [ScriptedReplica("d0", delay=None),
            ScriptedReplica("d1", delay=None)]
    fc = _hedge_client(dead, clock=FakeClock(step=0.05),
                       hedge_delay_ms=10.0, request_retries=0)
    with pytest.raises(ReplyTimeout):
        fc._shard_request(0, Envelope("ping"), timeout=0.5)
    assert all(r.requests == 1 for r in dead)
    assert fc._losers_cancelled == 2
    fc.close()


def test_writes_go_to_primary_only_never_hedged():
    schema = _schema()
    dbA = BitmapDB(schema, backend="ref")
    dbB = BitmapDB(schema, backend="ref")
    sm = ShardMap.blocked(1, block_size=1 << 30)
    with FabricClient.local([[dbA, dbB]], sm, max_delay_ms=1.0,
                            hedge_delay_ms=0.0) as fc:
        fc.append_encoded(_records(50, seed=1))
        assert dbA.num_records == 50    # primary took the write
        assert dbB.num_records == 0     # replica untouched (replication
        #                                 is sync_store's job, not RPC's)


def test_replicas_disagree_on_layout_but_not_content(tmp_path):
    """Two replicas hold identical records in different segment layouts
    (pure in-memory vs spilled durable segments); racing hedged reads
    must return bit-identical results whichever replica wins."""
    schema = _schema()
    recs = _records(400, seed=9)
    single = BitmapDB(schema, backend="ref")
    single.append_encoded(recs)
    mem = BitmapDB(schema, backend="ref")
    mem.append_encoded(recs)
    dur = BitmapDB(schema, backend="ref",
                   path=str(tmp_path / "replica"), spill_records=64)
    for i in range(0, 400, 100):        # different append granularity
        dur.append_encoded(recs[i:i + 100])
    assert dur.num_records == mem.num_records == 400
    sm = ShardMap.blocked(1, block_size=1 << 30)
    with FabricClient.local([[mem, dur]], sm, max_delay_ms=1.0,
                            gids=[np.arange(400, dtype=np.int64)],
                            hedge_delay_ms=0.0, hedge_seed=3) as fc:
        for rnd in range(3):            # both replicas get to win races
            for q in _queries():
                fut = fc.submit(q)
                want = single.query(q)
                row, count = fut.result(timeout=30)
                assert count == want.count
                np.testing.assert_array_equal(
                    _trim(row, 400), _trim(want.rows, 400))
        assert fc.metrics()["hedges_launched"] > 0


# ----------------------------------------------------------------- sockets
def test_socket_transport_round_trip_and_fabric_identity():
    schema = _schema()
    recs = _records(300, seed=13)
    single = BitmapDB(schema, backend="ref")
    single.append_encoded(recs)
    sm = ShardMap.hashed(schema, "a", 2, seed=7)
    parts = {s: (r, g) for s, r, g in sm.partition(recs)}
    hosts, servers, gids = [], [], []
    for s in range(2):
        r, g = parts.get(s, (np.zeros((0, 2), np.int32),
                             np.zeros(0, np.int64)))
        db = BitmapDB(schema, backend="ref")
        if r.shape[0]:
            db.append_encoded(r)
        host = ServiceHost(
            BitmapService(db, ServiceConfig(max_delay_ms=1.0,
                                            maintenance=False)),
            shard_id=s)
        hosts.append(host)
        servers.append(serve_socket(host))
        gids.append(g)
    try:
        # raw transport: ping + info over real frames
        t = SocketTransport(servers[0].address)
        assert t.request(Envelope("ping"), timeout=10).payload[
            "shard_id"] == 0
        t.close()
        from repro.fabric.transport import TransportClosed
        with pytest.raises(TransportClosed):
            t.send(Envelope("ping"))    # closed transport refuses
        fc = FabricClient.connect(
            [servers[s].address for s in range(2)], sm,
            schema=schema, gids=gids, max_delay_ms=1.0)
        try:
            for q in _queries():
                fut = fc.submit(q)
                want = single.query(q)
                row, count = fut.result(timeout=60)
                assert count == want.count
                np.testing.assert_array_equal(
                    _trim(row, 300), _trim(want.rows, 300))
            # appends cross the socket too (exactly-once protocol)
            more = _records(64, seed=14)
            single.append_encoded(more)
            assert fc.append_encoded(more) == 364
            assert sum(p["num_records"] for p in fc.info()) == 364
            q = col("a") == 2
            assert fc.submit(q).count == single.query(q).count
        finally:
            fc.close()
    finally:
        for srv in servers:
            srv.close()
        for h in hosts:
            h.close()


# ------------------------------------------------------------ trace stitch
def test_trace_propagates_across_the_rpc_boundary():
    tracer = obs_trace.Tracer(capacity=4096)
    obs_trace.install(tracer)
    try:
        recs = _records(128, seed=4)
        sm = ShardMap.blocked(2, total_records=128)
        parts = {s: (r, g) for s, r, g in sm.partition(recs)}
        stores, gids = [], []
        for s in range(2):
            r, g = parts[s]
            db = BitmapDB(_schema(), backend="ref")
            db.append_encoded(r)
            stores.append(db)
            gids.append(g)
        with FabricClient.local(stores, sm, gids=gids,
                                max_delay_ms=1.0) as fc:
            fut = fc.submit(col("a") == 1)
            fut.result(timeout=30)
            assert fc.drain(timeout=30)
        spans = tracer.spans()
        scatters = [s for s in spans if s.name == "fabric.scatter"]
        rpcs = [s for s in spans if s.name == "rpc.query"]
        assert scatters and rpcs
        assert fut.trace_id == scatters[-1].trace_id
        # every shard-side rpc.query span is stitched under the
        # client-side scatter: same trace, parented at the scatter span
        sc = scatters[-1]
        stitched = [r for r in rpcs if r.trace_id == sc.trace_id]
        assert len(stitched) == 2       # one per touched shard
        for r in stitched:
            assert r.parent_id == sc.span_id
    finally:
        obs_trace.uninstall(tracer)


# ------------------------------------------------------------ network chaos
def test_network_chaos_loses_no_acknowledged_writes():
    plan = FaultPlan.random(23, profile="network", n_faults=16,
                            max_occurrence=24, max_stall_s=0.001)
    assert all(s.site in ("rpc.send", "rpc.recv") for s in plan.specs)
    ref = BitmapDB(num_keys=M)
    blocks = [np.asarray(np.random.default_rng(100 + i)
                         .integers(0, M, (48, 2), dtype=np.int32))
              for i in range(6)]
    for b in blocks:
        ref.append_encoded(b)
    truth = [ref.query(key(i)).count for i in range(M)]
    sm = ShardMap(num_shards=2, strategy="hash", column_index=0,
                  base=0, cardinality=0, seed=23)
    fc = FabricClient.local(
        [BitmapDB(num_keys=M) for _ in range(2)], sm,
        max_delay_ms=1.0, request_timeout_s=0.5, request_retries=8,
        append_retries=10)
    inj = FaultInjector(plan).install()
    try:
        acked = 0
        for b in blocks:
            acked = fc.append_encoded(b)        # returns only when acked
        final = [fc.submit(key(i)).count for i in range(M)]
        stored = sum(p["num_records"] for p in fc.info())
    finally:
        inj.uninstall()
        fc.close()
    assert acked == 6 * 48
    assert stored == acked              # nothing lost, nothing doubled
    assert final == truth               # bit-identical to the clean run
    assert inj.fired()                  # the schedule actually did fire
    assert all(e["site"] in ("rpc.send", "rpc.recv")
               for e in inj.fired())


def test_network_chaos_same_seed_same_schedule():
    p1 = FaultPlan.random(47, profile="network")
    p2 = FaultPlan.random(47, profile="network")
    assert p1.specs == p2.specs
    assert FaultPlan.from_json(p1.to_json()).specs == p1.specs


# ------------------------------------------------------------- multiprocess
@pytest.mark.slow
def test_multiprocess_shard_workers_end_to_end(tmp_path):
    from repro.fabric.worker import spawn_shards

    schema = _schema()
    recs = _records(240, seed=17)
    single = BitmapDB(schema, backend="ref")
    single.append_encoded(recs)
    sm = ShardMap.hashed(schema, "a", 2, seed=11)
    parts = {s: (r, g) for s, r, g in sm.partition(recs)}
    shard_records, gids = [], []
    for s in range(2):
        r, g = parts.get(s, (np.zeros((0, 2), np.int32),
                             np.zeros(0, np.int64)))
        shard_records.append(r)
        gids.append(g)
    art = str(tmp_path / "artifacts")
    with spawn_shards(2, schema=schema, shard_records=shard_records,
                      service_config={"max_delay_ms": 1.0},
                      artifact_dir=art) as fleet:
        fc = FabricClient.connect(fleet.addresses, sm, schema=schema,
                                  gids=gids, max_delay_ms=1.0)
        try:
            assert sum(p["num_records"] for p in fc.info()) == 240
            for q in _queries():
                fut = fc.submit(q)
                want = single.query(q)
                row, count = fut.result(timeout=120)
                assert count == want.count
                np.testing.assert_array_equal(
                    _trim(row, 240), _trim(want.rows, 240))
            more = _records(32, seed=18)
            single.append_encoded(more)
            assert fc.append_encoded(more) == 272
            assert fc.submit(key(2)).count == single.query(key(2)).count
        finally:
            fc.close()
    for p in fleet.procs:
        assert not p.is_alive()
