"""repro.engine: backend registry, boolean query planner, streaming runtime.

The acceptance bar for the engine layer:
  * ``execute(plan)`` on a random predicate tree is bit-identical between
    the ``pallas`` (interpret) and ``ref`` backends;
  * incremental append matches a from-scratch rebuild of the same records;
  * the planner's DNF normalization preserves boolean semantics (checked
    against dense evaluation) including non-32-aligned N and M and
    all-inverted clauses (the kernel pad-guard path).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.bic import BICConfig, BICCore
from repro.engine import backends, batch, planner, policy, runtime
from repro.engine.planner import (And, CompositePlan, Key, Not, Or,
                                  QueryPlan, evaluate_dense, execute, factor,
                                  from_include_exclude, key, plan,
                                  total_clauses)
from repro.engine.runtime import (MulticoreRuntime, StreamingIndexer,
                                  append_packed, fold_block_indexes,
                                  multicore_create_index)
from repro.kernels import ref

RNG = np.random.default_rng(2024)


def _random_index(n, m, w=8, lo=0, hi=48):
    records = jnp.asarray(RNG.integers(lo, hi, (n, w), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(lo, hi, (m,), dtype=np.int32))
    return records, keys


def _random_pred(rng, m, depth):
    """Random nested AND/OR/NOT tree over key indices < m."""
    if depth == 0 or rng.random() < 0.3:
        leaf = key(int(rng.integers(0, m)))
        return ~leaf if rng.random() < 0.4 else leaf
    arity = int(rng.integers(2, 4))
    children = tuple(_random_pred(rng, m, depth - 1) for _ in range(arity))
    node = And(children) if rng.random() < 0.5 else Or(children)
    return ~node if rng.random() < 0.2 else node


# ------------------------------------------------------------ backend layer
def test_backend_registry_and_resolution():
    assert set(backends.available_backends()) >= {"pallas", "ref", "auto"}
    assert backends.resolve_backend("ref") == "ref"
    assert backends.resolve_backend("auto") in ("pallas", "ref")
    with pytest.raises(ValueError):
        backends.resolve_backend("no-such-backend")


@pytest.mark.parametrize("n,m,w", [(16, 8, 32), (19, 37, 7), (50, 5, 3),
                                   (33, 64, 8)])
def test_backends_create_bit_identical(n, m, w):
    records, keys = _random_index(n, m, w)
    a = backends.get_backend("pallas").create_index(records, keys)
    b = backends.get_backend("ref").create_index(records, keys)
    assert a.shape == (m, policy.num_words(n))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- planner: DNF
def test_plan_normalizes_de_morgan():
    p = ~(key(1) | key(2))                 # -> ~1 & ~2, one fused pass
    assert plan(p).clauses == (((1, True), (2, True)),)


def test_plan_drops_contradictions():
    assert plan(key(3) & ~key(3)).clauses == ()
    # contradiction inside one branch of an OR leaves the other branch
    assert plan((key(3) & ~key(3)) | key(1)).clauses == (((1, False),),)


def test_plan_absorption_and_dedup():
    # a | (a & b) -> a ;  duplicate literals collapse
    assert plan(key(1) | (key(1) & key(2))).clauses == (((1, False),),)
    assert plan(key(4) & key(4)).clauses == (((4, False),),)


def test_plan_shape_is_cache_key():
    a = plan((key(1) | key(2)) & key(3))
    b = plan((key(5) | key(6)) & key(7))
    assert a.shape == b.shape == (2, 2)
    assert a.clauses != b.clauses


def test_plan_clauses_ordered_cheapest_first():
    """Satellite: DNF clauses order by literal count (cheapest pass first,
    short-circuit potential for composite executors) — and since the plan
    is an OR of clauses, the ordering never changes a result bit."""
    p = key(9) | (key(1) & key(2) & key(3)) | (key(4) & key(5))
    pl = plan(p)
    assert pl.shape == (1, 2, 3)
    assert pl.shape == tuple(sorted(pl.shape))
    records, keys = _random_index(70, 12)
    idx = backends.get_backend("ref").create_index(records, keys)
    r1, c1 = execute(idx, pl, num_records=70, backend="ref")
    r2, c2 = execute(idx, QueryPlan(tuple(reversed(pl.clauses))),
                     num_records=70, backend="ref")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert int(c1) == int(c2)


def test_plan_stats_reorder_clauses_identical_bits():
    """Satellite: per-key set-bit stats order DNF clauses by estimated
    selectivity (literal count stays the uninformed fallback), and the
    reordered passes produce identical result bits."""
    p = (key(0) & key(1)) | (key(2) & key(3) & key(4)) | key(5)
    baseline = plan(p)
    assert [len(c) for c in baseline.clauses] == [1, 2, 3]
    n = 70
    # key 5 saturated, keys 2-4 rare: the stats must push the 3-literal
    # clause first and the single-literal clause last
    counts = [60, 60, 2, 2, 2, 70] + [35] * 6
    stats = planner.KeyStats.from_counts(counts, n)
    assert stats.literal_estimate(5, False) == 70
    assert stats.literal_estimate(5, True) == 0
    assert stats.literal_estimate(99, False) == n     # unknown key
    ordered = plan(p, stats=stats)
    assert set(ordered.clauses) == set(baseline.clauses)
    assert [len(c) for c in ordered.clauses] == [3, 2, 1]
    records, keys = _random_index(n, 12)
    idx = backends.get_backend("ref").create_index(records, keys)
    r1, c1 = execute(idx, baseline, num_records=n, backend="ref")
    r2, c2 = execute(idx, ordered, num_records=n, backend="ref")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert int(c1) == int(c2)
    # batched serving agrees too (plans bucket independently of order)
    rows, cts = batch.execute_many(idx, [baseline, ordered],
                                   num_records=n, backend="ref")
    np.testing.assert_array_equal(np.asarray(rows[0]), np.asarray(rows[1]))


def test_include_exclude_compiles_to_single_pass():
    p = from_include_exclude([2, 4], [5])
    assert plan(p).clauses == (((2, False), (4, False), (5, True)),)
    with pytest.raises(ValueError):
        from_include_exclude([], [])


# ------------------------------------------- planner: differential execution
@pytest.mark.parametrize("n,m", [(32, 32), (19, 37), (50, 5), (200, 12)])
def test_random_trees_pallas_vs_ref_bit_identical(n, m):
    """Acceptance: random predicate trees, non-32-aligned N and M, identical
    packed result and count across backends, both matching dense eval."""
    records, keys = _random_index(n, m)
    idx = backends.get_backend("ref").create_index(records, keys)
    dense = ref.unpack_bits(idx, n)
    rng = np.random.default_rng(n * 1000 + m)
    for _ in range(8):
        pred = _random_pred(rng, m, depth=3)
        r_ref, c_ref = execute(idx, pred, num_records=n, backend="ref")
        r_pal, c_pal = execute(idx, pred, num_records=n, backend="pallas")
        np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_pal))
        assert int(c_ref) == int(c_pal)
        want = np.asarray(evaluate_dense(pred, dense))
        got = np.asarray(ref.unpack_bits(r_ref[None], n))[0].astype(bool)
        np.testing.assert_array_equal(got, want)
        assert int(c_ref) == int(want.sum())


def test_all_inverted_operands_hit_pad_guard():
    """Every operand inverted + non-aligned N: inverted rows turn the pad
    words all-ones; the kernel pad-guard must zero them again."""
    n, m = 45, 6
    records, keys = _random_index(n, m)
    idx = backends.get_backend("ref").create_index(records, keys)
    pred = And(tuple(~key(i) for i in range(m)))
    for backend in ("ref", "pallas"):
        row, cnt = execute(idx, pred, num_records=n, backend=backend)
        want = np.asarray(evaluate_dense(pred, ref.unpack_bits(idx, n)))
        got = np.asarray(ref.unpack_bits(row[None], n))[0].astype(bool)
        np.testing.assert_array_equal(got, want)
        assert int(cnt) == int(want.sum())
        # tail bits past n must be zero even though every operand inverted
        tail = np.asarray(ref.unpack_bits(row[None], row.shape[0] * 32))[0]
        assert tail[n:].sum() == 0


def test_out_of_range_key_raises():
    """A typo'd key id must raise, not silently gather-clamp to the last
    index row."""
    records, keys = _random_index(40, 4)
    idx = backends.get_backend("ref").create_index(records, keys)
    with pytest.raises(ValueError, match=r"\[99\] out of range"):
        execute(idx, key(99), num_records=40)
    with pytest.raises(ValueError, match="out of range"):
        execute(idx, key(0) & ~key(-1), num_records=40)
    # a typo buried in a branch normalization simplifies away still raises
    with pytest.raises(ValueError, match=r"\[99\] out of range"):
        execute(idx, (key(99) & ~key(99)) | key(1), num_records=40)
    with pytest.raises(ValueError, match=r"\[99\] out of range"):
        execute(idx, key(1) | (key(1) & key(99)), num_records=40)


def test_contradiction_executes_without_kernel_pass():
    records, keys = _random_index(40, 4)
    idx = backends.get_backend("ref").create_index(records, keys)
    row, cnt = execute(idx, key(0) & ~key(0), num_records=40)
    assert int(cnt) == 0
    assert np.asarray(row).sum() == 0


def test_executor_jit_cache_reuses_same_shape():
    records, keys = _random_index(64, 16)
    idx = backends.get_backend("ref").create_index(records, keys)
    before = planner.compiled_plan_cache_info().currsize
    execute(idx, (key(1) | key(2)) & key(3), num_records=64, backend="ref")
    mid = planner.compiled_plan_cache_info()
    # same plan shape, different key ids -> cache hit, no new executor
    execute(idx, (key(9) | key(4)) & key(7), num_records=64, backend="ref")
    after = planner.compiled_plan_cache_info()
    assert mid.currsize >= before
    assert after.currsize == mid.currsize
    assert after.hits > mid.hits


def test_biccore_query_where_matches_include_exclude():
    records, keys = _random_index(30, 8)
    core = BICCore(BICConfig(num_keys=8, num_records=30, words_per_record=8,
                             backend="ref"))
    bi = core.create(records, keys)
    r1, c1 = core.query(bi, include=[2, 4], exclude=[5])
    r2, c2 = core.query(bi, where=key(2) & key(4) & ~key(5))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert int(c1) == int(c2)
    with pytest.raises(ValueError):
        core.query(bi, include=[1], where=key(1))


# ------------------------------------------------- planner: size guard
def _alternating_deep_tree(levels: int, m: int):
    """AND-of-OR alternation ``levels`` deep: full DNF distribution would
    produce 2**levels clauses."""
    p = Or((key(0 % m), key(1 % m)))
    for i in range(1, levels):
        p = And((Or((key(2 * i % m), key((2 * i + 1) % m))), p))
    return p


def test_plan_size_guard_bounds_adversarial_trees():
    """Acceptance: a 20-level alternating OR/AND tree (2**20 DNF clauses)
    plans as a composite of sub-plans, each under the clause ceiling."""
    ceiling = 64
    pred = _alternating_deep_tree(20, m=64)
    pl = plan(pred, max_clauses=ceiling)
    assert isinstance(pl, CompositePlan)

    def leaves(node):
        if isinstance(node, QueryPlan):
            return [node]
        return [leaf for part in node.parts for leaf in leaves(part)]

    assert all(len(leaf.clauses) <= ceiling for leaf in leaves(pl))
    # nowhere near the 2**20 clauses full distribution would produce
    assert total_clauses(pl) <= ceiling + 2 * 20


def test_plan_size_guard_preserves_semantics():
    n, m = 50, 64
    records, keys = _random_index(n, m)
    idx = backends.get_backend("ref").create_index(records, keys)
    dense = ref.unpack_bits(idx, n)
    pred = _alternating_deep_tree(20, m=m)
    pl = plan(pred, max_clauses=16)
    assert isinstance(pl, CompositePlan)
    row, cnt = execute(idx, pl, num_records=n, backend="ref")
    want = np.asarray(evaluate_dense(pred, dense))
    got = np.asarray(ref.unpack_bits(row[None], n))[0].astype(bool)
    np.testing.assert_array_equal(got, want)
    assert int(cnt) == int(want.sum())
    # small trees stay plain QueryPlans under the default guard
    assert isinstance(plan((key(1) | key(2)) & key(3)), QueryPlan)


def test_plan_guard_disabled_distributes_fully():
    pred = _alternating_deep_tree(8, m=32)          # 256 clauses, tractable
    pl = plan(pred, max_clauses=None)
    assert isinstance(pl, QueryPlan)
    assert len(pl.clauses) == 2 ** 8


# ------------------------------------------------- planner: clause factoring
def test_factor_shares_common_clause_prefix():
    # (a&b&c) | (a&b&d) | (a&b&e) -> a&b & (c|d|e): 2 passes instead of 3
    p = ((key(1) & key(2) & key(3)) | (key(1) & key(2) & key(4))
         | (key(1) & key(2) & key(5)))
    qp = plan(p)
    fp = factor(qp)
    assert qp.num_passes == 3
    assert fp.num_passes == 2
    assert fp.groups == ((((1, False), (2, False)),
                          ((3, False), (4, False), (5, False))),)


def test_factor_collapses_pure_or_to_one_pass():
    # a|b|c = ~(~a & ~b & ~c): one De-Morgan pass instead of three
    fp = factor(plan(key(1) | key(2) | key(3)))
    assert fp.num_passes == 1
    assert fp.groups == (((), ((1, False), (2, False), (3, False))),)


def test_factor_passes_through_unrelated_clauses():
    fp = factor(plan((key(1) & key(2)) | (key(3) & key(4))))
    assert fp.num_passes == 2           # nothing shared: plain passes
    assert all(d == () for _, d in fp.groups)


@pytest.mark.parametrize("n,m", [(50, 12), (19, 37)])
def test_factored_execution_bit_identical(n, m):
    records, keys = _random_index(n, m)
    idx = backends.get_backend("ref").create_index(records, keys)
    rng = np.random.default_rng(n * 77 + m)
    checked = 0
    for _ in range(10):
        pred = _random_pred(rng, m, depth=3)
        pl = plan(pred)
        if not isinstance(pl, planner.QueryPlan) or not pl.clauses:
            continue
        checked += 1
        r1, c1 = execute(idx, pl, num_records=n, backend="ref")
        r2, c2 = execute(idx, factor(pl), num_records=n, backend="ref")
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        assert int(c1) == int(c2)
    assert checked >= 5


def test_factored_execution_pallas_matches_ref():
    records, keys = _random_index(40, 9)
    idx = backends.get_backend("ref").create_index(records, keys)
    fp = factor(plan((key(0) & key(1)) | (key(0) & key(2)) | key(3)
                     | key(4)))
    r_ref, c_ref = execute(idx, fp, num_records=40, backend="ref")
    r_pal, c_pal = execute(idx, fp, num_records=40, backend="pallas")
    np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_pal))
    assert int(c_ref) == int(c_pal)


def test_plan_constants_are_cached():
    records, keys = _random_index(64, 16)
    idx = backends.get_backend("ref").create_index(records, keys)
    pl = plan((key(1) | key(2)) & key(3))
    execute(idx, pl, num_records=64, backend="ref")
    before = planner.plan_constant_cache_info()
    for _ in range(3):
        execute(idx, pl, num_records=64, backend="ref")
    after = planner.plan_constant_cache_info()
    assert after.hits >= before.hits + 3    # no per-call literal re-upload
    assert after.currsize == before.currsize


# --------------------------------------------------- batched query serving
def test_execute_many_matches_sequential_execute():
    """Acceptance: a mixed batch (random trees + contradiction + deep
    composite + include/exclude) is bit-identical to per-query execute."""
    n, m = 200, 24
    records, keys = _random_index(n, m)
    idx = backends.get_backend("ref").create_index(records, keys)
    rng = np.random.default_rng(99)
    preds = [_random_pred(rng, m, depth=3) for _ in range(30)]
    preds.append(key(0) & ~key(0))                    # contradiction
    preds.append(from_include_exclude([2, 4], [5]))
    preds.append(_alternating_deep_tree(15, m=m))     # composite fallback
    for factor_flag in (False, True):
        rows, counts = batch.execute_many(idx, preds, num_records=n,
                                          backend="ref", factor=factor_flag)
        assert rows.shape == (len(preds), policy.num_words(n))
        for i, p in enumerate(preds):
            r, c = execute(idx, p, num_records=n, backend="ref")
            np.testing.assert_array_equal(np.asarray(rows[i]),
                                          np.asarray(r))
            assert int(counts[i]) == int(c)


def test_execute_many_pallas_matches_ref():
    n, m = 50, 10
    records, keys = _random_index(n, m)
    idx = backends.get_backend("ref").create_index(records, keys)
    preds = [key(0), key(1) & ~key(2), (key(3) | key(4)) & key(5),
             key(6) | key(7)]
    r_ref, c_ref = batch.execute_many(idx, preds, num_records=n,
                                      backend="ref")
    r_pal, c_pal = batch.execute_many(idx, preds, num_records=n,
                                      backend="pallas")
    np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_pal))
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))


def test_execute_many_buckets_amortize_traces():
    """A 200-query mix must land in a handful of canonical-shape buckets
    (the whole point: traces stay O(shapes), not O(queries))."""
    n, m = 64, 32
    records, keys = _random_index(n, m)
    idx = backends.get_backend("ref").create_index(records, keys)
    rng = np.random.default_rng(5)

    def k():
        return int(rng.integers(0, m))

    preds = []
    for i in range(200):
        preds.append([key(k()),
                      key(k()) & key(k()),
                      key(k()) & key(k()) & ~key(k()),
                      (key(k()) | key(k())) & key(k()),
                      key(k()) | key(k())][i % 5])
    before = batch.batched_executor_cache_info()
    rows, counts = batch.execute_many(idx, preds, num_records=n,
                                      backend="ref")
    after = batch.batched_executor_cache_info()
    assert after.currsize - before.currsize <= 5
    # and re-serving the same mix compiles nothing new
    batch.execute_many(idx, preds, num_records=n, backend="ref")
    again = batch.batched_executor_cache_info()
    assert again.currsize == after.currsize
    assert again.hits > after.hits


def test_execute_many_validates_key_range():
    records, keys = _random_index(40, 4)
    idx = backends.get_backend("ref").create_index(records, keys)
    with pytest.raises(ValueError, match=r"\[99\] out of range"):
        batch.execute_many(idx, [key(0), key(99)], num_records=40,
                           backend="ref")
    with pytest.raises(ValueError, match="out of range"):
        batch.execute_many(idx, [plan(key(99))], num_records=40,
                           backend="ref")


def test_execute_many_empty_batch():
    records, keys = _random_index(40, 4)
    idx = backends.get_backend("ref").create_index(records, keys)
    rows, counts = batch.execute_many(idx, [], num_records=40, backend="ref")
    assert rows.shape == (0, policy.num_words(40))
    assert counts.shape == (0,)


def test_biccore_query_many_matches_query():
    records, keys = _random_index(30, 8)
    core = BICCore(BICConfig(num_keys=8, num_records=30, words_per_record=8,
                             backend="ref"))
    bi = core.create(records, keys)
    preds = [key(2) & key(4) & ~key(5), key(1) | key(6), key(0)]
    rows, counts = core.query_many(bi, preds)
    for i, p in enumerate(preds):
        r, c = core.query(bi, where=p)
        np.testing.assert_array_equal(np.asarray(rows[i]), np.asarray(r))
        assert int(counts[i]) == int(c)


def test_serve_bitmap_query_step():
    from repro.serve.step import make_bitmap_query_step
    records, keys = _random_index(30, 8)
    core = BICCore(BICConfig(backend="ref"))
    bi = core.create(records, keys)
    step = make_bitmap_query_step(bi, backend="ref")
    rows, counts = step([key(1), key(2) & ~key(3)])
    for i, p in enumerate([key(1), key(2) & ~key(3)]):
        r, c = execute(bi.packed, p, num_records=bi.num_records,
                       backend="ref")
        np.testing.assert_array_equal(np.asarray(rows[i]), np.asarray(r))
        assert int(counts[i]) == int(c)


# --------------------------------------------------------- streaming append
@pytest.mark.parametrize("blocks", [
    [16, 16], [7, 32, 19, 1, 64], [31, 1, 33], [5],
    [3, 130],                 # block much larger than the existing index,
                              # crossing several 32-bit word boundaries
    [33, 95, 66],             # repeated non-aligned multi-word appends
])
def test_incremental_append_matches_rebuild(blocks):
    """Acceptance: appending block-by-block == indexing everything at once,
    including non-32-aligned intermediate record counts."""
    m, w = 21, 6
    keys = jnp.asarray(RNG.integers(0, 32, (m,), dtype=np.int32))
    si = StreamingIndexer(keys, backend="ref")
    all_blocks = []
    for b in blocks:
        blk = jnp.asarray(RNG.integers(0, 32, (b, w), dtype=np.int32))
        all_blocks.append(blk)
        si.append(blk)
        # the live index is consistent after EVERY append, not just the last
        n_so_far = sum(x.shape[0] for x in all_blocks)
        rebuilt = backends.get_backend("ref").create_index(
            jnp.concatenate(all_blocks, axis=0), keys)
        np.testing.assert_array_equal(np.asarray(si.index.packed),
                                      np.asarray(rebuilt))
        assert si.num_records == n_so_far


def test_append_empty_block_is_noop():
    """Satellite: a 0-record block must not dispatch create_index (the
    backends cannot index zero rows) and must leave the index untouched."""
    m, w = 9, 4
    keys = jnp.asarray(RNG.integers(0, 32, (m,), dtype=np.int32))
    si = StreamingIndexer(keys, backend="ref")
    empty = jnp.zeros((0, w), jnp.int32)
    si.append(empty)                         # empty append on empty index
    assert si.num_records == 0
    blk = jnp.asarray(RNG.integers(0, 32, (21, w), dtype=np.int32))
    si.append(blk)
    before = np.asarray(si.index.packed).copy()
    si.append(empty)
    assert si.num_records == 21
    np.testing.assert_array_equal(np.asarray(si.index.packed), before)
    # append_many with zero blocks / zero-record blocks is equally inert
    si.append_many(jnp.zeros((0, 8, w), jnp.int32))
    si.append_many(jnp.zeros((3, 0, w), jnp.int32))
    assert si.num_records == 21


def test_append_many_matches_sequential_and_rebuild():
    """Batched appends (one vmapped build + one scanned splice fold) are
    bit-identical to block-by-block appends and to a rebuild, including on
    top of a non-aligned prefix."""
    m, w = 21, 6
    keys = jnp.asarray(RNG.integers(0, 32, (m,), dtype=np.int32))
    prefix = jnp.asarray(RNG.integers(0, 32, (5, w), dtype=np.int32))
    blocks = jnp.asarray(RNG.integers(0, 32, (6, 7, w), dtype=np.int32))
    si_many = StreamingIndexer(keys, backend="ref")
    si_many.append(prefix)
    si_many.append_many(blocks)
    si_seq = StreamingIndexer(keys, backend="ref")
    si_seq.append(prefix)
    for b in blocks:
        si_seq.append(b)
    rebuilt = backends.get_backend("ref").create_index(
        jnp.concatenate([prefix, blocks.reshape(-1, w)], axis=0), keys)
    np.testing.assert_array_equal(np.asarray(si_many.index.packed),
                                  np.asarray(rebuilt))
    np.testing.assert_array_equal(np.asarray(si_seq.index.packed),
                                  np.asarray(rebuilt))
    assert si_many.num_records == si_seq.num_records == 47


def test_streaming_splice_not_retraced_per_block():
    """Acceptance: steady-state appends of one block size reuse a single
    compiled splice — the trace count must not grow with the block count."""
    m, w = 8, 4
    keys = jnp.asarray(RNG.integers(0, 32, (m,), dtype=np.int32))
    si = StreamingIndexer(keys, backend="ref", capacity_words=64)
    blk = jnp.asarray(RNG.integers(0, 32, (48, w), dtype=np.int32))
    si.append(blk)                           # first append traces once
    before = runtime.splice_cache_size()
    for _ in range(6):                       # non-aligned: offset cycles
        si.append(jnp.asarray(RNG.integers(0, 32, (48, w), dtype=np.int32)))
    assert runtime.splice_cache_size() == before


def test_fold_block_indexes_matches_rebuild():
    m, w = 13, 5
    keys = jnp.asarray(RNG.integers(0, 32, (m,), dtype=np.int32))
    rec = jnp.asarray(RNG.integers(0, 32, (4, 7, w), dtype=np.int32))
    be = backends.get_backend("ref")
    blocks = jnp.stack([be.create_index(r, keys) for r in rec])
    folded = fold_block_indexes(blocks, 7)
    rebuilt = be.create_index(rec.reshape(-1, w), keys)
    np.testing.assert_array_equal(np.asarray(folded.packed),
                                  np.asarray(rebuilt))
    assert folded.num_records == 28


def test_append_packed_is_pure_splice():
    m = 4
    a = jnp.asarray(RNG.integers(0, 2 ** 32, (m, 2), dtype=np.uint32))
    n_a = 45                                    # unaligned tail
    a = a & jnp.asarray(ref.pack_bits(
        (jnp.arange(64) < n_a).astype(jnp.uint32)).reshape(1, 2))
    b_bits = RNG.integers(0, 2, (m, 23)).astype(np.uint32)
    b = ref.pack_bits(jnp.asarray(np.pad(b_bits, ((0, 0), (0, 9)))))
    out = append_packed(a, n_a, b, 23)
    dense_a = np.asarray(ref.unpack_bits(a, n_a))
    dense_out = np.asarray(ref.unpack_bits(out, n_a + 23))
    np.testing.assert_array_equal(dense_out[:, :n_a], dense_a)
    np.testing.assert_array_equal(dense_out[:, n_a:], b_bits)


# --------------------------------------------------------- multicore runtime
def _one_device_mesh():
    import jax
    return jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def test_multicore_runtime_fuses_energy_and_execution():
    mesh = _one_device_mesh()
    rt = MulticoreRuntime(mesh, backend="ref")
    keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    ticks = []
    for wl in (4, 0, 2):
        ticks.append(None if wl == 0 else jnp.asarray(
            RNG.integers(0, 256, (wl, 16, 32), dtype=np.int32)))
    outs, report = rt.index_stream(ticks, keys, tick_seconds=0.01)
    assert len(outs) == 2                       # idle tick produced no work
    assert outs[0].shape == (4, 8, 1)
    assert report.batches == 6
    assert report.active_joules > 0
    assert report.standby_joules > 0            # the idle tick was accounted
    # the indexes it produced match the single-core engine build
    core = BICCore(BICConfig(backend="ref"))
    for z in range(4):
        want = core.create(ticks[0][z], keys).packed
        np.testing.assert_array_equal(np.asarray(outs[0][z]),
                                      np.asarray(want))


def test_run_tick_serves_query_batch_against_tick_index():
    """run_tick(queries=...) folds the per-core block indexes into one tick
    index and serves the whole query batch through engine.batch —
    bit-identical to querying a from-scratch index of the tick's records."""
    mesh = _one_device_mesh()
    rt = MulticoreRuntime(mesh, backend="ref")
    keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    records = jnp.asarray(RNG.integers(0, 256, (3, 16, 32), dtype=np.int32))
    queries = [key(0), key(1) & ~key(2), (key(3) | key(4)) & key(5)]
    res = rt.run_tick(records, keys, 0.01, queries=queries)
    assert res.indexes is not None
    assert res.query_rows.shape == (3, policy.num_words(48))
    tick_idx = backends.get_backend("ref").create_index(
        records.reshape(-1, 32), keys)
    for i, q in enumerate(queries):
        r, c = execute(tick_idx, q, num_records=48, backend="ref")
        np.testing.assert_array_equal(np.asarray(res.query_rows[i]),
                                      np.asarray(r))
        assert int(res.query_counts[i]) == int(c)
    # idle ticks and query-less ticks keep the old contract
    idle = rt.run_tick(None, keys, 0.01, queries=queries)
    assert idle.query_rows is None
    plain = rt.run_tick(records, keys, 0.01)
    assert plain.query_rows is None and plain.indexes is not None


_NON_DIVISIBLE_SCRIPT = """
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.engine.runtime import multicore_create_index
from repro.core.bic import BICCore, BICConfig
assert len(jax.devices()) == 4, jax.devices()
mesh = jax.make_mesh((4,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(3)
keys = jnp.asarray(rng.integers(0, 256, (8,), dtype=np.int32))
rec = jnp.asarray(rng.integers(0, 256, (6, 16, 32), dtype=np.int32))
out = multicore_create_index(rec, keys, mesh, backend="ref")   # 6 % 4 != 0
assert out.shape == (6, 8, 1), out.shape
core = BICCore(BICConfig(backend="ref"))
for z in range(6):
    want = core.create(rec[z], keys).packed
    np.testing.assert_array_equal(np.asarray(out[z]), np.asarray(want))
print("OK")
"""


def test_multicore_handles_non_divisible_batch_counts():
    """Workload sizes that don't divide the mesh axis pad for dispatch and
    slice back.  The pad branch only exists for >1 device, so this runs in
    a subprocess with a forced 4-device CPU platform (same trick as
    launch/dryrun.py)."""
    import os
    import subprocess
    import sys as _sys
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([_sys.executable, "-c", _NON_DIVISIBLE_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_multicore_create_index_backend_dispatch():
    mesh = _one_device_mesh()
    rec = jnp.asarray(RNG.integers(0, 256, (2, 16, 32), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    a = multicore_create_index(rec, keys, mesh, backend="ref")
    b = multicore_create_index(rec, keys, mesh, backend="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
