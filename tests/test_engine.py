"""repro.engine: backend registry, boolean query planner, streaming runtime.

The acceptance bar for the engine layer:
  * ``execute(plan)`` on a random predicate tree is bit-identical between
    the ``pallas`` (interpret) and ``ref`` backends;
  * incremental append matches a from-scratch rebuild of the same records;
  * the planner's DNF normalization preserves boolean semantics (checked
    against dense evaluation) including non-32-aligned N and M and
    all-inverted clauses (the kernel pad-guard path).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.bic import BICConfig, BICCore
from repro.engine import backends, planner, policy
from repro.engine.planner import (And, Key, Not, Or, evaluate_dense, execute,
                                  from_include_exclude, key, plan)
from repro.engine.runtime import (MulticoreRuntime, StreamingIndexer,
                                  append_packed, multicore_create_index)
from repro.kernels import ref

RNG = np.random.default_rng(2024)


def _random_index(n, m, w=8, lo=0, hi=48):
    records = jnp.asarray(RNG.integers(lo, hi, (n, w), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(lo, hi, (m,), dtype=np.int32))
    return records, keys


def _random_pred(rng, m, depth):
    """Random nested AND/OR/NOT tree over key indices < m."""
    if depth == 0 or rng.random() < 0.3:
        leaf = key(int(rng.integers(0, m)))
        return ~leaf if rng.random() < 0.4 else leaf
    arity = int(rng.integers(2, 4))
    children = tuple(_random_pred(rng, m, depth - 1) for _ in range(arity))
    node = And(children) if rng.random() < 0.5 else Or(children)
    return ~node if rng.random() < 0.2 else node


# ------------------------------------------------------------ backend layer
def test_backend_registry_and_resolution():
    assert set(backends.available_backends()) >= {"pallas", "ref", "auto"}
    assert backends.resolve_backend("ref") == "ref"
    assert backends.resolve_backend("auto") in ("pallas", "ref")
    with pytest.raises(ValueError):
        backends.resolve_backend("no-such-backend")


@pytest.mark.parametrize("n,m,w", [(16, 8, 32), (19, 37, 7), (50, 5, 3),
                                   (33, 64, 8)])
def test_backends_create_bit_identical(n, m, w):
    records, keys = _random_index(n, m, w)
    a = backends.get_backend("pallas").create_index(records, keys)
    b = backends.get_backend("ref").create_index(records, keys)
    assert a.shape == (m, policy.num_words(n))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- planner: DNF
def test_plan_normalizes_de_morgan():
    p = ~(key(1) | key(2))                 # -> ~1 & ~2, one fused pass
    assert plan(p).clauses == (((1, True), (2, True)),)


def test_plan_drops_contradictions():
    assert plan(key(3) & ~key(3)).clauses == ()
    # contradiction inside one branch of an OR leaves the other branch
    assert plan((key(3) & ~key(3)) | key(1)).clauses == (((1, False),),)


def test_plan_absorption_and_dedup():
    # a | (a & b) -> a ;  duplicate literals collapse
    assert plan(key(1) | (key(1) & key(2))).clauses == (((1, False),),)
    assert plan(key(4) & key(4)).clauses == (((4, False),),)


def test_plan_shape_is_cache_key():
    a = plan((key(1) | key(2)) & key(3))
    b = plan((key(5) | key(6)) & key(7))
    assert a.shape == b.shape == (2, 2)
    assert a.clauses != b.clauses


def test_include_exclude_compiles_to_single_pass():
    p = from_include_exclude([2, 4], [5])
    assert plan(p).clauses == (((2, False), (4, False), (5, True)),)
    with pytest.raises(ValueError):
        from_include_exclude([], [])


# ------------------------------------------- planner: differential execution
@pytest.mark.parametrize("n,m", [(32, 32), (19, 37), (50, 5), (200, 12)])
def test_random_trees_pallas_vs_ref_bit_identical(n, m):
    """Acceptance: random predicate trees, non-32-aligned N and M, identical
    packed result and count across backends, both matching dense eval."""
    records, keys = _random_index(n, m)
    idx = backends.get_backend("ref").create_index(records, keys)
    dense = ref.unpack_bits(idx, n)
    rng = np.random.default_rng(n * 1000 + m)
    for _ in range(8):
        pred = _random_pred(rng, m, depth=3)
        r_ref, c_ref = execute(idx, pred, num_records=n, backend="ref")
        r_pal, c_pal = execute(idx, pred, num_records=n, backend="pallas")
        np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_pal))
        assert int(c_ref) == int(c_pal)
        want = np.asarray(evaluate_dense(pred, dense))
        got = np.asarray(ref.unpack_bits(r_ref[None], n))[0].astype(bool)
        np.testing.assert_array_equal(got, want)
        assert int(c_ref) == int(want.sum())


def test_all_inverted_operands_hit_pad_guard():
    """Every operand inverted + non-aligned N: inverted rows turn the pad
    words all-ones; the kernel pad-guard must zero them again."""
    n, m = 45, 6
    records, keys = _random_index(n, m)
    idx = backends.get_backend("ref").create_index(records, keys)
    pred = And(tuple(~key(i) for i in range(m)))
    for backend in ("ref", "pallas"):
        row, cnt = execute(idx, pred, num_records=n, backend=backend)
        want = np.asarray(evaluate_dense(pred, ref.unpack_bits(idx, n)))
        got = np.asarray(ref.unpack_bits(row[None], n))[0].astype(bool)
        np.testing.assert_array_equal(got, want)
        assert int(cnt) == int(want.sum())
        # tail bits past n must be zero even though every operand inverted
        tail = np.asarray(ref.unpack_bits(row[None], row.shape[0] * 32))[0]
        assert tail[n:].sum() == 0


def test_out_of_range_key_raises():
    """A typo'd key id must raise, not silently gather-clamp to the last
    index row."""
    records, keys = _random_index(40, 4)
    idx = backends.get_backend("ref").create_index(records, keys)
    with pytest.raises(ValueError, match=r"\[99\] out of range"):
        execute(idx, key(99), num_records=40)
    with pytest.raises(ValueError, match="out of range"):
        execute(idx, key(0) & ~key(-1), num_records=40)
    # a typo buried in a branch normalization simplifies away still raises
    with pytest.raises(ValueError, match=r"\[99\] out of range"):
        execute(idx, (key(99) & ~key(99)) | key(1), num_records=40)
    with pytest.raises(ValueError, match=r"\[99\] out of range"):
        execute(idx, key(1) | (key(1) & key(99)), num_records=40)


def test_contradiction_executes_without_kernel_pass():
    records, keys = _random_index(40, 4)
    idx = backends.get_backend("ref").create_index(records, keys)
    row, cnt = execute(idx, key(0) & ~key(0), num_records=40)
    assert int(cnt) == 0
    assert np.asarray(row).sum() == 0


def test_executor_jit_cache_reuses_same_shape():
    records, keys = _random_index(64, 16)
    idx = backends.get_backend("ref").create_index(records, keys)
    before = planner.compiled_plan_cache_info().currsize
    execute(idx, (key(1) | key(2)) & key(3), num_records=64, backend="ref")
    mid = planner.compiled_plan_cache_info()
    # same plan shape, different key ids -> cache hit, no new executor
    execute(idx, (key(9) | key(4)) & key(7), num_records=64, backend="ref")
    after = planner.compiled_plan_cache_info()
    assert mid.currsize >= before
    assert after.currsize == mid.currsize
    assert after.hits > mid.hits


def test_biccore_query_where_matches_include_exclude():
    records, keys = _random_index(30, 8)
    core = BICCore(BICConfig(num_keys=8, num_records=30, words_per_record=8,
                             backend="ref"))
    bi = core.create(records, keys)
    r1, c1 = core.query(bi, include=[2, 4], exclude=[5])
    r2, c2 = core.query(bi, where=key(2) & key(4) & ~key(5))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert int(c1) == int(c2)
    with pytest.raises(ValueError):
        core.query(bi, include=[1], where=key(1))


# --------------------------------------------------------- streaming append
@pytest.mark.parametrize("blocks", [
    [16, 16], [7, 32, 19, 1, 64], [31, 1, 33], [5],
])
def test_incremental_append_matches_rebuild(blocks):
    """Acceptance: appending block-by-block == indexing everything at once,
    including non-32-aligned intermediate record counts."""
    m, w = 21, 6
    keys = jnp.asarray(RNG.integers(0, 32, (m,), dtype=np.int32))
    si = StreamingIndexer(keys, backend="ref")
    all_blocks = []
    for b in blocks:
        blk = jnp.asarray(RNG.integers(0, 32, (b, w), dtype=np.int32))
        all_blocks.append(blk)
        si.append(blk)
        # the live index is consistent after EVERY append, not just the last
        n_so_far = sum(x.shape[0] for x in all_blocks)
        rebuilt = backends.get_backend("ref").create_index(
            jnp.concatenate(all_blocks, axis=0), keys)
        np.testing.assert_array_equal(np.asarray(si.index.packed),
                                      np.asarray(rebuilt))
        assert si.num_records == n_so_far


def test_append_packed_is_pure_splice():
    m = 4
    a = jnp.asarray(RNG.integers(0, 2 ** 32, (m, 2), dtype=np.uint32))
    n_a = 45                                    # unaligned tail
    a = a & jnp.asarray(ref.pack_bits(
        (jnp.arange(64) < n_a).astype(jnp.uint32)).reshape(1, 2))
    b_bits = RNG.integers(0, 2, (m, 23)).astype(np.uint32)
    b = ref.pack_bits(jnp.asarray(np.pad(b_bits, ((0, 0), (0, 9)))))
    out = append_packed(a, n_a, b, 23)
    dense_a = np.asarray(ref.unpack_bits(a, n_a))
    dense_out = np.asarray(ref.unpack_bits(out, n_a + 23))
    np.testing.assert_array_equal(dense_out[:, :n_a], dense_a)
    np.testing.assert_array_equal(dense_out[:, n_a:], b_bits)


# --------------------------------------------------------- multicore runtime
def _one_device_mesh():
    import jax
    return jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def test_multicore_runtime_fuses_energy_and_execution():
    mesh = _one_device_mesh()
    rt = MulticoreRuntime(mesh, backend="ref")
    keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    ticks = []
    for wl in (4, 0, 2):
        ticks.append(None if wl == 0 else jnp.asarray(
            RNG.integers(0, 256, (wl, 16, 32), dtype=np.int32)))
    outs, report = rt.index_stream(ticks, keys, tick_seconds=0.01)
    assert len(outs) == 2                       # idle tick produced no work
    assert outs[0].shape == (4, 8, 1)
    assert report.batches == 6
    assert report.active_joules > 0
    assert report.standby_joules > 0            # the idle tick was accounted
    # the indexes it produced match the single-core engine build
    core = BICCore(BICConfig(backend="ref"))
    for z in range(4):
        want = core.create(ticks[0][z], keys).packed
        np.testing.assert_array_equal(np.asarray(outs[0][z]),
                                      np.asarray(want))


_NON_DIVISIBLE_SCRIPT = """
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.engine.runtime import multicore_create_index
from repro.core.bic import BICCore, BICConfig
assert len(jax.devices()) == 4, jax.devices()
mesh = jax.make_mesh((4,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(3)
keys = jnp.asarray(rng.integers(0, 256, (8,), dtype=np.int32))
rec = jnp.asarray(rng.integers(0, 256, (6, 16, 32), dtype=np.int32))
out = multicore_create_index(rec, keys, mesh, backend="ref")   # 6 % 4 != 0
assert out.shape == (6, 8, 1), out.shape
core = BICCore(BICConfig(backend="ref"))
for z in range(6):
    want = core.create(rec[z], keys).packed
    np.testing.assert_array_equal(np.asarray(out[z]), np.asarray(want))
print("OK")
"""


def test_multicore_handles_non_divisible_batch_counts():
    """Workload sizes that don't divide the mesh axis pad for dispatch and
    slice back.  The pad branch only exists for >1 device, so this runs in
    a subprocess with a forced 4-device CPU platform (same trick as
    launch/dryrun.py)."""
    import os
    import subprocess
    import sys as _sys
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([_sys.executable, "-c", _NON_DIVISIBLE_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_multicore_create_index_backend_dispatch():
    mesh = _one_device_mesh()
    rec = jnp.asarray(RNG.integers(0, 256, (2, 16, 32), dtype=np.int32))
    keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
    a = multicore_create_index(rec, keys, mesh, backend="ref")
    b = multicore_create_index(rec, keys, mesh, backend="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
