"""Acceptance suite for `repro.fabric` — the distributed shard fabric.

Covers the wire codec (typed round-trips, CRC/version rejection, query
trees), the shard map (routing, partition coverage, predicate pruning),
the loopback fabric's bit-identity against a single-node session (hash
AND block partitioning, scatter pruning, provably-empty short-circuit),
exactly-once fabric appends, the cluster manifest (atomic swap, gid
tables, replica sync + rebalance as segment handoff), close() semantics
(idempotent + concurrent with in-flight submits, client and service),
error isolation inside a scattered wave, the observability roll-up, and
the shared indexing⇄serving duty cycle (`attach_runtime`/`run_tick` on
ONE energy ledger).
"""
import threading

import numpy as np
import pytest

from repro.db import BitmapDB, Column, Schema, col
from repro.db import expr as expr_mod
from repro.db.result import unpack_ids
from repro.engine.planner import And, Key, Not, Or, key
from repro.fabric import cluster
from repro.fabric.client import FabricClient, FabricError, FabricFuture
from repro.fabric.envelope import (Envelope, WireError, decode, encode,
                                   query_from_wire, query_to_wire)
from repro.fabric.protocol import ServiceHost
from repro.fabric.shardmap import ShardMap
from repro.fabric.transport import LoopbackTransport
from repro.serve.service import BitmapService, ServiceClosed, ServiceConfig

RNG = np.random.default_rng(7)
M = 16
HALF = M // 2


# ----------------------------------------------------------------- fixtures
def _schema() -> Schema:
    return Schema([Column.categorical("a", list(range(HALF))),
                   Column.categorical("b", list(range(HALF, M)))])


def _records(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, HALF, n, dtype=np.int32),
                     rng.integers(HALF, M, n, dtype=np.int32)], axis=1)


def _queries():
    return [col("a") == 3,
            (col("a") == 1) & ~(col("b") == 9),
            (col("a") == 2) | (col("b") == 12),
            key(0), key(5) & ~key(11),
            col("b").isin([8, 9, 10]),
            ~(col("a") == 4)]


def _single_node(records) -> BitmapDB:
    db = BitmapDB(_schema(), backend="ref")
    db.append_encoded(records)
    return db


def _mk_fabric(sm: ShardMap, records, *, replicas: int = 1, **kw
               ) -> FabricClient:
    """Pre-partitioned local fabric: one (or `replicas`) BitmapDB per
    shard holding its records, gid tables from the partition."""
    parts = {s: (recs, g) for s, recs, g in sm.partition(records)}
    stores, gids = [], []
    for s in range(sm.num_shards):
        recs, g = parts.get(
            s, (np.zeros((0, records.shape[1]), np.int32),
                np.zeros(0, np.int64)))
        group = []
        for _ in range(replicas):
            db = BitmapDB(_schema(), backend="ref")
            if recs.shape[0]:
                db.append_encoded(recs)
            group.append(db)
        stores.append(group if replicas > 1 else group[0])
        gids.append(g)
    kw.setdefault("max_delay_ms", 1.0)
    return FabricClient.local(stores, sm, gids=gids, **kw)


def _trim(row, n: int) -> np.ndarray:
    w = (n + 31) >> 5
    out = np.zeros(w, np.uint32)
    row = np.asarray(row, np.uint32).reshape(-1)[:w]
    out[:row.shape[0]] = row
    return out


# ------------------------------------------------------------------- codec
def test_envelope_roundtrip_all_types():
    arr = RNG.integers(0, 1 << 30, (3, 5), dtype=np.int32)
    env = Envelope("query", msg_id=42, trace=(123, 456), payload={
        "none": None, "t": True, "f": False, "i": -7,
        "big": 2**75 + 3, "fl": 1.5, "s": "héllo", "by": b"\x00\xff",
        "l": [1, [2, "x"]], "tu": (1, 2), "nested": {"k": [None, 0.25]},
        "arr": arr, "u64": np.uint64(2**63 + 1),
        "f32": np.asarray([0.5, -2.0], np.float32)})
    out = decode(encode(env))
    assert out.kind == "query" and out.msg_id == 42
    assert out.trace == (123, 456)
    p = out.payload
    assert p["none"] is None and p["t"] is True and p["f"] is False
    assert p["i"] == -7 and p["big"] == 2**75 + 3 and p["fl"] == 1.5
    assert p["s"] == "héllo" and p["by"] == b"\x00\xff"
    assert p["l"] == [1, [2, "x"]] and p["tu"] == (1, 2)
    assert p["nested"] == {"k": [None, 0.25]}
    np.testing.assert_array_equal(p["arr"], arr)
    assert p["arr"].dtype == np.int32
    assert p["u64"] == 2**63 + 1
    np.testing.assert_array_equal(
        p["f32"], np.asarray([0.5, -2.0], np.float32))


def test_envelope_rejects_corruption_and_skew():
    frame = bytearray(encode(Envelope("ping")))
    frame[-1] ^= 0x40                       # flip a body bit -> CRC
    with pytest.raises(WireError):
        decode(bytes(frame))
    frame = bytearray(encode(Envelope("ping")))
    frame[4] ^= 0x01                        # version byte
    with pytest.raises(WireError):
        decode(bytes(frame))
    with pytest.raises(WireError):
        decode(b"\x01\x02")                 # shorter than the header
    with pytest.raises(TypeError):
        encode(Envelope("x", payload={"bad": object()}))
    with pytest.raises(TypeError):
        encode(Envelope("x", payload={1: "non-str dict key"}))


def test_query_wire_roundtrip_rebuilds_exact_objects():
    preds = [key(3), Not(key(1)), And((key(0), Not(key(2)))),
             Or((key(4), And((key(5), key(6)))))]
    exprs = [col("a") == 3, (col("a") == 1) & ~(col("b") == 9),
             col("b").isin([8, 9]), (col("a") == 0) | (col("a") == 2)]
    for q in preds + exprs:
        back = query_from_wire(query_to_wire(q))
        assert back == q
        assert type(back) is type(q)
    with pytest.raises(TypeError):
        query_to_wire({"not": "a query"})
    with pytest.raises(WireError):
        query_from_wire(["bogus-tag", 1])


# ---------------------------------------------------------------- shard map
def test_shardmap_partition_covers_every_record():
    schema = _schema()
    recs = _records(500, seed=3)
    for sm in (ShardMap.hashed(schema, "a", 3, seed=9),
               ShardMap.blocked(3, total_records=500)):
        parts = sm.partition(recs, start_gid=0)
        seen = np.concatenate([g for _, _, g in parts])
        assert sorted(seen.tolist()) == list(range(500))
        for s, local, g in parts:
            np.testing.assert_array_equal(local, recs[g])
            assert np.all(sm.route(local, start_gid=0) == s) \
                or sm.strategy == "block"
    # hash routing is a pure function of the key word
    sm = ShardMap.hashed(schema, "a", 3, seed=9)
    r1 = sm.route(recs)
    r2 = sm.route(recs)
    np.testing.assert_array_equal(r1, r2)
    for v in range(HALF):
        ix = np.flatnonzero(recs[:, 0] == v)
        assert len(set(r1[ix].tolist())) <= 1


def test_shardmap_owner_pruning():
    sm = ShardMap.hashed(_schema(), "a", 4, seed=1)
    # a key on the sharded column prunes to exactly its owner
    for v in range(HALF):
        assert sm.owners(key(v)) == frozenset((sm.shard_of_key(v),))
    # a key on the other column cannot prune
    assert sm.owners(key(HALF + 1)) is None
    # Not never prunes; And intersects; Or unions
    assert sm.owners(Not(key(0))) is None
    a, b = 0, 1
    sa, sb = sm.shard_of_key(a), sm.shard_of_key(b)
    assert sm.owners(Or((key(a), key(b)))) == frozenset((sa, sb))
    assert sm.owners(And((key(a), key(HALF + 2)))) == frozenset((sa,))
    if sa != sb:                    # contradiction on the sharded column
        assert sm.owners(And((key(a), key(b)))) == frozenset()
    # block strategy: no pruning at all
    assert ShardMap.blocked(4, block_size=8).owners(key(0)) is None


def test_shardmap_json_roundtrip():
    for sm in (ShardMap.hashed(_schema(), "b", 5, seed=77),
               ShardMap.blocked(2, block_size=64)):
        assert ShardMap.from_json(sm.to_json()) == sm
    with pytest.raises(ValueError):
        ShardMap(num_shards=0)
    with pytest.raises(ValueError):
        ShardMap(num_shards=2, strategy="block", block_size=0)


# ------------------------------------------------- loopback fabric identity
@pytest.mark.parametrize("make_sm", [
    lambda n: ShardMap.hashed(_schema(), "a", 3, seed=5),
    lambda n: ShardMap.blocked(3, total_records=n)],
    ids=["hash", "block"])
def test_fabric_bit_identical_to_single_node(make_sm):
    recs = _records(700, seed=11)
    single = _single_node(recs)
    sm = make_sm(700)
    with _mk_fabric(sm, recs) as fc:
        assert fc.num_records == 700
        futs = [fc.submit(q) for q in _queries()]
        cfuts = [fc.submit(q, count_only=True) for q in _queries()]
        for q, fut, cfut in zip(_queries(), futs, cfuts):
            want = single.query(q)
            row, count = fut.result(timeout=30)
            assert count == want.count == cfut.result(timeout=30)[1]
            np.testing.assert_array_equal(
                _trim(row, 700), _trim(want.rows, 700))
            np.testing.assert_array_equal(fut.ids, want.ids)
            assert cfut.result()[0] is None


def test_fabric_pruned_scatter_touches_only_owner_shard():
    recs = _records(300, seed=2)
    sm = ShardMap.hashed(_schema(), "a", 4, seed=3)
    with _mk_fabric(sm, recs) as fc:
        v = 3
        owner = sm.shard_of_key(v)
        want = _single_node(recs).query(col("a") == v)
        fut = fc.submit(col("a") == v)
        assert fut.count == want.count
        served = [s["served"] for s in fc.metrics()["shards"]]
        for s in range(4):
            assert served[s] == (1 if s == owner else 0)


def test_fabric_provably_empty_resolves_without_scatter():
    recs = _records(200, seed=4)
    sm = ShardMap.hashed(_schema(), "a", 4, seed=6)
    a, b = 1, 2
    if sm.shard_of_key(a) == sm.shard_of_key(b):
        b = next(v for v in range(HALF)
                 if sm.shard_of_key(v) != sm.shard_of_key(a))
    with _mk_fabric(sm, recs) as fc:
        fut = fc.submit(And((key(a), key(b))))
        row, count = fut.result(timeout=10)
        assert count == 0 and not row.any()
        assert fut.ids.size == 0
        assert all(s["served"] == 0 for s in fc.metrics()["shards"])


def test_fabric_append_routes_and_stays_identical():
    schema = _schema()
    sm = ShardMap.hashed(schema, "a", 3, seed=8)
    stores = [BitmapDB(schema, backend="ref") for _ in range(3)]
    single = BitmapDB(schema, backend="ref")
    with FabricClient.local(stores, sm, max_delay_ms=1.0) as fc:
        total = 0
        for i in range(4):
            batch = _records(150 + 31 * i, seed=20 + i)
            total += batch.shape[0]
            assert fc.append_encoded(batch) == total
            single.append_encoded(batch)
        assert fc.num_records == total
        assert sum(p["num_records"] for p in fc.info()) == total
        for q in _queries():
            want = single.query(q)
            fut = fc.submit(q)
            row, count = fut.result(timeout=30)
            assert count == want.count
            np.testing.assert_array_equal(
                _trim(row, total), _trim(want.rows, total))
        # gid tables partition the global ordinal space exactly
        allg = np.concatenate([fc.gids(s) for s in range(3)])
        assert sorted(allg.tolist()) == list(range(total))


def test_fabric_append_rows_through_schema():
    schema = _schema()
    sm = ShardMap.hashed(schema, "a", 2, seed=1)
    with FabricClient.local([BitmapDB(schema, backend="ref")
                             for _ in range(2)], sm,
                            max_delay_ms=1.0) as fc:
        enc = _records(64, seed=5)
        rows = [{"a": int(r[0]), "b": int(r[1])} for r in enc]
        try:
            fc.append(rows)
        except (TypeError, KeyError, ValueError):
            # schema row format differs across revisions — the encoded
            # path above is the contract under test
            fc.append_encoded(enc)
        assert fc.num_records == 64


# ------------------------------------------------------------- error paths
def test_wave_error_isolation_per_query():
    recs = _records(100, seed=9)
    sm = ShardMap.blocked(2, total_records=100)
    with _mk_fabric(sm, recs) as fc:
        good = fc.submit(col("a") == 1)
        bad = fc.submit(key(10_000))    # fails shard-side at execution
        good2 = fc.submit(col("b") == 9)
        err = bad.exception(timeout=30)
        assert isinstance(err, FabricError)
        assert "ValueError" in str(err)
        want = _single_node(recs)
        assert good.count == want.query(col("a") == 1).count
        assert good2.count == want.query(col("b") == 9).count
        # an expression the schema cannot lower fails AT THE CLIENT —
        # before anything crosses the wire
        with pytest.raises(KeyError):
            fc.submit(expr_mod.Eq("nope", 1))


def test_host_replies_error_envelope_on_garbage():
    svc = BitmapService(_single_node(_records(32)),
                        ServiceConfig(max_delay_ms=1.0,
                                      maintenance=False))
    host = ServiceHost(svc, shard_id=7)
    t = LoopbackTransport(host, name="t")
    try:
        assert t.request(Envelope("ping"), timeout=5).payload[
            "shard_id"] == 7
        r = t.request(Envelope("definitely-not-a-kind"), timeout=5)
        assert r.kind == "error"
        r = t.request(Envelope("query", payload={
            "queries": [["bogus-tag", 1]], "count_only": False}),
            timeout=5)
        assert r.kind == "error" and "bogus" in r.payload["error"]
    finally:
        t.close()
        host.close()


def test_append_stream_gap_is_refused():
    svc = BitmapService(BitmapDB(_schema(), backend="ref"),
                        ServiceConfig(max_delay_ms=1.0,
                                      maintenance=False))
    host = ServiceHost(svc)
    t = LoopbackTransport(host)
    try:
        recs = _records(8)
        ok = t.request(Envelope("append", payload={
            "stream": "s", "seq": 1, "records": recs}), timeout=5)
        assert ok.kind == "appended" and not ok.payload["duplicate"]
        dup = t.request(Envelope("append", payload={
            "stream": "s", "seq": 1, "records": recs}), timeout=5)
        assert dup.payload["duplicate"] \
            and dup.payload["num_records"] == 8
        gap = t.request(Envelope("append", payload={
            "stream": "s", "seq": 3, "records": recs}), timeout=5)
        assert gap.kind == "error" and gap.payload["type"] == "GapError"
    finally:
        t.close()
        host.close()


# -------------------------------------------------------- cluster manifest
def test_cluster_manifest_swap_and_gids(tmp_path):
    root = str(tmp_path / "cluster")
    assert cluster.load(root) is None
    sm = ShardMap.hashed(_schema(), "a", 2, seed=4)
    gids0 = np.arange(0, 10, 2, dtype=np.int64)
    name = cluster.save_gids(root, 0, 1, gids0)
    m = cluster.ClusterManifest(
        version=1, shardmap=sm,
        shards=(cluster.ShardEntry(0, ("storeA",), num_records=5,
                                   gids_file=name),
                cluster.ShardEntry(1, ("storeB", "storeC"))))
    cluster.commit(root, m)
    back = cluster.load(root)
    assert back == m and back.num_records == 5
    np.testing.assert_array_equal(
        cluster.load_gids(root, back.shard(0)), gids0)
    assert cluster.load_gids(root, back.shard(1)).size == 0
    # with_shard bumps the version; commit atomically repoints CURRENT
    m2 = m.with_shard(cluster.ShardEntry(1, ("storeB",),
                                         num_records=3))
    assert m2.version == 2
    cluster.commit(root, m2)
    assert cluster.load(root) == m2
    assert cluster.load(root).shard(0) == m.shard(0)   # untouched entry
    with pytest.raises(KeyError):
        m2.shard(9)


def test_cluster_manifest_validate_rejects_bad_membership():
    sm = ShardMap.blocked(2, block_size=4)
    from repro.store.format import CorruptFileError
    with pytest.raises(CorruptFileError):
        cluster.ClusterManifest(
            version=1, shardmap=sm,
            shards=(cluster.ShardEntry(0, ("x",)),)).validate()
    with pytest.raises(CorruptFileError):
        cluster.ClusterManifest(
            version=1, shardmap=sm,
            shards=(cluster.ShardEntry(0, ("x",)),
                    cluster.ShardEntry(1, ()))).validate()


def _durable_store(root: str, seed: int) -> int:
    """A shard store with committed segments on disk; returns its
    record count."""
    db = BitmapDB(_schema(), path=root, spill_records=64,
                  backend="ref")
    n = 0
    for i in range(3):
        batch = _records(64, seed=seed + i)
        db.append_encoded(batch)        # spill threshold -> segments
        n += 64
    db.store.close()
    return n


def test_sync_store_is_idempotent_segment_handoff(tmp_path):
    src = str(tmp_path / "src")
    dst = str(tmp_path / "dst")
    n = _durable_store(src, seed=31)
    shipped = cluster.sync_store(src, dst)
    assert shipped > 0
    assert cluster.sync_store(src, dst) == 0        # idempotent
    from repro.db.session import open_db
    a = open_db(src)
    b = open_db(dst)
    try:
        assert a.num_records == b.num_records == n
        for q in _queries():
            ra, rb = a.query(q), b.query(q)
            assert ra.count == rb.count
            np.testing.assert_array_equal(
                _trim(ra.rows, n), _trim(rb.rows, n))
    finally:
        a.store.close()
        b.store.close()


def test_rebalance_commits_one_manifest_version(tmp_path):
    root = str(tmp_path / "cluster")
    srcA = str(tmp_path / "a")
    srcB = str(tmp_path / "b")
    new = str(tmp_path / "new")
    _durable_store(srcA, seed=41)
    _durable_store(srcB, seed=43)
    sm = ShardMap.blocked(2, block_size=192)
    m = cluster.ClusterManifest(
        version=1, shardmap=sm,
        shards=(cluster.ShardEntry(0, (srcA,)),
                cluster.ShardEntry(1, (srcB,))))
    cluster.commit(root, m)
    m2 = cluster.rebalance(root, m, 1, new)
    assert m2.version == 2
    assert m2.shard(1).replicas == (srcB, new)
    assert cluster.load(root) == m2
    m3 = cluster.rebalance(root, m2, 1, new, drop=srcB)
    assert m3.shard(1).replicas == (new,)
    # rebalancing a shard onto its own store is a harmless no-op sync
    m4 = cluster.rebalance(root, m3, 1, new)
    assert m4.shard(1).replicas == (new,)


# ---------------------------------------------------------- close semantics
def test_client_close_idempotent_and_reentrant():
    recs = _records(64, seed=1)
    sm = ShardMap.blocked(2, total_records=64)
    fc = _mk_fabric(sm, recs)
    assert fc.submit(key(0)).wait(10)
    fc.close()
    fc.close()                                  # no-op, no raise
    with pytest.raises(ServiceClosed):
        fc.submit(key(0))


def test_client_close_concurrent_with_submit_storm():
    recs = _records(256, seed=12)
    sm = ShardMap.hashed(_schema(), "a", 2, seed=2)
    fc = _mk_fabric(sm, recs)
    futs: list[FabricFuture] = []
    flock = threading.Lock()
    stop = threading.Event()

    def submitter():
        while not stop.is_set():
            try:
                f = fc.submit(key(int(RNG.integers(0, M))))
            except ServiceClosed:
                return
            with flock:
                futs.append(f)

    subs = [threading.Thread(target=submitter) for _ in range(4)]
    for s in subs:
        s.start()
    closers = [threading.Thread(target=fc.close) for _ in range(3)]
    for c in closers:
        c.start()
    stop.set()
    for t in closers + subs:
        t.join(timeout=60)
        assert not t.is_alive()
    # every accepted future resolved exactly one way — none hang
    for f in futs:
        assert f.wait(timeout=30)
        assert f.done()
        if f.exception() is not None:
            assert isinstance(f.exception(),
                              (ServiceClosed, FabricError))


def test_service_close_idempotent_and_concurrent():
    svc = BitmapService(_single_node(_records(128, seed=3)),
                        ServiceConfig(max_delay_ms=1.0,
                                      maintenance=False))
    futs = [svc.submit(key(i % M)) for i in range(32)]
    errs = []

    def closer():
        try:
            svc.close(timeout=30)
        except BaseException as e:      # noqa: BLE001 — fail the test
            errs.append(e)

    cs = [threading.Thread(target=closer) for _ in range(4)]
    for c in cs:
        c.start()
    for c in cs:
        c.join(timeout=60)
        assert not c.is_alive()
    assert not errs
    for f in futs:
        assert f.wait(timeout=30)
    svc.close()                                 # still a no-op


# -------------------------------------------------------- shared duty cycle
def test_attach_runtime_shares_one_ledger_and_duty_cycle():
    import jax
    import jax.numpy as jnp
    from repro.engine.runtime import MulticoreRuntime

    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rt = MulticoreRuntime(mesh, backend="ref")
    own_ledger = rt.ledger
    svc = BitmapService(_single_node(_records(128, seed=6)),
                        ServiceConfig(max_delay_ms=1.0,
                                      maintenance=False,
                                      idle_after_ms=10_000.0))
    try:
        with pytest.raises(RuntimeError):
            svc.run_tick(None, jnp.zeros(8, jnp.int32), 0.01)
        assert svc.attach_runtime(rt) is svc
        assert rt.ledger is svc._ledger
        assert rt.ledger is not own_ledger
        keys = jnp.asarray(RNG.integers(0, 256, (8,), dtype=np.int32))
        ticks = [jnp.asarray(RNG.integers(0, 256, (2, 16, 32),
                                          dtype=np.int32)),
                 None,
                 jnp.asarray(RNG.integers(0, 256, (1, 16, 32),
                                          dtype=np.int32))]
        before = svc._ledger.snapshot()["total_joules"]
        for t in ticks:
            out = svc.run_tick(t, keys, tick_seconds=0.01)
            assert out is not None
        snap = svc._ledger.snapshot()
        # the ticks' joules entered the SERVICE ledger
        assert snap["total_joules"] > before
        # a non-idle tick with nothing queued parks the service back in
        # standby — one duty cycle across indexing and serving
        assert svc._state == "standby"
        m = svc.metrics()
        assert m.wakes >= 1 and m.standby_entries >= 1
        # serving still works after ticks, and wakes the duty cycle
        fut = svc.submit(key(0))
        assert fut.wait(10) and fut.result()[1] >= 0
    finally:
        svc.close()


def test_fabric_metrics_energy_rollup_sums_shards():
    recs = _records(200, seed=14)
    sm = ShardMap.blocked(3, total_records=200)
    with _mk_fabric(sm, recs) as fc:
        for q in _queries():
            fc.submit(q)
        assert fc.drain(timeout=30)
        m = fc.metrics()
        assert m["served"] == len(_queries())
        assert m["num_shards"] == 3 and len(m["shards"]) == 3
        per = m["energy"]["per_shard"]
        assert len(per) == 3
        total = sum(e.get("total_joules", 0.0) for e in per)
        assert m["energy"]["total_joules"] == pytest.approx(total)
        assert m["energy"]["total_joules"] > 0
        h = fc.health()
        assert not h["degraded"] and len(h["shards"]) == 3
        assert fc.drain_shards(timeout_s=30)
        stats = fc.transport_stats()
        assert [len(g) for g in stats] == [1, 1, 1]
        assert all(t["pending"] == 0 for g in stats for t in g)


def test_fabric_future_surface_matches_query_future():
    recs = _records(96, seed=15)
    sm = ShardMap.blocked(2, total_records=96)
    single = _single_node(recs)
    with _mk_fabric(sm, recs) as fc:
        fut = fc.submit(col("a") == 2)
        row, count = fut.result(timeout=10)
        want = single.query(col("a") == 2)
        assert fut.done() and fut.exception() is None
        assert count == want.count == fut.count
        np.testing.assert_array_equal(fut.ids, want.ids)
        np.testing.assert_array_equal(
            unpack_ids(_trim(fut.rows, 96), 96), want.ids)
        assert "done" in repr(fut)


# ------------------------------------------------------- data-plane routing
def test_pipeline_select_global_matches_per_shard():
    """The training pipeline's fabric plane: one scatter/merge over all
    corpus shards returns the same document set as the per-shard
    ``select`` loop, with gids offset by ``shard * docs_per_shard``."""
    from repro.data.pipeline import BitmapIndexedDataset, DataConfig

    cfg = DataConfig(vocab_size=64, seq_len=8, docs_per_shard=128,
                     num_shards=3, num_attributes=32, seed=5)
    ds = BitmapIndexedDataset(cfg)
    try:
        wheres = [col("domain").isin([0, 1]) & ~(col("quality") == 3),
                  col("lang") == 2,
                  key(5) | key(20)]
        got = ds.select_global(wheres)
        for q, ids in zip(wheres, got):
            want = np.concatenate(
                [s * cfg.docs_per_shard + ds.select(s, where=q)
                 for s in range(cfg.num_shards)]).astype(np.int64)
            np.testing.assert_array_equal(ids, want)
        assert ds.fabric() is ds.fabric()      # one client, cached
    finally:
        ds.close()
    assert ds._fabric is None                  # close() tears it down
