"""Logical-axis sharding rules: divisibility fallback, no-double-assign,
tuple-axis filtering, and spec coverage for every arch's param tree."""
import os

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models.model import abstract_params, cache_logical, param_logical
from repro.parallel.sharding import logical_spec


@pytest.fixture(scope="module")
def meshes():
    # 4x2 toy mesh shaped like (data, model); pod variant 2x2x2.
    sp = jax.make_mesh((1,), ("data",),
                       axis_types=(jax.sharding.AxisType.Auto,))
    return sp


def test_divisibility_fallback():
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    with jax.set_mesh(mesh):
        # data axis size 1 -> everything divisible, sharded on 'data'
        assert logical_spec((8, 16), ("batch", None)) == P("data", None)
    # no mesh context -> fully unsharded
    assert logical_spec((8, 16), ("batch", None)) == P(None, None)


def test_no_mesh_axis_used_twice():
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    with jax.set_mesh(mesh):
        # both "batch" and "fsdp" map to data; only the first may take it
        spec = logical_spec((4, 4), ("batch", "fsdp"))
        assert spec == P("data", None)


def test_param_logical_covers_all_params():
    """Every param leaf must have a logical-name tuple of matching rank."""
    for arch in ARCHS:
        cfg = get_config(arch)
        params = abstract_params(cfg)
        logical = param_logical(cfg)
        assert set(params) == set(logical), arch
        for k, p in params.items():
            assert len(logical[k]) == len(p.shape), (arch, k)


def test_cache_logical_ranks():
    from repro.models.model import init_cache
    for arch in ARCHS:
        cfg = get_config(arch)
        cache = init_cache(cfg, batch=2, max_len=8, abstract=True)
        names = cache_logical(cfg)
        for k, v in cache.items():
            if k == "pos":
                continue
            assert len(names[k]) == len(v.shape), (arch, k)
