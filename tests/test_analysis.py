"""Tests for ``repro.analysis``: each checker against a synthetic tree
containing exactly one planted violation (and its fixed twin), the
runtime lock witness's pair logic, and the real tree against the
committed baseline — the lint gate CI enforces."""
import os
import subprocess
import sys
import textwrap
import threading

import pytest

from repro.analysis import Baseline, Finding, checker, find_repo_root, run
from repro.analysis.core import default_baseline_path
from repro.analysis import witness as witness_mod

REPO_ROOT = find_repo_root(os.path.dirname(__file__))

# Both machine-parsed tables, minimal: two ranked locks for the locks
# checker, two span rows for the taxonomy checker.
_ARCH = textwrap.dedent("""\
    # Synthetic architecture

    ## Lock hierarchy

    | rank | lock | owner | may nest inside |
    |---|---|---|---|
    | 10 | `Outer._lock` | m.py | nothing |
    | 20 | `Inner._lock` | m.py | rank 10 |

    ## Observability

    | span | scope | meaning |
    |---|---|---|
    | `query` | per query | one query |
    | `flush.*` | per flush | one flush |
    """)


def _mk_tree(tmp_path, files: dict, arch: str = _ARCH) -> str:
    root = tmp_path / "synthetic"
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    (root / "ARCHITECTURE.md").write_text(arch)
    return str(root)


# ------------------------------------------------------------------- locks
_LOCK_INVERSION = """\
    import threading


    class Outer:
        def __init__(self):
            self._lock = threading.Lock()


    class Inner:
        def __init__(self):
            self._lock = threading.Lock()
            self.outer = Outer()

        def bad(self):
            with self._lock:
                with self.outer._lock:
                    pass
    """


def test_locks_flags_planted_inversion(tmp_path):
    root = _mk_tree(tmp_path, {"src/repro/serve/m.py": _LOCK_INVERSION})
    found = run(root, ["locks"])
    assert len(found) == 1, [f.render() for f in found]
    assert found[0].rule == "inversion"
    assert "Inner._lock" in found[0].symbol
    assert "Outer._lock" in found[0].symbol


def test_locks_correct_order_is_clean(tmp_path):
    good = _LOCK_INVERSION.replace(
        "with self._lock:\n                with self.outer._lock:",
        "with self.outer._lock:\n                with self._lock:")
    root = _mk_tree(tmp_path, {"src/repro/serve/m.py": good})
    assert run(root, ["locks"]) == []


def test_locks_flags_undocumented_lock_in_nesting(tmp_path):
    src = """\
        import threading


        class Outer:
            def __init__(self):
                self._lock = threading.Lock()


        class Rogue:
            def __init__(self):
                self._lock = threading.Lock()      # not in the table
                self.outer = Outer()

            def use(self):
                with self.outer._lock:
                    with self._lock:
                        pass
        """
    root = _mk_tree(tmp_path, {"src/repro/serve/m.py": src})
    found = run(root, ["locks"])
    assert [f.rule for f in found] == ["unranked"], \
        [f.render() for f in found]
    assert "Rogue._lock" in found[0].symbol


# ------------------------------------------------------------------- seams
def test_seams_flags_raw_fsync_once(tmp_path):
    src = """\
        import os


        def bad_sync(fd):
            os.fsync(fd)


        def good_sync(fd, seam):
            seam.fire("store.sync")
            os.fsync(fd)
        """
    root = _mk_tree(tmp_path, {"src/repro/store/badio.py": src})
    found = run(root, ["seams"])
    assert len(found) == 1, [f.render() for f in found]
    assert found[0].rule == "unseamed-io"
    assert found[0].symbol == "bad_sync:os.fsync"


def test_seams_scope_excludes_other_layers(tmp_path):
    src = """\
        import os


        def bad_sync(fd):
            os.fsync(fd)
        """
    root = _mk_tree(tmp_path, {"src/repro/obs/sink.py": src})
    assert run(root, ["seams"]) == []


# --------------------------------------------------------------------- jax
def test_jax_flags_host_sync_in_jit_body(tmp_path):
    src = """\
        import jax


        @jax.jit
        def bad(x):
            return x.sum().item()


        @jax.jit
        def good(x):
            return x * 2
        """
    root = _mk_tree(tmp_path, {"src/repro/engine/kern.py": src})
    found = run(root, ["jax"])
    assert len(found) == 1, [f.render() for f in found]
    assert found[0].rule == "host-sync"
    assert ".item()" in found[0].symbol
    assert "bad" in found[0].symbol


# ---------------------------------------------------------------- taxonomy
def test_taxonomy_flags_duplicate_metric(tmp_path):
    src = """\
        def setup(reg):
            reg.gauge("depth")
            reg.histogram("depth")
        """
    root = _mk_tree(tmp_path, {"src/repro/serve/m.py": src})
    found = run(root, ["taxonomy"])
    assert len(found) == 1, [f.render() for f in found]
    assert found[0].rule == "metric-collision"
    assert found[0].symbol == "depth"


def test_taxonomy_flags_undocumented_span(tmp_path):
    src = """\
        def probe(tr):
            with tr.span("bogus"):
                pass
            with tr.span("flush.segment"):
                pass
        """
    root = _mk_tree(tmp_path, {"src/repro/serve/m.py": src})
    found = run(root, ["taxonomy"])
    assert len(found) == 1, [f.render() for f in found]
    assert found[0].rule == "unknown-span"
    assert found[0].symbol == "bogus"


# -------------------------------------------------------------------- wire
def test_wire_flags_missing_handler(tmp_path):
    host = """\
        class Host:
            def _on_query(self, env):
                return env.reply("result")

            def _on_flush(self, env):
                return env.reply("ok")
        """
    cli = """\
        from repro.fabric.envelope import Envelope


        def drive(t):
            t.request(Envelope("flush"))
            t.request(Envelope("nuke"))
            r = t.request(Envelope("query"))
            if r.kind == "result":
                return True
            return r.kind == "ok"
        """
    root = _mk_tree(tmp_path, {"src/repro/fabric/host.py": host,
                               "src/repro/fabric/cli.py": cli})
    found = run(root, ["wire"])
    assert len(found) == 1, [f.render() for f in found]
    assert found[0].rule == "missing-handler"
    assert found[0].symbol == "nuke"


# ---------------------------------------------------------------- baseline
def test_baseline_requires_reason():
    with pytest.raises(ValueError):
        Baseline([{"checker": "seams", "path": "x", "rule": "r",
                   "symbol": "s", "reason": "  "}])


def test_baseline_matches_across_line_drift():
    bl = Baseline([{"checker": "seams", "path": "p.py", "rule": "r",
                    "symbol": "f:os.fsync", "reason": "known"}])
    f1 = Finding("seams", "r", "p.py", 10, "f:os.fsync", "m")
    f2 = Finding("seams", "r", "p.py", 99, "f:os.fsync", "m")
    unbase, supp, stale = bl.split([f1, f2])
    assert unbase == [] and len(supp) == 2 and stale == []


# ----------------------------------------------------------------- witness
def _rank_extremes(wit):
    by_rank = sorted(wit.ranks.items(), key=lambda kv: kv[1])
    return by_rank[0][0], by_rank[-1][0]     # outermost id, innermost id


def test_witness_flags_inverted_nesting():
    wit = witness_mod.LockWitness(REPO_ROOT)      # no install: pure logic
    outer_id, inner_id = _rank_extremes(wit)
    outer = witness_mod._Wrapped(threading.Lock(), wit, outer_id)
    inner = witness_mod._Wrapped(threading.Lock(), wit, inner_id)
    with inner:                                   # innermost rank first...
        with outer:                               # ...then outermost: bad
            pass
    assert any("rank inversion" in v for v in wit.violations())


def test_witness_accepts_documented_order():
    wit = witness_mod.LockWitness(REPO_ROOT)
    outer_id, inner_id = _rank_extremes(wit)
    outer = witness_mod._Wrapped(threading.Lock(), wit, outer_id)
    inner = witness_mod._Wrapped(threading.Lock(), wit, inner_id)
    with outer:
        with inner:
            pass
    assert wit.violations() == []


def test_witness_reset_thread_clears_stale_hold():
    wit = witness_mod.LockWitness(REPO_ROOT)
    outer_id, inner_id = _rank_extremes(wit)
    abandoned = witness_mod._Wrapped(threading.Lock(), wit, inner_id)
    abandoned.acquire()          # crash-simulation idiom: never released
    wit.reset_thread()
    other = witness_mod._Wrapped(threading.Lock(), wit, outer_id)
    with other:
        pass
    assert wit.violations() == []


# --------------------------------------------------------------- real tree
def test_real_tree_has_zero_unbaselined_findings():
    findings = run(REPO_ROOT)
    bl = Baseline.load(default_baseline_path())
    unbase, _supp, stale = bl.split(findings)
    assert unbase == [], "\n".join(f.render() for f in unbase)
    assert stale == [], f"stale baseline entries: {stale}"


def test_cli_exits_zero_on_real_tree():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True,
        timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout


def test_checker_registry_rejects_unknown_name(tmp_path):
    root = _mk_tree(tmp_path, {"src/repro/serve/m.py": "x = 1\n"})
    with pytest.raises(KeyError):
        run(root, ["no-such-checker"])
