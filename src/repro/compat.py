"""Compatibility shims so the codebase runs on jax 0.4.x and newer jax alike.

The repo is written against the current jax mesh API (``jax.make_mesh`` with
``axis_types``, ``jax.set_mesh``, ``jax.sharding.get_abstract_mesh``,
``jax.shard_map``).  On jax 0.4.x those entry points are missing; this module
installs equivalents on the ``jax`` / ``jax.sharding`` namespaces:

  * ``jax.sharding.AxisType``        — minimal Auto/Explicit/Manual enum.
  * ``jax.sharding.get_abstract_mesh`` — returns the mesh activated by the
    surrounding ``with mesh:`` / ``jax.set_mesh(mesh)`` block (the physical
    mesh; it exposes the same ``empty`` / ``shape`` / ``axis_names`` surface
    the callers use).
  * ``jax.set_mesh`` / ``jax.sharding.use_mesh`` — context managers entering
    the mesh the 0.4.x way.
  * ``jax.shard_map``                — wraps ``jax.experimental.shard_map``,
    translating ``check_vma`` to the old ``check_rep``.
  * ``jax.make_mesh``                — accepts and drops ``axis_types``.

Importing this module installs the shims (idempotently).  Only APIs that are
actually absent are patched — on a new jax this module is a no-op.
"""
from __future__ import annotations

import contextlib
import enum
import functools

import jax

_INSTALLED_FLAG = "_repro_compat_installed"


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _current_mesh():
    """The mesh made current via ``with mesh:`` (0.4.x thread resources)."""
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


@contextlib.contextmanager
def _enter_mesh(mesh):
    with mesh:
        yield mesh


def _wrap_shard_map():
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        if check_vma is not None:
            kwargs.setdefault("check_rep", check_vma)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)

    return shard_map


def _wrap_make_mesh():
    _make_mesh = jax.make_mesh

    @functools.wraps(_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
        del axis_types  # 0.4.x meshes are implicitly Auto on every axis
        return _make_mesh(axis_shapes, axis_names, devices=devices)

    return make_mesh


def install() -> None:
    """Install the 0.4.x shims (no-op where the real API exists)."""
    if getattr(jax, _INSTALLED_FLAG, False):
        return
    sharding = jax.sharding
    if not hasattr(sharding, "AxisType"):
        sharding.AxisType = _AxisType
    if not hasattr(sharding, "get_abstract_mesh"):
        sharding.get_abstract_mesh = _current_mesh
    if not hasattr(sharding, "use_mesh"):
        sharding.use_mesh = _enter_mesh
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _enter_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _wrap_shard_map()
    try:
        import inspect
        if (hasattr(jax, "make_mesh") and "axis_types" not in
                inspect.signature(jax.make_mesh).parameters):
            jax.make_mesh = _wrap_make_mesh()
    except (TypeError, ValueError):  # pragma: no cover - exotic signatures
        pass
    setattr(jax, _INSTALLED_FLAG, True)


install()
