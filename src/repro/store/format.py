"""Versioned, checksummed on-disk serialization — the substrate shared by
the segment store (``repro.store``) and the training checkpoint store
(``repro.checkpoint.store``).

Two container shapes cover every durability need in the repo:

  * **Array files** — a single immutable file holding named numpy arrays
    plus a JSON meta dict.  Layout: magic, version, a JSON directory
    (name/dtype/shape/nbytes/crc32 per array) protected by its own CRC,
    then the raw array payloads.  Readers verify every CRC before any
    byte reaches a consumer, so a torn or bit-flipped file raises
    :class:`CorruptFileError` instead of silently feeding garbage bits
    into an index.  Writes are atomic (tmp file + fsync + ``os.replace``
    + directory fsync): a crash mid-write never leaves a half-visible
    file under the final name.
  * **Framed append logs** — the write-ahead log format: a fixed header
    followed by length+CRC framed entries.  The reader stops at the first
    torn or corrupt frame (the expected state after a crash mid-append)
    and returns everything before it.

Only stdlib + numpy: this module sits *below* the engine and must import
nothing above it (:mod:`repro.fault.seam`, the fault-injection seam the
writers and readers fire through, is itself stdlib-only and sits beside
this module — one global ``None`` check when no injector is installed).
"""
from __future__ import annotations

import errno
import io
import json
import os
import struct
import zlib
from typing import Any, BinaryIO, Iterator

import numpy as np

from repro.fault import seam

ARRAY_MAGIC = b"RBSF"          # Repro Bitmap Store File
LOG_MAGIC = b"RBWL"            # Repro Bitmap Write-ahead Log
VERSION = 1

_U32S = struct.Struct("<I")    # little-endian u32 framing


class CorruptFileError(RuntimeError):
    """A store file failed magic/version/CRC validation."""


def crc32(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def fsync_dir(path: str) -> None:
    """fsync a directory so a rename inside it is durable (POSIX)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:            # platforms without directory fds
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_replace(tmp_path: str, final_path: str) -> None:
    """Durable rename: the final name either has the complete old content
    or the complete new content, never a torn mix."""
    os.replace(tmp_path, final_path)
    fsync_dir(os.path.dirname(final_path) or ".")


def write_bytes_atomic(path: str, data: bytes) -> None:
    act = seam.fire("format.write", path=path, size=len(data))
    tmp = path + ".tmp"
    if act and act.get("torn_bytes") is not None:
        # injected crash-mid-write: a prefix of the payload reaches the
        # .tmp and "the process dies" before any cleanup — the final
        # name never appears (atomicity holds) and the orphan debris is
        # exactly what gc() must collect
        with open(tmp, "wb") as f:
            f.write(data[:act["torn_bytes"]])
        raise OSError(errno.EIO, f"injected torn write: {path}")
    try:
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
    except BaseException:
        # a FAILED (not crashed) write cleans up its own debris: the
        # caller sees the error, the directory holds no orphan .tmp
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    atomic_replace(tmp, path)


def write_json_atomic(path: str, obj: Any) -> None:
    write_bytes_atomic(path, json.dumps(obj, sort_keys=True).encode())


# ----------------------------------------------------------------- array file
def _array_entry(name: str, arr: np.ndarray) -> dict:
    return {"name": name, "dtype": str(arr.dtype),
            "shape": list(arr.shape), "nbytes": arr.nbytes,
            "crc32": crc32(arr.tobytes())}


def write_array_file(path: str, arrays: dict[str, np.ndarray],
                     meta: dict | None = None) -> None:
    """Atomically write named arrays + meta as one checksummed file."""
    arrays = {k: np.ascontiguousarray(v) for k, v in arrays.items()}
    header = json.dumps(
        {"meta": meta or {},
         "arrays": [_array_entry(k, v) for k, v in arrays.items()]},
        sort_keys=True).encode()
    buf = io.BytesIO()
    buf.write(ARRAY_MAGIC)
    buf.write(_U32S.pack(VERSION))
    buf.write(_U32S.pack(len(header)))
    buf.write(header)
    buf.write(_U32S.pack(crc32(header)))
    for arr in arrays.values():
        buf.write(arr.tobytes())
    write_bytes_atomic(path, buf.getvalue())


def read_array_file(path: str, *, verify: bool = True
                    ) -> tuple[dict[str, np.ndarray], dict]:
    """Read back (arrays, meta); raises :class:`CorruptFileError` on any
    magic/version/CRC mismatch or truncation."""
    with open(path, "rb") as f:
        data = f.read()
    act = seam.fire("format.read", path=path, data=data)
    if act and act.get("data") is not None:
        data = act["data"]              # injected read-side bit rot
    if data[:4] != ARRAY_MAGIC:
        raise CorruptFileError(f"{path}: bad magic {data[:4]!r}")
    if len(data) < 12:
        raise CorruptFileError(f"{path}: truncated preamble "
                               f"({len(data)} bytes)")
    (version,) = _U32S.unpack_from(data, 4)
    if version != VERSION:
        raise CorruptFileError(f"{path}: unsupported version {version}")
    (hlen,) = _U32S.unpack_from(data, 8)
    hdr_end = 12 + hlen
    if len(data) < hdr_end + 4:
        raise CorruptFileError(f"{path}: truncated header")
    header = data[12:hdr_end]
    (hcrc,) = _U32S.unpack_from(data, hdr_end)
    if verify and crc32(header) != hcrc:
        raise CorruptFileError(f"{path}: header CRC mismatch")
    directory = json.loads(header)
    arrays: dict[str, np.ndarray] = {}
    off = hdr_end + 4
    for ent in directory["arrays"]:
        end = off + ent["nbytes"]
        if end > len(data):
            raise CorruptFileError(f"{path}: truncated payload for "
                                   f"{ent['name']!r}")
        raw = data[off:end]
        if verify and crc32(raw) != ent["crc32"]:
            raise CorruptFileError(f"{path}: payload CRC mismatch for "
                                   f"{ent['name']!r}")
        arrays[ent["name"]] = np.frombuffer(
            raw, dtype=np.dtype(ent["dtype"])).reshape(ent["shape"])
        off = end
    return arrays, directory["meta"]


# ----------------------------------------------------------------- framed log
def write_log_header(f: BinaryIO) -> None:
    f.write(LOG_MAGIC)
    f.write(_U32S.pack(VERSION))
    f.flush()
    os.fsync(f.fileno())


def append_log_entry(f: BinaryIO, meta: dict, payload: bytes) -> None:
    """Append one durable length+CRC framed entry (meta JSON + raw bytes).

    Failure modes surface, never corrupt silently: a torn frame (injected
    crash) or a failed fsync raises — the caller must treat the entry as
    NOT durable (see ``WriteAheadLog.append_block``, which rewinds the
    handle to the last intact frame boundary so later appends never land
    behind an unreachable tail)."""
    head = json.dumps(meta, sort_keys=True).encode()
    body = _U32S.pack(len(head)) + head + payload
    frame = _U32S.pack(len(body)) + _U32S.pack(crc32(body)) + body
    act = seam.fire("log.append", path=getattr(f, "name", ""),
                    size=len(frame))
    if act and act.get("torn_bytes") is not None:
        f.write(frame[:act["torn_bytes"]])  # crash mid-append: torn tail
        f.flush()
        raise OSError(errno.EIO, "injected torn log append")
    f.write(frame)
    f.flush()
    if act and act.get("fail_fsync"):
        raise OSError(errno.EIO, "injected fsync failure (entry written "
                                 "but not durable)")
    os.fsync(f.fileno())


def read_log_entries(path: str) -> Iterator[tuple[dict, bytes]]:
    """Yield (meta, payload) per intact entry; a torn/corrupt tail (the
    normal post-crash state) ends iteration instead of raising."""
    for meta, payload, _ in read_log_entries_from(path, 8):
        yield meta, payload


def read_log_entries_from(path: str, offset: int
                          ) -> Iterator[tuple[dict, bytes, int]]:
    """Like :func:`read_log_entries` but starting at byte ``offset``
    (pass 8 for the whole log) and yielding ``(meta, payload,
    end_offset)`` — ``end_offset`` is the resume point for an
    incremental re-read of a log that is still being appended to."""
    try:
        with open(path, "rb") as f:
            data = f.read()
    except FileNotFoundError:
        return
    if data[:4] != LOG_MAGIC:
        return
    off = max(offset, 8)
    while off + 8 <= len(data):
        (blen,) = _U32S.unpack_from(data, off)
        (bcrc,) = _U32S.unpack_from(data, off + 4)
        end = off + 8 + blen
        if end > len(data):
            return                               # torn tail
        body = data[off + 8:end]
        if crc32(body) != bcrc:
            return                               # corrupt tail
        (hlen,) = _U32S.unpack_from(body, 0)
        meta = json.loads(body[4:4 + hlen])
        yield meta, body[4 + hlen:], end
        off = end


def intact_log_length(path: str) -> int:
    """Byte length of the intact prefix of a framed log (header + every
    complete, CRC-valid entry).  0 for a missing/headerless file.  A
    writer reopening a crashed log MUST truncate to this before appending
    — bytes written after a torn frame would be unreachable to readers."""
    try:
        with open(path, "rb") as f:
            data = f.read()
    except FileNotFoundError:
        return 0
    if data[:4] != LOG_MAGIC:
        return 0
    off = 8
    while off + 8 <= len(data):
        (blen,) = _U32S.unpack_from(data, off)
        (bcrc,) = _U32S.unpack_from(data, off + 4)
        end = off + 8 + blen
        if end > len(data) or crc32(data[off + 8:end]) != bcrc:
            break
        off = end
    return off
