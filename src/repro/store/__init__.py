"""Durable segment store for packed bitmap indexes.

Public surface:

  * :class:`SegmentStore` — directory of immutable checksummed segments +
    atomic manifest + write-ahead block log + tiered compaction.
  * :class:`StoredIndex` / :func:`open_index` — segment-parallel queryable
    view (serves through :func:`repro.engine.batch.execute_many_segments`).
  * :func:`recover_index` — manifest + WAL crash recovery to a bit-identical
    :class:`repro.engine.policy.BitmapIndex`.
  * :mod:`repro.store.format` — the checksummed serialization substrate
    (shared with :mod:`repro.checkpoint.store`).
"""
from repro.store.format import CorruptFileError  # noqa: F401
from repro.store.manifest import Manifest, SegmentMeta  # noqa: F401
from repro.store.store import (CompactionStats, GCStats,  # noqa: F401
                               ScrubStats, SegmentStore, StoredIndex,
                               np_splice, open_index, recover_index)
