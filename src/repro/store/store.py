"""`SegmentStore` — the durable, LSM-flavored home of a packed bitmap index.

Layout of one store directory::

    CURRENT                 -> name of the committed manifest
    MANIFEST-<v>.json       -> ordered live segment set + open WAL generation
    seg-<id>.seg            -> immutable packed segment (checksummed array file)
    wal-<gen>.log           -> write-ahead block log for the open tail

A **segment** is an immutable packed slice of the record stream: the
key-major ``(M, ceil(n/32))`` uint32 words for records
``[start_record, start_record + n)``, serialized with a versioned header and
per-array CRCs (:mod:`repro.store.format`).  The **manifest** names the live
segments in record order and is swapped atomically (write new manifest,
repoint ``CURRENT``), so every commit is all-or-nothing.  The **WAL** logs
raw record blocks before they are spliced into the in-memory index; a flush
writes the in-memory tail as a new segment, commits it, and rotates to a
fresh WAL generation.  Crash anywhere: recovery loads the committed
segments, re-indexes the surviving WAL blocks (the backends are pure
functions), and splices them on — reproducing the never-crashed in-memory
index word for word.

**Tiered compaction** keeps the segment count logarithmic: segments bucket
into size tiers (powers of ``compact_fanout`` records) and any run of
``compact_fanout`` adjacent same-tier segments merges into one via the same
shift/carry splice the streaming path uses.  Merges write the new segment
first and commit via the manifest, so compaction is crash-safe too.

Because segments partition the *record axis*, query serving never needs the
whole index resident: :class:`StoredIndex` runs a query batch against each
segment and OR-splices the per-segment result rows at their record offsets
(:func:`repro.engine.batch.execute_many_segments`).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Sequence

import numpy as np

from repro.store import format as fmt
from repro.store import wal as wal_mod
from repro.store.manifest import Manifest, SegmentMeta, commit, load

PACK = 32
KEYS_FILE = "KEYS.arr"         # persisted key set (see ensure_keys)


def _num_words(n: int) -> int:
    return -(-n // PACK)


def np_splice(dst: np.ndarray, start_bit: int, block: np.ndarray,
              block_records: int) -> None:
    """OR packed ``block`` rows into ``dst`` at ``start_bit`` in place
    (numpy shift/carry; host-side twin of the engine's jitted splice)."""
    off = start_bit % PACK
    w0 = start_bit // PACK
    bw = _num_words(block_records)
    block = block[:, :bw].astype(np.uint32, copy=False)
    if off == 0:
        dst[:, w0:w0 + bw] |= block
        return
    # words sliding past the destination tail are provably zero (block bits
    # past block_records are zero), so clipping them drops nothing
    hi = (block << np.uint32(off)).astype(np.uint32)
    carry = (block >> np.uint32(PACK - off)).astype(np.uint32)
    end = min(w0 + bw, dst.shape[1])
    dst[:, w0:end] |= hi[:, :end - w0]
    cend = min(w0 + 1 + bw, dst.shape[1])
    dst[:, w0 + 1:cend] |= carry[:, :cend - (w0 + 1)]


class SegmentStore:
    """One durable index = one store directory.  All mutation goes through
    ``log_block`` (WAL append) and ``write_segment`` (flush + manifest
    commit); both leave the directory recoverable at every instant."""

    def __init__(self, root: str, *, compact_fanout: int = 4,
                 auto_compact: bool = True):
        if compact_fanout < 2:
            raise ValueError("compact_fanout must be >= 2")
        self.root = root
        self.compact_fanout = compact_fanout
        self.auto_compact = auto_compact
        os.makedirs(root, exist_ok=True)
        self._manifest = load(root) or Manifest(
            version=0, segments=(), wal_generation=0, next_segment_id=0)
        self._wal: wal_mod.WriteAheadLog | None = None

    # ------------------------------------------------------------- accessors
    @property
    def manifest(self) -> Manifest:
        return self._manifest

    @property
    def segments(self) -> tuple[SegmentMeta, ...]:
        return self._manifest.segments

    @property
    def durable_records(self) -> int:
        """Records covered by committed segments (WAL tail excluded)."""
        return self._manifest.durable_records

    @property
    def num_keys(self) -> int | None:
        segs = self._manifest.segments
        return segs[0].num_keys if segs else None

    def wal_path(self) -> str:
        return wal_mod.wal_path(self.root, self._manifest.wal_generation)

    # ---------------------------------------------------------- key identity
    def ensure_keys(self, keys: np.ndarray) -> None:
        """Persist the key set on first use; afterwards reject ANY
        different key set (even one of the same length) — segments and
        WAL re-indexing are only meaningful under one key set, and a
        same-shape mismatch would recover a silently corrupt index."""
        keys = np.ascontiguousarray(keys, dtype=np.int32)
        path = os.path.join(self.root, KEYS_FILE)
        if os.path.exists(path):
            stored, _ = fmt.read_array_file(path)
            if not np.array_equal(stored["keys"], keys):
                raise ValueError(
                    f"store {self.root} was built with a different key "
                    "set; one store persists ONE index")
        else:
            fmt.write_array_file(path, {"keys": keys})

    # ------------------------------------------------------------------- WAL
    def log_block(self, records: np.ndarray, start: int,
                  tick: int | None = None) -> None:
        """Durably log a raw record block BEFORE it is spliced in memory."""
        if self._wal is None:
            self._wal = wal_mod.WriteAheadLog(self.wal_path())
        self._wal.append_block(np.asarray(records), start, tick)

    def replay_wal(self) -> list[tuple[int, np.ndarray, int | None]]:
        """Intact WAL (start, records, tick) blocks not yet covered by a
        committed segment, in stream order — exactly what recovery must
        re-index."""
        floor = self.durable_records
        return [(start, rec, tick)
                for start, rec, tick in wal_mod.replay(self.wal_path())
                if start >= floor]

    # -------------------------------------------------------------- segments
    def segment_path(self, meta: SegmentMeta) -> str:
        return os.path.join(self.root, meta.file)

    def read_segment(self, meta: SegmentMeta) -> np.ndarray:
        """Load + verify one segment's packed words."""
        arrays, fmeta = fmt.read_array_file(self.segment_path(meta))
        packed = arrays["packed"]
        if (fmeta.get("num_records") != meta.num_records
                or fmeta.get("segment_id") != meta.segment_id
                or packed.shape != (meta.num_keys,
                                    _num_words(meta.num_records))):
            raise fmt.CorruptFileError(
                f"{meta.file}: segment meta mismatch (manifest says "
                f"{meta}, file says {fmeta} / {packed.shape})")
        return packed

    def write_segment(self, packed: np.ndarray, num_records: int,
                      start_record: int, *,
                      tick_watermark: tuple[int, int] | None = None
                      ) -> SegmentMeta:
        """Flush a packed tail slice as a new immutable segment and commit:
        segment file first, then an atomic manifest swap that also rotates
        the WAL generation (the flushed records no longer need the log).
        ``tick_watermark`` carries the (tick, blocks) watermark of the
        flushed records into the manifest (it must survive the WAL
        rotation)."""
        m = self._manifest
        if start_record != m.durable_records:
            raise ValueError(
                f"segment must extend the stream: start={start_record}, "
                f"durable={m.durable_records}")
        if num_records <= 0:
            raise ValueError("segment needs at least one record")
        packed = np.ascontiguousarray(packed, dtype=np.uint32)
        if self.num_keys is not None and packed.shape[0] != self.num_keys:
            raise ValueError(f"segment has {packed.shape[0]} key rows, "
                             f"store has {self.num_keys}")
        if packed.shape[1] != _num_words(num_records):
            raise ValueError(f"packed shape {packed.shape} does not match "
                             f"{num_records} records")
        meta = self._write_segment_file(packed, num_records, start_record)
        tick, blocks = (tick_watermark if tick_watermark is not None
                        else (m.last_tick, m.last_tick_blocks))
        self._commit(dataclasses.replace(
            m, version=m.version + 1, segments=m.segments + (meta,),
            wal_generation=m.wal_generation + 1,
            next_segment_id=m.next_segment_id + 1,
            last_tick=tick, last_tick_blocks=blocks))
        if self.auto_compact:
            self.compact()
        return meta

    def _write_segment_file(self, packed: np.ndarray, num_records: int,
                            start_record: int) -> SegmentMeta:
        """Write the next segment id's immutable file (flush and merge
        share this); the segment becomes live only at the manifest commit."""
        m = self._manifest
        meta = SegmentMeta(segment_id=m.next_segment_id,
                           file=f"seg-{m.next_segment_id:08d}.seg",
                           start_record=start_record,
                           num_records=num_records,
                           num_keys=packed.shape[0])
        fmt.write_array_file(
            os.path.join(self.root, meta.file), {"packed": packed},
            meta={"segment_id": meta.segment_id,
                  "start_record": meta.start_record,
                  "num_records": meta.num_records})
        return meta

    def _commit(self, new: Manifest) -> None:
        commit(self.root, new)
        self._manifest = new
        if self._wal is not None:           # rotated: next log_block reopens
            self._wal.close()
            self._wal = None

    # ------------------------------------------------------------ compaction
    def _tier(self, num_records: int) -> int:
        # integer arithmetic: float log truncates exact fanout powers
        # (int(math.log(243, 3)) == 4) and would mis-bucket them
        tier, bound = 0, self.compact_fanout
        while num_records >= bound:
            tier += 1
            bound *= self.compact_fanout
        return tier

    def compact(self) -> int:
        """Tiered merge: while any ``compact_fanout``-long run of adjacent
        same-tier segments exists, splice it into one segment (write new
        file, atomic manifest swap).  Returns the number of merges."""
        merges = 0
        while True:
            run = self._find_run()
            if run is None:
                return merges
            self._merge(*run)
            merges += 1

    def _find_run(self) -> tuple[int, int] | None:
        segs = self._manifest.segments
        i = 0
        while i < len(segs):
            j = i
            t = self._tier(segs[i].num_records)
            while (j < len(segs)
                   and self._tier(segs[j].num_records) == t):
                j += 1
            if j - i >= self.compact_fanout:
                return i, i + self.compact_fanout
            i += 1
        return None

    def _merge(self, lo: int, hi: int) -> None:
        m = self._manifest
        run = m.segments[lo:hi]
        total = sum(s.num_records for s in run)
        merged = np.zeros((run[0].num_keys, _num_words(total)), np.uint32)
        at = 0
        for s in run:
            np_splice(merged, at, self.read_segment(s), s.num_records)
            at += s.num_records
        meta = self._write_segment_file(merged, total, run[0].start_record)
        self._commit(dataclasses.replace(
            m, version=m.version + 1,
            segments=m.segments[:lo] + (meta,) + m.segments[hi:],
            next_segment_id=m.next_segment_id + 1))

    # ------------------------------------------------------------- bulk read
    def load_packed(self) -> tuple[np.ndarray, int]:
        """Materialize the committed segments as one packed array
        ``(M, ceil(n/32))`` (WAL tail excluded).  Segments are contiguous
        and start 32-aligned relative to nothing — the host splice handles
        arbitrary offsets."""
        segs = self._manifest.segments
        n = self.durable_records
        if not segs:
            return np.zeros((0, 0), np.uint32), 0
        out = np.zeros((segs[0].num_keys, _num_words(n)), np.uint32)
        for s in segs:
            np_splice(out, s.start_record, self.read_segment(s),
                      s.num_records)
        return out, n

    # -------------------------------------------------------------------- gc
    def gc(self) -> list[str]:
        """Delete files unreachable from CURRENT (orphan segments from
        crashed flushes, superseded manifests, rotated WALs)."""
        m = self._manifest
        keep = {"CURRENT", f"MANIFEST-{m.version:08d}.json",
                os.path.basename(self.wal_path())}
        keep |= {s.file for s in m.segments}
        removed = []
        for name in os.listdir(self.root):
            if name in keep:
                continue
            # includes stale .tmp files (crash mid-atomic-write): the
            # atomic writers finish their replace before returning, so an
            # unreferenced .tmp is never about to become live
            if (name.startswith(("seg-", "wal-", "MANIFEST-"))
                    or name.endswith(".tmp")):
                os.remove(os.path.join(self.root, name))
                removed.append(name)
        return removed

    def close(self) -> None:
        if self._wal is not None:
            self._wal.close()
            self._wal = None


# --------------------------------------------------------- queryable handle
@dataclasses.dataclass
class StoredIndex:
    """Segment-parallel queryable view of a (possibly spilled) index: an
    ordered list of per-segment packed arrays covering disjoint record
    ranges, plus the total record count.  ``query_many`` serves a batch of
    predicate trees with one bucketed dispatch per segment and OR-splices
    the per-segment rows at their record offsets — no materialized
    full-index buffer (see :func:`repro.engine.batch.execute_many_segments`).
    """
    parts: tuple            # of (packed jax/np (M, w_i) uint32, n_i records)
    num_records: int

    @property
    def num_keys(self) -> int:
        return int(self.parts[0][0].shape[0]) if self.parts else 0

    @property
    def num_segments(self) -> int:
        return len(self.parts)

    def query_many(self, predicates: Sequence, *, backend: str = "auto"):
        from repro.engine import batch as engine_batch
        return engine_batch.execute_many_segments(
            self.parts, predicates, backend=backend)

    def to_bitmap_index(self):
        """Materialize one contiguous :class:`repro.engine.policy.BitmapIndex`
        (tests / small indexes only — serving should stay segment-parallel)."""
        from repro.engine import policy
        from repro.engine.runtime import append_packed
        import jax.numpy as jnp
        packed = jnp.zeros((self.num_keys, 0), jnp.uint32)
        n = 0
        for part, cnt in self.parts:
            packed = append_packed(packed, n, jnp.asarray(part), cnt)
            n += cnt
        return policy.BitmapIndex(packed, n)


def open_index(store: SegmentStore, *, tail=None) -> StoredIndex:
    """Open the committed segment set as a :class:`StoredIndex`.  ``tail``
    optionally appends an in-memory packed suffix ``(packed, num_records)``
    — e.g. a recovered WAL tail not yet flushed."""
    import jax.numpy as jnp
    parts = [(jnp.asarray(store.read_segment(s)), s.num_records)
             for s in store.segments]
    n = store.durable_records
    if tail is not None:
        tpacked, tcount = tail
        if tcount:
            parts.append((jnp.asarray(tpacked), int(tcount)))
            n += int(tcount)
    return StoredIndex(tuple(parts), n)


def recover_index(store: SegmentStore, keys, *, backend: str = "auto"):
    """Full crash recovery: committed segments + re-indexed WAL tail ->
    a :class:`repro.engine.policy.BitmapIndex` bit-identical to the
    never-crashed in-memory index (see ``StreamingIndexer.restore`` for
    recovery into a live appendable indexer)."""
    from repro.engine.runtime import StreamingIndexer
    return StreamingIndexer.restore(store, keys, backend=backend).index
