"""`SegmentStore` — the durable, LSM-flavored home of a packed bitmap index.

Layout of one store directory::

    CURRENT                 -> name of the committed manifest
    MANIFEST-<v>.json       -> ordered live segment set + open WAL generation
    seg-<id>.seg            -> immutable packed segment (checksummed array file)
    wal-<gen>.log           -> write-ahead block log for the open tail

A **segment** is an immutable packed slice of the record stream: the
key-major ``(M, ceil(n/32))`` uint32 words for records
``[start_record, start_record + n)``, serialized with a versioned header and
per-array CRCs (:mod:`repro.store.format`).  The **manifest** names the live
segments in record order and is swapped atomically (write new manifest,
repoint ``CURRENT``), so every commit is all-or-nothing.  The **WAL** logs
raw record blocks before they are spliced into the in-memory index; a flush
writes the in-memory tail as a new segment, commits it, and rotates to a
fresh WAL generation.  Crash anywhere: recovery loads the committed
segments, re-indexes the surviving WAL blocks (the backends are pure
functions), and splices them on — reproducing the never-crashed in-memory
index word for word.

**Tiered compaction** keeps the segment count logarithmic: segments bucket
into size tiers (powers of ``compact_fanout`` records) and any run of
``compact_fanout`` adjacent same-tier segments merges into one via the same
shift/carry splice the streaming path uses.  Merges write the new segment
first and commit via the manifest, so compaction is crash-safe too.

**Concurrency** — one writer stream, one maintenance thread: the append
path only ever touches the WAL (:meth:`SegmentStore.log_block`), while
flushes/compaction/gc mutate the manifest.  A store-internal lock guards
the WAL handle and every manifest swap; the slow work (segment file
writes, merge splices) runs OUTSIDE the lock via the two-phase
:meth:`SegmentStore.prepare_segment` / :meth:`SegmentStore.commit_segment`
protocol, so appends never wait on a flush.  Blocks logged to the
outgoing WAL generation while a background flush was preparing are
carried into the fresh generation *before* the manifest swap — no crash
instant can lose an acknowledged block.  Files being prepared register as
in-flight so :meth:`SegmentStore.gc` (which may run concurrently from the
maintenance executor) never deletes a segment about to be committed.

Because segments partition the *record axis*, query serving never needs the
whole index resident: :class:`StoredIndex` runs a query batch against each
segment and OR-splices the per-segment result rows at their record offsets
(:func:`repro.engine.batch.execute_many_segments`).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs.trace import maybe_span
from repro.store import format as fmt
from repro.store import wal as wal_mod
from repro.store.manifest import Manifest, SegmentMeta, commit, load

PACK = 32
KEYS_FILE = "KEYS.arr"         # persisted key set (see ensure_keys)


def _num_words(n: int) -> int:
    return -(-n // PACK)


def np_splice(dst: np.ndarray, start_bit: int, block: np.ndarray,
              block_records: int) -> None:
    """OR packed ``block`` rows into ``dst`` at ``start_bit`` in place
    (numpy shift/carry; host-side twin of the engine's jitted splice)."""
    off = start_bit % PACK
    w0 = start_bit // PACK
    bw = _num_words(block_records)
    block = block[:, :bw].astype(np.uint32, copy=False)
    if off == 0:
        dst[:, w0:w0 + bw] |= block
        return
    # words sliding past the destination tail are provably zero (block bits
    # past block_records are zero), so clipping them drops nothing
    hi = (block << np.uint32(off)).astype(np.uint32)
    carry = (block >> np.uint32(PACK - off)).astype(np.uint32)
    end = min(w0 + bw, dst.shape[1])
    dst[:, w0:end] |= hi[:, :end - w0]
    cend = min(w0 + 1 + bw, dst.shape[1])
    dst[:, w0 + 1:cend] |= carry[:, :cend - (w0 + 1)]


@dataclasses.dataclass
class CompactionStats:
    """What a :meth:`SegmentStore.compact` pass did (or, with
    ``dry_run=True``, would do).  ``bytes_reclaimed`` counts superseded
    segment files turned into garbage (actually deleted later by
    :meth:`SegmentStore.gc`); comparisons against numbers compare the
    merge count, so ``store.compact() > 0`` keeps reading naturally."""
    merges: int = 0
    segments_merged: int = 0
    bytes_written: int = 0
    bytes_reclaimed: int = 0
    dry_run: bool = False

    def __int__(self) -> int:
        return self.merges

    def __bool__(self) -> bool:
        return self.merges > 0

    def __eq__(self, other):
        if isinstance(other, (int, float)):
            return self.merges == other
        return super().__eq__(other)

    def __lt__(self, other):
        return self.merges < other

    def __le__(self, other):
        return self.merges <= other

    def __gt__(self, other):
        return self.merges > other

    def __ge__(self, other):
        return self.merges >= other


@dataclasses.dataclass(frozen=True)
class ScrubStats:
    """What a :meth:`SegmentStore.scrub` pass found and did.  ``corrupt``
    names every segment whose read failed CRC/meta validation this pass;
    each such segment lands in exactly one of ``repaired`` (a supplied
    replica rewrote it, or a clean re-read proved the corruption was
    read-side) or ``quarantined`` (no replica — served around until one
    appears).  Truthy iff corruption was found."""
    checked: int = 0
    corrupt: tuple[str, ...] = ()
    repaired: tuple[str, ...] = ()
    quarantined: tuple[str, ...] = ()
    dry_run: bool = False

    def __bool__(self) -> bool:
        return bool(self.corrupt)


@dataclasses.dataclass(frozen=True)
class GCStats:
    """What a :meth:`SegmentStore.gc` pass removed (or, with
    ``dry_run=True``, would remove).  Iterates / contains like the plain
    filename list it used to be."""
    removed: tuple[str, ...] = ()
    bytes_reclaimed: int = 0
    skipped_inflight: tuple[str, ...] = ()
    dry_run: bool = False

    def __contains__(self, name) -> bool:
        return name in self.removed

    def __iter__(self):
        return iter(self.removed)

    def __len__(self) -> int:
        return len(self.removed)

    def __bool__(self) -> bool:
        return bool(self.removed)


class SegmentStore:
    """One durable index = one store directory.  All mutation goes through
    ``log_block`` (WAL append) and ``write_segment`` (flush + manifest
    commit); both leave the directory recoverable at every instant.  An
    internal lock guards the WAL handle and manifest swaps so one append
    stream and one maintenance thread can share the store (see the module
    docstring's concurrency section)."""

    def __init__(self, root: str, *, compact_fanout: int = 4,
                 auto_compact: bool = True):
        if compact_fanout < 2:
            raise ValueError("compact_fanout must be >= 2")
        self.root = root
        self.compact_fanout = compact_fanout
        self.auto_compact = auto_compact
        os.makedirs(root, exist_ok=True)
        self._manifest = load(root) or Manifest(
            version=0, segments=(), wal_generation=0, next_segment_id=0)
        self._wal: wal_mod.WriteAheadLog | None = None
        self._wal_gen: int | None = None   # generation of the open handle
        # guards the WAL handle + manifest mutations (never held across a
        # segment-file write or merge splice — appends must not wait on
        # maintenance)
        self._lock = threading.RLock()
        # serializes segment CREATION (a two-phase flush holds it from
        # prepare to commit/abort, a merge for its whole body): segment
        # ids stay unique and every commit runs against a manifest no
        # other segment writer has moved.  Appends never touch it.
        self._flush_lock = threading.Lock()
        # filenames a two-phase flush/merge is writing right now: gc must
        # treat them (and their .tmp twins) as live, not garbage
        self._inflight: set[str] = set()
        # segments whose last read failed validation: file -> reason.
        # Quarantined segments stay in the manifest (their records are
        # still the stream's records) but compaction refuses to merge
        # them and the serving layer substitutes a replica / serves
        # around until scrub() repairs or clears them.
        self._quarantined: dict[str, str] = {}
        # durability counters live in a typed registry (health() is a
        # view over it; services attach it as their "store" subtree).
        # The old attribute names stay readable via properties below.
        self.registry = obs_metrics.Registry()
        self._quarantine_events_c = self.registry.counter(
            "quarantine_events_total", "lifetime quarantine entries")
        self._repairs_c = self.registry.counter(
            "repairs_total", "lifetime un-quarantines")
        self._read_retries_c = self.registry.counter(
            "read_retries_total", "transient read errors retried away")
        self._segments_g = self.registry.gauge(
            "segments", "live committed segments")
        self._quarantined_g = self.registry.gauge(
            "quarantined", "segments currently quarantined")
        self._segments_g.set(len(self._manifest.segments))

    # ------------------------------------------------- counter compat views
    @property
    def quarantine_events(self) -> int:
        return self._quarantine_events_c.value

    @property
    def repairs(self) -> int:
        return self._repairs_c.value

    @property
    def read_retries(self) -> int:
        return self._read_retries_c.value

    # ------------------------------------------------------------- accessors
    @property
    def manifest(self) -> Manifest:
        return self._manifest

    @property
    def segments(self) -> tuple[SegmentMeta, ...]:
        return self._manifest.segments

    @property
    def durable_records(self) -> int:
        """Records covered by committed segments (WAL tail excluded)."""
        return self._manifest.durable_records

    @property
    def num_keys(self) -> int | None:
        segs = self._manifest.segments
        return segs[0].num_keys if segs else None

    def wal_path(self) -> str:
        return wal_mod.wal_path(self.root, self._manifest.wal_generation)

    # ---------------------------------------------------------- key identity
    def ensure_keys(self, keys: np.ndarray) -> None:
        """Persist the key set on first use; afterwards reject ANY
        different key set (even one of the same length) — segments and
        WAL re-indexing are only meaningful under one key set, and a
        same-shape mismatch would recover a silently corrupt index."""
        keys = np.ascontiguousarray(keys, dtype=np.int32)
        path = os.path.join(self.root, KEYS_FILE)
        if os.path.exists(path):
            stored, _ = fmt.read_array_file(path)
            if not np.array_equal(stored["keys"], keys):
                raise ValueError(
                    f"store {self.root} was built with a different key "
                    "set; one store persists ONE index")
        else:
            fmt.write_array_file(path, {"keys": keys})

    # ------------------------------------------------------------------- WAL
    def log_block(self, records: np.ndarray, start: int,
                  tick: int | None = None) -> None:
        """Durably log a raw record block BEFORE it is spliced in memory.
        Holds the store lock only for the framed append itself, so this —
        the whole append-path footprint on the store — never waits on a
        segment write or a compaction merge."""
        with self._lock:
            if self._wal is None:
                self._wal = wal_mod.WriteAheadLog(self.wal_path())
                self._wal_gen = self._manifest.wal_generation
            self._wal.append_block(np.asarray(records), start, tick)

    def replay_wal(self) -> list[tuple[int, np.ndarray, int | None]]:
        """Intact WAL (start, records, tick) blocks not yet covered by a
        committed segment, in stream order — exactly what recovery must
        re-index.

        Reads the committed generation AND the next one: a rotation
        installs the fresh generation's handle (appends switch over)
        *before* the manifest swap, so a crash in that window leaves
        live blocks in generation g+1 while ``CURRENT`` still names g.
        Blocks are deduplicated by stream position (carried copies in
        the fresh generation are byte-identical to their originals), so
        every rotation crash window replays exactly once."""
        gen = self._manifest.wal_generation
        out = []
        pos = self.durable_records
        for path in (wal_mod.wal_path(self.root, gen),
                     wal_mod.wal_path(self.root, gen + 1)):
            for start, rec, tick in wal_mod.replay(path):
                if start < pos:
                    continue        # segment-covered, or a carried dup
                out.append((start, rec, tick))
                pos = start + rec.shape[0]
        return out

    # -------------------------------------------------------------- segments
    def segment_path(self, meta: SegmentMeta) -> str:
        return os.path.join(self.root, meta.file)

    def read_segment(self, meta: SegmentMeta, *,
                     retries: int = 2) -> np.ndarray:
        """Load + verify one segment's packed words.  Transient I/O
        errors (EIO blips — real or injected) retry up to ``retries``
        times with a short linear backoff; validation failures
        (:class:`~repro.store.format.CorruptFileError`) never retry —
        corruption is persistent until repaired, and the caller's move
        is :meth:`quarantine` + :meth:`scrub`, not another read."""
        attempt = 0
        while True:
            try:
                arrays, fmeta = fmt.read_array_file(self.segment_path(meta))
                break
            except fmt.CorruptFileError:
                raise
            except OSError:
                attempt += 1
                if attempt > retries:
                    raise
                self._read_retries_c.inc()
                time.sleep(0.001 * attempt)
        packed = arrays["packed"]
        if (fmeta.get("num_records") != meta.num_records
                or fmeta.get("segment_id") != meta.segment_id
                or packed.shape != (meta.num_keys,
                                    _num_words(meta.num_records))):
            raise fmt.CorruptFileError(
                f"{meta.file}: segment meta mismatch (manifest says "
                f"{meta}, file says {fmeta} / {packed.shape})")
        return packed

    # ------------------------------------------------------- quarantine/scrub
    @property
    def quarantined(self) -> dict[str, str]:
        """Snapshot of quarantined segment files -> reason."""
        with self._lock:
            return dict(self._quarantined)

    def quarantine(self, meta: SegmentMeta, reason: str) -> None:
        """Mark a live segment as corrupt-on-disk: compaction will not
        merge it and the serving layer serves around it until
        :meth:`repair_segment` (or a clean :meth:`scrub` re-read)
        clears it.  Idempotent per file."""
        with self._lock:
            if meta.file not in {s.file for s in self._manifest.segments}:
                return                 # superseded while we looked at it
            if meta.file not in self._quarantined:
                self._quarantined[meta.file] = str(reason)
                self._quarantine_events_c.inc()
                self._quarantined_g.set(len(self._quarantined))

    def repair_segment(self, meta: SegmentMeta, packed: np.ndarray) -> None:
        """Rewrite a (quarantined) segment's file from a known-good
        replica of its packed words — e.g. re-extracted from the live
        in-memory index — then verify the round trip and lift the
        quarantine.  Runs under the flush lock so no compaction merge or
        two-phase flush can move the manifest mid-repair."""
        packed = np.ascontiguousarray(packed, dtype=np.uint32)
        want = (meta.num_keys, _num_words(meta.num_records))
        if packed.shape != want:
            raise ValueError(f"replica shape {packed.shape} does not match "
                             f"segment {meta.file} ({want})")
        with self._flush_lock:
            with self._lock:
                if meta.file not in {s.file
                                     for s in self._manifest.segments}:
                    raise ValueError(f"{meta.file} is not a live segment")
                # gc guard for the .tmp twin during the atomic rewrite
                self._inflight.add(meta.file)
            try:
                with maybe_span("store.repair", file=meta.file):
                    fmt.write_array_file(
                        self.segment_path(meta), {"packed": packed},
                        meta={"segment_id": meta.segment_id,
                              "start_record": meta.start_record,
                              "num_records": meta.num_records})
            finally:
                with self._lock:
                    self._inflight.discard(meta.file)
        self.read_segment(meta)        # verify before lifting quarantine
        with self._lock:
            self._quarantined.pop(meta.file, None)
            self._quarantined_g.set(len(self._quarantined))
        self._repairs_c.inc()          # every successful rewrite counts

    def scrub(self, *,
              repair: Callable[[SegmentMeta], np.ndarray | None] | None
              = None,
              dry_run: bool = False) -> ScrubStats:
        """CRC-verify every committed segment (the background scrubber's
        body).  A segment that fails validation is repaired from
        ``repair(meta)``'s replica when one is available, otherwise
        quarantined; a quarantined segment whose re-read comes back
        clean (the corruption was read-side, not on disk) is released.
        In-flight segments are skipped — their writer owns them.
        ``dry_run=True`` only reports."""
        with maybe_span("store.scrub", dry_run=dry_run):
            return self._scrub_sweep(repair=repair, dry_run=dry_run)

    def _scrub_sweep(self, *, repair, dry_run) -> ScrubStats:
        checked = 0
        corrupt: list[str] = []
        repaired: list[str] = []
        quarantined: list[str] = []
        for meta in self._manifest.segments:      # immutable snapshot
            with self._lock:
                if meta.file in self._inflight:
                    continue
            checked += 1
            try:
                self.read_segment(meta)
            except (fmt.CorruptFileError, OSError) as e:
                corrupt.append(meta.file)
                if dry_run:
                    continue
                replica = repair(meta) if repair is not None else None
                if replica is not None:
                    try:
                        self.repair_segment(meta, replica)
                        repaired.append(meta.file)
                        continue
                    except (ValueError, OSError, fmt.CorruptFileError):
                        pass           # fall through to quarantine
                self.quarantine(meta, f"{type(e).__name__}: {e}")
                quarantined.append(meta.file)
            else:
                if dry_run:
                    continue
                lifted = False
                with self._lock:       # clean read-back lifts quarantine
                    if self._quarantined.pop(meta.file, None) is not None:
                        self._quarantined_g.set(len(self._quarantined))
                        lifted = True
                if lifted:
                    self._repairs_c.inc()
                    repaired.append(meta.file)
        return ScrubStats(checked, tuple(corrupt), tuple(repaired),
                          tuple(quarantined), dry_run)

    def write_segment(self, packed: np.ndarray, num_records: int,
                      start_record: int, *,
                      tick_watermark: tuple[int, int] | None = None
                      ) -> SegmentMeta:
        """Flush a packed tail slice as a new immutable segment and commit:
        segment file first, then an atomic manifest swap that also rotates
        the WAL generation (the flushed records no longer need the log).
        ``tick_watermark`` carries the (tick, blocks) watermark of the
        flushed records into the manifest (it must survive the WAL
        rotation)."""
        meta = self.prepare_segment(packed, num_records, start_record)
        try:
            self.commit_segment(meta, tick_watermark=tick_watermark)
        except BaseException:
            self.abort_segment(meta)    # completes the two-phase op
            raise
        return meta

    def prepare_segment(self, packed: np.ndarray, num_records: int,
                        start_record: int) -> SegmentMeta:
        """Phase one of a (possibly background) flush: validate and write
        the immutable segment FILE without touching the manifest — the
        slow part, safe to run off the append path because appends only
        ever touch the WAL.  The segment becomes live only at
        :meth:`commit_segment`; until then gc treats the file as
        in-flight, not garbage.  Holds the store's flush lock until
        :meth:`commit_segment` / :meth:`abort_segment` releases it, so
        no other segment writer (an explicit ``snapshot()`` spill, a
        compaction merge) can move the manifest — or claim the same
        segment id — underneath the two-phase flush."""
        packed = np.ascontiguousarray(packed, dtype=np.uint32)
        if num_records <= 0:
            raise ValueError("segment needs at least one record")
        if packed.shape[1] != _num_words(num_records):
            raise ValueError(f"packed shape {packed.shape} does not match "
                             f"{num_records} records")
        self._flush_lock.acquire()
        try:
            with self._lock:
                m = self._manifest
                if start_record != m.durable_records:
                    raise ValueError(
                        f"segment must extend the stream: "
                        f"start={start_record}, "
                        f"durable={m.durable_records}")
                if self.num_keys is not None \
                        and packed.shape[0] != self.num_keys:
                    raise ValueError(
                        f"segment has {packed.shape[0]} key rows, "
                        f"store has {self.num_keys}")
                meta = SegmentMeta(segment_id=m.next_segment_id,
                                   file=f"seg-{m.next_segment_id:08d}.seg",
                                   start_record=start_record,
                                   num_records=num_records,
                                   num_keys=packed.shape[0])
                self._inflight.add(meta.file)
            try:
                with maybe_span("store.prepare", file=meta.file,
                                records=num_records):
                    fmt.write_array_file(
                        os.path.join(self.root, meta.file),
                        {"packed": packed},
                        meta={"segment_id": meta.segment_id,
                              "start_record": meta.start_record,
                              "num_records": meta.num_records})
            except BaseException:
                with self._lock:
                    self._inflight.discard(meta.file)
                raise
        except BaseException:
            self._flush_lock.release()
            raise
        return meta

    def commit_segment(self, meta: SegmentMeta, *,
                       tick_watermark: tuple[int, int] | None = None
                       ) -> None:
        """Phase two: atomic manifest swap making a prepared segment live
        (and rotating the WAL generation — blocks logged while the
        prepare was running are carried into the fresh generation, see
        :meth:`_commit`).  A crash anywhere before this call leaves only
        an orphan file; recovery still replays every logged block.

        On FAILURE the flush lock stays held and the segment stays
        in-flight: the two-phase op is still open, and the caller
        finishes it with :meth:`abort_segment` (exactly one release —
        releasing here too would let a second release free some OTHER
        writer's critical section).

        The store lock is held only for the handle swap plus the tail of
        the WAL carry-over (normally zero blocks): the bulk copy of the
        outgoing generation, the fresh generation's creation, and the
        manifest's fsync-heavy file writes all run outside it, so
        appends stall for at most one WAL frame.  Crash windows are
        covered by :meth:`replay_wal`'s two-generation deduplicating
        read."""
        m = self._manifest                 # stable: flush lock held
        if meta.start_record != m.durable_records:
            raise ValueError(
                f"segment must extend the stream: "
                f"start={meta.start_record}, "
                f"durable={m.durable_records}")
        # phase A (unlocked): fresh generation file (truncating a stale
        # one from a crashed prior rotation) + bulk carry-over of blocks
        # the new manifest will not cover, while appends keep logging to
        # the outgoing generation.  If a prior commit attempt already
        # switched the handle to the target generation (its manifest
        # swap failed), every block at or past this flush's floor is
        # already there — truncating it would lose them, so both phases
        # are skipped.
        target_gen = m.wal_generation + 1
        if self._wal_gen != target_gen:
            old_path = wal_mod.wal_path(self.root, m.wal_generation)
            fresh = wal_mod.WriteAheadLog.create(
                wal_mod.wal_path(self.root, target_gen))
            floor = meta.start_record + meta.num_records
            copied_to = floor
            entries, read_off = wal_mod.replay_from(old_path, 8)
            for start, rec, tick in entries:
                if start >= copied_to:
                    fresh.append_block(rec, start, tick)
                    copied_to = start + rec.shape[0]
            # phase B (locked, brief): catch blocks that raced the bulk
            # copy, then switch the append stream to the fresh generation
            with self._lock:
                if self._wal is not None:
                    self._wal.close()      # flush the outgoing handle
                    self._wal = None
                entries, _ = wal_mod.replay_from(old_path, read_off)
                for start, rec, tick in entries:
                    if start >= copied_to:
                        fresh.append_block(rec, start, tick)
                        copied_to = start + rec.shape[0]
                self._wal = fresh
                self._wal_gen = target_gen
        # phase C (unlocked): the atomic manifest swap — a crash before
        # it leaves CURRENT at the old generation, whose blocks replay
        # (the fresh file's copies dedup away); after it, the fresh
        # generation is simply current
        tick, blocks = (tick_watermark if tick_watermark is not None
                        else (m.last_tick, m.last_tick_blocks))
        with maybe_span("store.commit", file=meta.file,
                        records=meta.num_records):
            self._commit(dataclasses.replace(
                m, version=m.version + 1,
                segments=m.segments + (meta,),
                wal_generation=m.wal_generation + 1,
                next_segment_id=max(m.next_segment_id,
                                    meta.segment_id + 1),
                last_tick=tick, last_tick_blocks=blocks))
        with self._lock:
            self._inflight.discard(meta.file)
        self._flush_lock.release()
        if self.auto_compact:
            self.compact()

    def abort_segment(self, meta: SegmentMeta) -> None:
        """Drop a prepared-but-never-committed segment's in-flight marker
        (its orphan file becomes ordinary gc fodder) and release the
        flush lock."""
        with self._lock:
            self._inflight.discard(meta.file)
        self._flush_lock.release()

    def _write_segment_file(self, packed: np.ndarray, num_records: int,
                            start_record: int) -> SegmentMeta:
        """Write the next segment id's immutable file (flush and merge
        share this); the segment becomes live only at the manifest commit."""
        m = self._manifest
        meta = SegmentMeta(segment_id=m.next_segment_id,
                           file=f"seg-{m.next_segment_id:08d}.seg",
                           start_record=start_record,
                           num_records=num_records,
                           num_keys=packed.shape[0])
        fmt.write_array_file(
            os.path.join(self.root, meta.file), {"packed": packed},
            meta={"segment_id": meta.segment_id,
                  "start_record": meta.start_record,
                  "num_records": meta.num_records})
        return meta

    def _commit(self, new: Manifest) -> None:
        """Atomic manifest swap.  The fsync-heavy file writes run
        without the store lock (appends never wait on them); only the
        in-memory manifest pointer flips under it.  WAL rotation is NOT
        handled here — :meth:`commit_segment` owns the three-phase
        rotation protocol; non-rotating commits (compaction merges)
        leave the WAL handle untouched."""
        commit(self.root, new)
        with self._lock:
            self._manifest = new
        self._segments_g.set(len(new.segments))

    # ------------------------------------------------------------ compaction
    def _tier(self, num_records: int) -> int:
        # integer arithmetic: float log truncates exact fanout powers
        # (int(math.log(243, 3)) == 4) and would mis-bucket them
        tier, bound = 0, self.compact_fanout
        while num_records >= bound:
            tier += 1
            bound *= self.compact_fanout
        return tier

    def compact(self, *, dry_run: bool = False) -> CompactionStats:
        """Tiered merge: while any ``compact_fanout``-long run of adjacent
        same-tier segments exists, splice it into one segment (write new
        file, atomic manifest swap).  Returns :class:`CompactionStats`
        (int-comparable as the merge count).  ``dry_run=True`` simulates
        the cascade without writing anything — ``bytes_written`` is then
        the merged payload estimate, not a measured file size."""
        stats = CompactionStats(dry_run=dry_run)
        if dry_run:
            segs = list(self._manifest.segments)
            while True:
                run = self._find_run(segs)
                if run is None:
                    return stats
                lo, hi = run
                total = sum(s.num_records for s in segs[lo:hi])
                stats.merges += 1
                stats.segments_merged += hi - lo
                stats.bytes_reclaimed += sum(
                    self._file_size(s.file) for s in segs[lo:hi])
                stats.bytes_written += (
                    segs[lo].num_keys * _num_words(total) * 4)
                segs[lo:hi] = [dataclasses.replace(
                    segs[lo], num_records=total)]
        while True:
            # each merge recomputes its run under the flush lock, so a
            # spill committed (or another compact pass run) between
            # iterations can never be merged against stale positions
            with self._flush_lock:
                run = self._find_run(self._manifest.segments)
                if run is None:
                    return stats
                with maybe_span("store.merge", lo=run[0], hi=run[1]):
                    self._merge(*run, stats=stats)

    def _file_size(self, name: str) -> int:
        try:
            return os.path.getsize(os.path.join(self.root, name))
        except OSError:
            return 0

    def _find_run(self, segs: Sequence[SegmentMeta]
                  ) -> tuple[int, int] | None:
        # a quarantined segment's bits are unreadable until repaired —
        # it can never join a merge run, and it breaks runs that would
        # otherwise span it (compaction serves around corruption)
        with self._lock:
            bad = set(self._quarantined)
        i = 0
        while i < len(segs):
            if segs[i].file in bad:
                i += 1
                continue
            j = i
            t = self._tier(segs[i].num_records)
            while (j < len(segs) and segs[j].file not in bad
                   and self._tier(segs[j].num_records) == t):
                j += 1
            if j - i >= self.compact_fanout:
                return i, i + self.compact_fanout
            i += 1
        return None

    def _merge(self, lo: int, hi: int, *,
               stats: CompactionStats | None = None) -> None:
        """Merge segments[lo:hi] (caller holds the flush lock, so the
        manifest's segment set cannot move under the slow splice — only
        WAL appends proceed concurrently)."""
        m = self._manifest
        run = m.segments[lo:hi]
        total = sum(s.num_records for s in run)
        merged = np.zeros((run[0].num_keys, _num_words(total)), np.uint32)
        at = 0
        for s in run:
            np_splice(merged, at, self.read_segment(s), s.num_records)
            at += s.num_records
        with self._lock:       # a concurrent gc must not eat the new file
            self._inflight.add(f"seg-{m.next_segment_id:08d}.seg")
        try:
            meta = self._write_segment_file(merged, total,
                                            run[0].start_record)
            self._commit(dataclasses.replace(
                m, version=m.version + 1,
                segments=m.segments[:lo] + (meta,) + m.segments[hi:],
                next_segment_id=m.next_segment_id + 1))
        finally:
            with self._lock:
                self._inflight.discard(f"seg-{m.next_segment_id:08d}.seg")
        if stats is not None:
            stats.merges += 1
            stats.segments_merged += hi - lo
            stats.bytes_written += self._file_size(meta.file)
            stats.bytes_reclaimed += sum(self._file_size(s.file)
                                         for s in run)

    # ------------------------------------------------------------- bulk read
    def load_packed(self) -> tuple[np.ndarray, int]:
        """Materialize the committed segments as one packed array
        ``(M, ceil(n/32))`` (WAL tail excluded).  Segments are contiguous
        and start 32-aligned relative to nothing — the host splice handles
        arbitrary offsets."""
        segs = self._manifest.segments
        n = self.durable_records
        if not segs:
            return np.zeros((0, 0), np.uint32), 0
        out = np.zeros((segs[0].num_keys, _num_words(n)), np.uint32)
        for s in segs:
            np_splice(out, s.start_record, self.read_segment(s),
                      s.num_records)
        return out, n

    # -------------------------------------------------------------------- gc
    def gc(self, *, dry_run: bool = False) -> GCStats:
        """Delete files unreachable from CURRENT (orphan segments from
        crashed flushes, superseded manifests, rotated WALs).  Safe to run
        concurrently with a background flush: files registered in-flight
        by :meth:`prepare_segment` / a compaction merge (and their
        ``.tmp`` twins, which an atomic write is about to replace) are
        skipped, not collected — without the guard a gc racing a spill
        could delete the very segment the next manifest swap commits.
        ``dry_run=True`` only reports.  Returns :class:`GCStats`
        (iterable/containment-compatible with the old filename list)."""
        with maybe_span("store.gc", dry_run=dry_run):
            return self._gc_sweep(dry_run=dry_run)

    def _gc_sweep(self, *, dry_run) -> GCStats:
        names = sorted(os.listdir(self.root))
        removed, skipped = [], []
        reclaimed = 0
        for name in names:
            # includes stale .tmp files (crash mid-atomic-write): the
            # atomic writers finish their replace before returning, so
            # an unreferenced, not-in-flight .tmp is never about to
            # become live
            if not (name.startswith(("seg-", "wal-", "MANIFEST-"))
                    or name.endswith(".tmp")):
                continue
            # the lock is taken PER FILE, with liveness re-checked under
            # it right before the unlink: an append (log_block) waits at
            # most one unlink, never the whole sweep — and a segment id
            # reused after an abort can't be deleted just as a new
            # prepare re-writes its file
            with self._lock:
                m = self._manifest
                # gen+1 stays live too: a rotation in flight (or crashed
                # pre-swap) may hold the stream's tail there (see
                # replay_wal)
                keep = {"CURRENT", f"MANIFEST-{m.version:08d}.json",
                        os.path.basename(self.wal_path()),
                        os.path.basename(wal_mod.wal_path(
                            self.root, m.wal_generation + 1))}
                keep |= {s.file for s in m.segments}
                if name in keep:
                    continue
                if name in self._inflight \
                        or name.removesuffix(".tmp") in self._inflight:
                    skipped.append(name)
                    continue
                reclaimed += self._file_size(name)
                if not dry_run:
                    try:
                        os.remove(os.path.join(self.root, name))
                    except FileNotFoundError:
                        pass            # someone else collected it
                removed.append(name)
        return GCStats(tuple(removed), reclaimed, tuple(skipped), dry_run)

    def health(self) -> dict:
        """Durability-side health snapshot (folded into
        ``BitmapService.health()``)."""
        with self._lock:
            quarantined = dict(self._quarantined)
            segments = len(self._manifest.segments)
        return {"quarantined": quarantined,
                "quarantine_events": self._quarantine_events_c.value,
                "repairs": self._repairs_c.value,
                "read_retries": self._read_retries_c.value,
                "segments": segments}

    def close(self) -> None:
        with self._lock:
            if self._wal is not None:
                self._wal.close()
                self._wal = None
                self._wal_gen = None


# --------------------------------------------------------- queryable handle
@dataclasses.dataclass
class StoredIndex:
    """Segment-parallel queryable view of a (possibly spilled) index: an
    ordered list of per-segment packed arrays covering disjoint record
    ranges, plus the total record count.  ``query_many`` serves a batch of
    predicate trees with one bucketed dispatch per segment and OR-splices
    the per-segment rows at their record offsets — no materialized
    full-index buffer (see :func:`repro.engine.batch.execute_many_segments`).
    """
    parts: tuple            # of (packed jax/np (M, w_i) uint32, n_i records)
    num_records: int

    @property
    def num_keys(self) -> int:
        return int(self.parts[0][0].shape[0]) if self.parts else 0

    @property
    def num_segments(self) -> int:
        return len(self.parts)

    def query_many(self, predicates: Sequence, *, backend: str = "auto"):
        from repro.engine import batch as engine_batch
        return engine_batch.execute_many_segments(
            self.parts, predicates, backend=backend)

    def to_bitmap_index(self):
        """Materialize one contiguous :class:`repro.engine.policy.BitmapIndex`
        (tests / small indexes only — serving should stay segment-parallel)."""
        from repro.engine import policy
        from repro.engine.runtime import append_packed
        import jax.numpy as jnp
        packed = jnp.zeros((self.num_keys, 0), jnp.uint32)
        n = 0
        for part, cnt in self.parts:
            packed = append_packed(packed, n, jnp.asarray(part), cnt)
            n += cnt
        return policy.BitmapIndex(packed, n)


def open_index(store: SegmentStore, *, tail=None) -> StoredIndex:
    """Open the committed segment set as a :class:`StoredIndex`.  ``tail``
    optionally appends an in-memory packed suffix ``(packed, num_records)``
    — e.g. a recovered WAL tail not yet flushed."""
    import jax.numpy as jnp
    parts = [(jnp.asarray(store.read_segment(s)), s.num_records)
             for s in store.segments]
    n = store.durable_records
    if tail is not None:
        tpacked, tcount = tail
        if tcount:
            parts.append((jnp.asarray(tpacked), int(tcount)))
            n += int(tcount)
    return StoredIndex(tuple(parts), n)


def recover_index(store: SegmentStore, keys, *, backend: str = "auto"):
    """Full crash recovery: committed segments + re-indexed WAL tail ->
    a :class:`repro.engine.policy.BitmapIndex` bit-identical to the
    never-crashed in-memory index (see ``StreamingIndexer.restore`` for
    recovery into a live appendable indexer)."""
    from repro.engine.runtime import StreamingIndexer
    return StreamingIndexer.restore(store, keys, backend=backend).index
