"""Write-ahead block log for streaming index appends.

Every record block appended to a store-attached ``StreamingIndexer`` is
logged here *before* it is spliced into the in-memory packed index, so a
crash between appends loses nothing: recovery re-indexes the logged blocks
deterministically (the engine backends are pure functions of their inputs)
and splices them onto the last durable segment, reproducing the in-memory
index bit for bit.

The log is generation-numbered (``wal-<gen>.log``).  When a segment flush
makes a prefix of the stream durable, the manifest commit switches to the
next generation and the old log becomes garbage — entries are never
rewritten in place.  Each entry carries the absolute record offset of its
block (``start``), so replay can also skip any block a committed segment
already covers (the crash-between-flush-and-rotate window).
"""
from __future__ import annotations

import os

import numpy as np

from repro.fault import seam
from repro.obs import metrics as obs_metrics
from repro.store import format as fmt

# WAL traffic meters live in the process-wide registry (logs are opened
# and handed across rotations; per-handle registries would lose counts)
_APPENDS = obs_metrics.GLOBAL.counter(
    "wal_appends_total", "framed block appends acked durable")
_BYTES = obs_metrics.GLOBAL.counter(
    "wal_bytes_total", "record payload bytes appended")
_ROTATIONS = obs_metrics.GLOBAL.counter(
    "wal_rotations_total", "fresh generations created by rotation")


def wal_path(root: str, generation: int) -> str:
    return os.path.join(root, f"wal-{generation:08d}.log")


class WriteAheadLog:
    """Append-only block log, one open generation at a time."""

    def __init__(self, path: str):
        self.path = path
        intact = fmt.intact_log_length(path)
        if intact == 0:
            self._f = open(path, "wb")       # fresh (or headerless) log
            fmt.write_log_header(self._f)
            # make the directory entry durable too: without this a crash
            # could drop the whole file, silently erasing every
            # acknowledged block logged since the last segment
            fmt.fsync_dir(os.path.dirname(path) or ".")
            return
        # drop any torn/corrupt tail BEFORE appending — entries written
        # after a torn frame would be unreachable to every reader
        self._f = open(path, "r+b")
        if os.path.getsize(path) > intact:
            self._f.truncate(intact)
        self._f.seek(intact)

    @classmethod
    def create(cls, path: str) -> "WriteAheadLog":
        """Open ``path`` as a FRESH generation, truncating any leftover
        bytes.  Rotation uses this instead of ``__init__``: a crash after
        a rotation pre-wrote the next generation but before its manifest
        swap leaves a stale file whose intact entries must NOT survive
        into the generation's real lifetime."""
        wal = cls.__new__(cls)
        wal.path = path
        wal._f = open(path, "wb")
        fmt.write_log_header(wal._f)
        fmt.fsync_dir(os.path.dirname(path) or ".")
        _ROTATIONS.inc()
        return wal

    def append_block(self, records: np.ndarray, start: int,
                     tick: int | None = None) -> None:
        """Durably log a record block whose first record has absolute
        offset ``start`` in the stream.  ``tick`` optionally stamps the
        workload tick that produced the block (the replay-idempotence
        watermark — see ``MulticoreRuntime.run_tick(tick_id=)``).

        On ANY append failure (full disk, torn frame, failed fsync) the
        handle rewinds to the last intact frame boundary before the
        error propagates: the failed entry is not durable and the caller
        knows it, but the NEXT append lands reachable — without the
        rewind, bytes written after a torn frame would be silently lost
        to every reader even though their appends "succeeded"."""
        records = np.ascontiguousarray(records)
        seam.fire("wal.append", path=self.path, start=int(start),
                  size=records.nbytes)
        meta = {"start": int(start), "dtype": str(records.dtype),
                "shape": list(records.shape)}
        if tick is not None:
            meta["tick"] = int(tick)
        pos = self._f.tell()
        try:
            fmt.append_log_entry(self._f, meta, records.tobytes())
        except BaseException:
            try:
                self._f.truncate(pos)
                self._f.seek(pos)
            except OSError:
                pass            # reopen-time truncation still covers it
            raise
        _APPENDS.inc()
        _BYTES.add(records.nbytes)

    def close(self) -> None:
        self._f.close()


def replay(path: str) -> list[tuple[int, np.ndarray, int | None]]:
    """All intact (start, records, tick) entries of a log, in append
    order.  Torn/corrupt tails (crash mid-append) are dropped, not
    raised."""
    return replay_from(path, 8)[0]


def replay_from(path: str, offset: int
                ) -> tuple[list[tuple[int, np.ndarray, int | None]], int]:
    """Intact (start, records, tick) entries from byte ``offset``
    onward, plus the byte offset one past the last intact frame — so a
    rotation can bulk-copy a live log outside the store lock and then
    catch only the raced tail under it."""
    out = []
    end = offset
    for meta, payload, end in fmt.read_log_entries_from(path, offset):
        arr = np.frombuffer(payload, dtype=np.dtype(meta["dtype"]))
        out.append((meta["start"], arr.reshape(meta["shape"]),
                    meta.get("tick")))
    return out, end
