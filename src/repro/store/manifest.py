"""Manifest: the atomically swapped source of truth for the live segment
set.

A manifest version is one JSON file (``MANIFEST-<v>.json``) listing the
ordered live segments, the open WAL generation, and the id counters.  The
``CURRENT`` pointer file names the committed version; commits write the new
manifest first, then atomically replace ``CURRENT`` — so a reader (or a
recovery after a crash at any point inside a commit) always sees one
complete, internally consistent segment set.  Files not reachable from
``CURRENT`` (orphan segments from a crashed flush, superseded manifests,
rotated WALs) are garbage, removed opportunistically by
:meth:`repro.store.store.SegmentStore.gc`.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.store import format as fmt

CURRENT = "CURRENT"


@dataclasses.dataclass(frozen=True)
class SegmentMeta:
    """Directory entry for one immutable segment file."""
    segment_id: int
    file: str                  # name relative to the store root
    start_record: int          # absolute offset of the segment's first record
    num_records: int
    num_keys: int

    @property
    def end_record(self) -> int:
        return self.start_record + self.num_records


@dataclasses.dataclass(frozen=True)
class Manifest:
    version: int
    segments: tuple[SegmentMeta, ...]      # ordered by start_record
    wal_generation: int
    next_segment_id: int
    # replay-idempotence watermark: highest workload tick covered by the
    # committed segments, and how many blocks of that tick they absorbed
    last_tick: int = -1
    last_tick_blocks: int = 0

    @property
    def durable_records(self) -> int:
        """Records covered by committed segments (the WAL replay floor)."""
        return self.segments[-1].end_record if self.segments else 0

    def to_json(self) -> dict:
        return {"version": self.version,
                "segments": [dataclasses.asdict(s) for s in self.segments],
                "wal_generation": self.wal_generation,
                "next_segment_id": self.next_segment_id,
                "last_tick": self.last_tick,
                "last_tick_blocks": self.last_tick_blocks}

    @classmethod
    def from_json(cls, obj: dict) -> "Manifest":
        segs = tuple(SegmentMeta(**s) for s in obj["segments"])
        m = cls(version=obj["version"], segments=segs,
                wal_generation=obj["wal_generation"],
                next_segment_id=obj["next_segment_id"],
                last_tick=obj.get("last_tick", -1),
                last_tick_blocks=obj.get("last_tick_blocks", 0))
        m.validate()
        return m

    def validate(self) -> None:
        at = 0
        for s in self.segments:
            if s.start_record != at or s.num_records <= 0:
                raise fmt.CorruptFileError(
                    f"manifest v{self.version}: segment {s.segment_id} "
                    f"covers [{s.start_record}, {s.end_record}) but the "
                    f"stream position is {at}")
            at = s.end_record


def manifest_path(root: str, version: int) -> str:
    return os.path.join(root, f"MANIFEST-{version:08d}.json")


def load(root: str) -> Manifest | None:
    """The committed manifest, or None for an empty/uninitialized store."""
    cur = os.path.join(root, CURRENT)
    try:
        with open(cur) as f:
            name = f.read().strip()
    except FileNotFoundError:
        return None
    with open(os.path.join(root, name)) as f:
        return Manifest.from_json(json.load(f))


def commit(root: str, m: Manifest) -> None:
    """Write MANIFEST-<v>, then atomically repoint CURRENT at it."""
    m.validate()
    fmt.write_json_atomic(manifest_path(root, m.version), m.to_json())
    fmt.write_bytes_atomic(os.path.join(root, CURRENT),
                           os.path.basename(
                               manifest_path(root, m.version)).encode())
