"""Cluster manifest: membership, replica groups, and segment handoff —
on the same atomic-swap substrate as :mod:`repro.store.manifest`.

One cluster root directory holds ``CLUSTER-<v>.json`` versions and a
``CURRENT`` pointer; a commit writes the new version file first and then
atomically repoints ``CURRENT``, so every reader (and every crash
recovery) sees one complete, internally consistent view of the fabric:
the :class:`~repro.fabric.shardmap.ShardMap`, each shard's replica
stores, and each shard's global-id table (the merge table mapping
shard-local record ordinals back to global bitmap positions).

Gid tables are content files referenced BY the manifest (CRC'd array
files, written before the swap), mirroring how segments relate to a
store manifest: the pointer swap is the only mutation, everything it
names is immutable once named.

**Rebalance is segment handoff**: shard stores are append-only sets of
immutable, CRC-verified segment files, so moving a shard to a new store
(or bringing a fresh replica into its group) is
:func:`sync_store` — copy the missing segment files, verify their
checksums, swap the destination's store manifest — followed by one
cluster-manifest commit that edits the replica tuple.  A crash between
the two leaves only an orphaned (never-referenced) copy, never a
half-moved shard.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil

import numpy as np

from repro.fabric.shardmap import ShardMap
from repro.store import format as fmt
from repro.store import manifest as store_manifest

__all__ = ["ShardEntry", "ClusterManifest", "load", "commit",
           "save_gids", "load_gids", "sync_store", "rebalance"]

CURRENT = "CURRENT"


@dataclasses.dataclass(frozen=True)
class ShardEntry:
    """One shard's place in the fabric: its replica stores (first entry
    is the preferred primary; reads may hedge across all of them) and
    its gid table."""
    shard_id: int
    replicas: tuple[str, ...]          # store roots (or socket addrs)
    num_records: int = 0
    gids_file: str | None = None       # CRC'd array file in cluster root

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["replicas"] = list(self.replicas)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ShardEntry":
        d = dict(d)
        d["replicas"] = tuple(d["replicas"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ClusterManifest:
    version: int
    shardmap: ShardMap
    shards: tuple[ShardEntry, ...]

    @property
    def num_records(self) -> int:
        return sum(s.num_records for s in self.shards)

    def shard(self, shard_id: int) -> ShardEntry:
        for s in self.shards:
            if s.shard_id == shard_id:
                return s
        raise KeyError(f"no shard {shard_id} in cluster "
                       f"v{self.version}")

    def validate(self) -> None:
        ids = [s.shard_id for s in self.shards]
        if ids != list(range(self.shardmap.num_shards)):
            raise fmt.CorruptFileError(
                f"cluster v{self.version}: shards {ids} != "
                f"0..{self.shardmap.num_shards - 1}")
        for s in self.shards:
            if not s.replicas:
                raise fmt.CorruptFileError(
                    f"cluster v{self.version}: shard {s.shard_id} "
                    f"has no replicas")

    def to_json(self) -> dict:
        return {"version": self.version,
                "shardmap": json.loads(self.shardmap.to_json()),
                "shards": [s.to_json() for s in self.shards]}

    @classmethod
    def from_json(cls, obj: dict) -> "ClusterManifest":
        m = cls(version=obj["version"],
                shardmap=ShardMap(**obj["shardmap"]),
                shards=tuple(ShardEntry.from_json(s)
                             for s in obj["shards"]))
        m.validate()
        return m

    # ------------------------------------------------------------- updates
    def with_shard(self, entry: ShardEntry) -> "ClusterManifest":
        """Next version with one shard entry replaced (commit it to make
        it real)."""
        shards = tuple(entry if s.shard_id == entry.shard_id else s
                       for s in self.shards)
        return dataclasses.replace(self, version=self.version + 1,
                                   shards=shards)


def _path(root: str, version: int) -> str:
    return os.path.join(root, f"CLUSTER-{version:08d}.json")


def load(root: str) -> ClusterManifest | None:
    """The committed cluster manifest, or None for an empty root."""
    try:
        with open(os.path.join(root, CURRENT)) as f:
            name = f.read().strip()
    except FileNotFoundError:
        return None
    with open(os.path.join(root, name)) as f:
        return ClusterManifest.from_json(json.load(f))


def commit(root: str, m: ClusterManifest) -> None:
    """Write CLUSTER-<v>, then atomically repoint CURRENT at it."""
    m.validate()
    os.makedirs(root, exist_ok=True)
    fmt.write_json_atomic(_path(root, m.version), m.to_json())
    fmt.write_bytes_atomic(os.path.join(root, CURRENT),
                           os.path.basename(_path(root, m.version))
                           .encode())


# ------------------------------------------------------------- gid tables
def save_gids(root: str, shard_id: int, version: int,
              gids: np.ndarray) -> str:
    """Write one shard's gid table as a versioned CRC'd array file;
    returns the file name to put in its :class:`ShardEntry` (call
    BEFORE committing the manifest that references it)."""
    name = f"gids-{shard_id:04d}-{version:08d}.arr"
    os.makedirs(root, exist_ok=True)
    fmt.write_array_file(os.path.join(root, name),
                         {"gids": np.asarray(gids, np.int64)})
    return name


def load_gids(root: str, entry: ShardEntry) -> np.ndarray:
    if entry.gids_file is None:
        return np.zeros(0, np.int64)
    arrays, _ = fmt.read_array_file(os.path.join(root, entry.gids_file))
    return np.asarray(arrays["gids"], np.int64)


# -------------------------------------------------------- segment handoff
def sync_store(src_root: str, dst_root: str) -> int:
    """Bring ``dst_root`` up to ``src_root``'s committed segment set:
    copy every missing segment file, re-verify each copy's CRC, copy the
    schema, then swap in a copy of the source's committed manifest.
    Returns the number of segments shipped.  Idempotent (re-running
    ships nothing) — this is both replica bring-up and rebalance
    handoff."""
    if os.path.normpath(src_root) == os.path.normpath(dst_root):
        return 0                       # self-sync: trivially up to date
    src_m = store_manifest.load(src_root)
    if src_m is None:
        raise FileNotFoundError(f"{src_root}: no committed manifest "
                                "(snapshot the shard first)")
    os.makedirs(dst_root, exist_ok=True)
    shipped = 0
    for seg in src_m.segments:
        dst_file = os.path.join(dst_root, seg.file)
        if os.path.exists(dst_file):
            continue
        shutil.copyfile(os.path.join(src_root, seg.file),
                        dst_file + ".part")
        os.replace(dst_file + ".part", dst_file)
        fmt.read_array_file(dst_file)          # CRC gate before commit
        shipped += 1
    schema = os.path.join(src_root, "SCHEMA.json")
    if os.path.exists(schema):
        shutil.copyfile(schema, os.path.join(dst_root, "SCHEMA.json"))
    # fresh replica starts a WAL generation of its own; the manifest's
    # segment set is what replication promises, and only that
    store_manifest.commit(dst_root, src_m)
    return shipped


def rebalance(root: str, m: ClusterManifest, shard_id: int,
              new_store: str, *, drop: str | None = None
              ) -> ClusterManifest:
    """Move/extend shard ``shard_id``'s replica group onto
    ``new_store``: ship its segments, then commit ONE manifest version
    adding the new replica (and optionally dropping an old one).
    Returns the committed manifest."""
    entry = m.shard(shard_id)
    sync_store(entry.replicas[0], new_store)
    replicas = tuple(r for r in entry.replicas if r != drop)
    if new_store not in replicas:
        replicas = replicas + (new_store,)
    if not replicas:
        raise ValueError(f"shard {shard_id}: rebalance would leave "
                         "no replicas")
    m2 = m.with_shard(dataclasses.replace(entry, replicas=replicas))
    commit(root, m2)
    return m2
