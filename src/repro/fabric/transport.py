"""The fabric's ``Transport`` seam: loopback and framed sockets.

A transport carries :class:`~repro.fabric.envelope.Envelope` requests to
one shard host and routes its replies back, correlated by ``msg_id``.
Two implementations share the contract:

  * :class:`LoopbackTransport` — in-process: envelopes are still
    **encoded and decoded** on every hop (so a type that cannot cross a
    real wire fails in unit tests, not in production) and still pass the
    ``rpc.send`` / ``rpc.recv`` fault seams (so a chaos schedule's
    network profile exercises the exact drop/duplicate/reorder handling
    the socket path uses, without sockets);
  * :class:`SocketTransport` — length-prefixed frames over TCP to a
    shard worker process (:func:`serve_socket` is the accept loop a
    worker runs).  One reader thread demultiplexes replies into the
    pending-future table.

Fault semantics (the ``network`` chaos profile): ``drop`` discards the
envelope — a request's future then times out and the CLIENT is
responsible for retry (appends carry sequence numbers, so a retried
write is deduplicated server-side; that is the zero-acked-loss
argument).  ``duplicate`` delivers twice; the host dedups appends and
the client counts surplus replies in ``stats()``.  ``reorder`` holds an
envelope until the next one passes.  ``stall`` sleeps inside the seam.

A dropped reply and a dropped request are indistinguishable to the
caller — both surface as :class:`ReplyTimeout` — which is exactly the
ambiguity real networks force, and why the append protocol is
idempotent rather than clever.
"""
from __future__ import annotations

import socket
import struct
import threading

from repro.fabric import envelope as env_mod
from repro.fabric.envelope import Envelope, WireError
from repro.fault import seam

__all__ = ["ReplyFuture", "ReplyTimeout", "TransportClosed",
           "LoopbackTransport", "SocketTransport", "serve_socket"]


class ReplyTimeout(TimeoutError):
    """No reply within the deadline (request or reply may have been
    lost — the fabric cannot tell which)."""


class TransportClosed(RuntimeError):
    """Send on a closed/failed transport."""


class ReplyFuture:
    """One in-flight request's reply slot.  ``cancel()`` abandons it
    (hedged-read losers do this); a reply landing afterwards is counted
    by the transport as ``late`` instead of delivered."""

    __slots__ = ("msg_id", "_ev", "_env", "_err", "_cancelled", "_lock")

    def __init__(self, msg_id: int):
        self.msg_id = msg_id
        self._ev = threading.Event()
        self._env: Envelope | None = None
        self._err: BaseException | None = None
        self._cancelled = False
        self._lock = threading.Lock()

    def _resolve(self, env: Envelope) -> bool:
        """True if the reply was delivered (False: cancelled/dup)."""
        with self._lock:
            if self._cancelled or self._ev.is_set():
                return False
            self._env = env
        self._ev.set()
        return True

    def _reject(self, err: BaseException) -> bool:
        with self._lock:
            if self._cancelled or self._ev.is_set():
                return False
            self._err = err
        self._ev.set()
        return True

    def cancel(self) -> bool:
        """Abandon the request (True if it had not resolved yet)."""
        with self._lock:
            if self._ev.is_set():
                return False
            self._cancelled = True
        self._ev.set()
        return True

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    def done(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._ev.wait(timeout)

    def result(self, timeout: float | None = None) -> Envelope:
        if not self._ev.wait(timeout):
            raise ReplyTimeout(
                f"no reply to msg {self.msg_id} within {timeout}s")
        if self._cancelled:
            raise ReplyTimeout(f"request msg {self.msg_id} was cancelled")
        if self._err is not None:
            raise self._err
        return self._env


class _Gate:
    """Drop/duplicate/reorder state for one seam direction.  ``admit``
    maps one envelope to the list actually delivered now (a held
    envelope rides behind the next admitted one)."""

    __slots__ = ("site", "name", "_held", "_lock")

    def __init__(self, site: str, name: str):
        self.site = site
        self.name = name
        self._held: list = []
        self._lock = threading.Lock()

    def admit(self, item, *, kind: str, size: int) -> list:
        d = seam.fire(self.site, path=self.name, kind=kind, size=size)
        if d:
            if d.get("drop"):
                out = []
            elif d.get("duplicate"):
                out = [item, item]
            elif d.get("hold"):
                with self._lock:
                    self._held.append(item)
                return []
            else:
                out = [item]
        else:
            out = [item]
        with self._lock:
            if self._held:
                out = out + self._held
                self._held = []
        return out

    def flush(self) -> list:
        """Release anything still held (transport close: a held frame
        must not be silently lost forever)."""
        with self._lock:
            out, self._held = self._held, []
            return out


class _PendingTable:
    """msg_id -> ReplyFuture, with late/duplicate-reply accounting."""

    def __init__(self):
        self._lock = threading.Lock()
        self._pending: dict[int, ReplyFuture] = {}
        self._ids = 0
        self.late_replies = 0          # replies for cancelled/unknown ids

    def new(self) -> ReplyFuture:
        with self._lock:
            self._ids += 1
            fut = ReplyFuture(self._ids)
            self._pending[fut.msg_id] = fut
        return fut

    def resolve(self, env: Envelope) -> None:
        with self._lock:
            fut = self._pending.pop(env.msg_id, None)
        if fut is None or not fut._resolve(env):
            with self._lock:
                self.late_replies += 1

    def fail_all(self, err: BaseException) -> None:
        with self._lock:
            futs = list(self._pending.values())
            self._pending.clear()
        for fut in futs:
            fut._reject(err)

    def forget(self, fut: ReplyFuture) -> None:
        with self._lock:
            self._pending.pop(fut.msg_id, None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)


class LoopbackTransport:
    """In-process transport over a :class:`repro.fabric.protocol.
    ServiceHost` (see module docstring for why it still encodes and
    still fires the rpc seams)."""

    def __init__(self, host, *, name: str = "loopback"):
        self._host = host
        self.name = name
        self._pending = _PendingTable()
        self._send_gate = _Gate("rpc.send", name)
        self._recv_gate = _Gate("rpc.recv", name)
        self._closed = False

    # one logical wire, same seam sites as the socket path: requests
    # fire ``rpc.send`` on the way out, replies fire ``rpc.recv`` on the
    # way back — one faulty hop per direction, so a chaos schedule's
    # occurrence numbering is identical between loopback and socket
    # runs, and a held (reordered) frame can only ever be released by
    # traffic of its OWN direction
    def send(self, env: Envelope) -> ReplyFuture:
        if self._closed:
            raise TransportClosed(f"loopback {self.name} is closed")
        fut = self._pending.new()
        env = Envelope(env.kind, msg_id=fut.msg_id, trace=env.trace,
                       payload=env.payload)
        frame = env_mod.encode(env)
        for f in self._send_gate.admit(frame, kind=env.kind,
                                       size=len(frame)):
            self._host.handle(env_mod.decode(f), self._on_reply)
        return fut

    def _on_reply(self, reply: Envelope) -> None:
        # a dropped/held ack is the interesting case for exactly-once
        # appends: the request applied, the client cannot know
        frame = env_mod.encode(reply)
        for f in self._recv_gate.admit(frame, kind=reply.kind,
                                       size=len(frame)):
            self._pending.resolve(env_mod.decode(f))

    def request(self, env: Envelope, timeout: float | None = None
                ) -> Envelope:
        fut = self.send(env)
        try:
            return fut.result(timeout)
        finally:
            self._pending.forget(fut)

    def stats(self) -> dict:
        return {"name": self.name, "kind": "loopback",
                "pending": len(self._pending),
                "late_replies": self._pending.late_replies}

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        # release reordered holds in-direction (held requests reach the
        # host, held replies reach their futures), then fail the rest
        for f in self._send_gate.flush():
            self._host.handle(env_mod.decode(f), self._on_reply)
        for f in self._recv_gate.flush():
            self._pending.resolve(env_mod.decode(f))
        self._pending.fail_all(TransportClosed(
            f"loopback {self.name} closed"))


_LEN = struct.Struct("<I")


def _read_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed mid-frame"
                                  if buf else "peer closed")
        buf += chunk
    return bytes(buf)


def _write_frame(sock: socket.socket, frame: bytes,
                 lock: threading.Lock) -> None:
    with lock:
        sock.sendall(_LEN.pack(len(frame)) + frame)


def _read_frame(sock: socket.socket) -> bytes:
    (n,) = _LEN.unpack(_read_exact(sock, _LEN.size))
    return _read_exact(sock, n)


class SocketTransport:
    """Framed-TCP client to one shard worker.  Thread-safe: any thread
    may ``send``; one reader thread resolves replies."""

    def __init__(self, address: tuple[str, int], *,
                 name: str | None = None, connect_timeout: float = 10.0):
        self.address = address
        self.name = name or f"{address[0]}:{address[1]}"
        self._sock = socket.create_connection(address,
                                              timeout=connect_timeout)
        self._sock.settimeout(None)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._wlock = threading.Lock()
        self._pending = _PendingTable()
        self._send_gate = _Gate("rpc.send", self.name)
        self._recv_gate = _Gate("rpc.recv", self.name)
        self._closed = False
        self._reader = threading.Thread(
            target=self._read_loop, name=f"fabric-reader-{self.name}",
            daemon=True)
        self._reader.start()

    def send(self, env: Envelope) -> ReplyFuture:
        if self._closed:
            raise TransportClosed(f"socket {self.name} is closed")
        fut = self._pending.new()
        env = Envelope(env.kind, msg_id=fut.msg_id, trace=env.trace,
                       payload=env.payload)
        frame = env_mod.encode(env)
        try:
            for f in self._send_gate.admit(frame, kind=env.kind,
                                           size=len(frame)):
                _write_frame(self._sock, f, self._wlock)
        except OSError as e:
            self._pending.forget(fut)
            raise TransportClosed(f"socket {self.name}: {e}") from e
        return fut

    def request(self, env: Envelope, timeout: float | None = None
                ) -> Envelope:
        fut = self.send(env)
        try:
            return fut.result(timeout)
        finally:
            self._pending.forget(fut)

    def _read_loop(self) -> None:
        try:
            while True:
                frame = _read_frame(self._sock)
                env = env_mod.decode(frame)
                for f in self._recv_gate.admit(frame, kind=env.kind,
                                               size=len(frame)):
                    self._pending.resolve(env_mod.decode(f))
        except (OSError, ConnectionError, WireError) as e:
            for f in self._recv_gate.flush():
                self._pending.resolve(env_mod.decode(f))
            if not self._closed:
                self._pending.fail_all(TransportClosed(
                    f"socket {self.name} reader died: {e}"))

    def stats(self) -> dict:
        return {"name": self.name, "kind": "socket",
                "address": list(self.address),
                "pending": len(self._pending),
                "late_replies": self._pending.late_replies}

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        self._pending.fail_all(TransportClosed(
            f"socket {self.name} closed"))


class serve_socket:
    """The worker-side accept loop: every connection gets a reader
    thread that feeds decoded envelopes to ``host.handle`` and writes
    its (possibly later) replies back under a per-connection lock.

    Class-as-function naming: instances are single-use servers —
    ``serve_socket(host, port=0)`` starts listening immediately;
    ``.port`` is the bound port, ``.close()`` stops.  The server side
    deliberately fires NO rpc seams: one faulty hop per direction
    (client-side send + recv) keeps a chaos schedule's occurrence
    numbering identical between loopback and socket runs.
    """

    def __init__(self, host, *, address: str = "127.0.0.1",
                 port: int = 0, backlog: int = 64):
        self._host = host
        self._lsock = socket.create_server((address, port),
                                           backlog=backlog)
        self.address = self._lsock.getsockname()
        self.port = self.address[1]
        self._closed = False
        self._conns: list[socket.socket] = []
        self._lock = threading.Lock()
        self._accept = threading.Thread(
            target=self._accept_loop, name=f"fabric-accept-{self.port}",
            daemon=True)
        self._accept.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                return                      # listener closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                if self._closed:
                    conn.close()
                    return
                self._conns.append(conn)
            threading.Thread(target=self._conn_loop, args=(conn,),
                             name=f"fabric-conn-{self.port}",
                             daemon=True).start()

    def _conn_loop(self, conn: socket.socket) -> None:
        wlock = threading.Lock()

        def reply(env: Envelope) -> None:
            try:
                _write_frame(conn, env_mod.encode(env), wlock)
            except OSError:
                pass                        # client gone; reply moot

        try:
            while True:
                env = env_mod.decode(_read_frame(conn))
                self._host.handle(env, reply)
        except (OSError, ConnectionError, WireError):
            pass
        finally:
            conn.close()
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)

    def close(self) -> None:
        self._closed = True
        try:
            self._lsock.close()
        except OSError:
            pass
        with self._lock:
            conns, self._conns = list(self._conns), []
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()
