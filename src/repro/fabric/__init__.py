"""`repro.fabric` — one query plane over many shard stores.

The source paper scales by replicating duty-cycled cores; this package is
the software analogue at the process level: N shard stores (each a full
``BitmapDB`` + ``BitmapService`` stack) behind ONE submit/future query
surface.  The pieces:

  * :mod:`repro.fabric.envelope` — the typed, pickle-free wire codec and
    the message envelope every fabric hop speaks (trace context rides in
    the envelope, so a query's span chain crosses process boundaries);
  * :mod:`repro.fabric.transport` — the ``Transport`` seam: an
    in-process loopback and a framed-socket transport share one
    request/reply contract (and the ``rpc.send``/``rpc.recv`` fault
    seams, so chaos schedules cover the network);
  * :mod:`repro.fabric.protocol` — ``ServiceHost``: submit / drain /
    metrics / health / append as plain messages over a
    :class:`repro.serve.service.BitmapService`;
  * :mod:`repro.fabric.shardmap` — hash / block partitioning of the
    record space, predicate pruning to owning shards;
  * :mod:`repro.fabric.cluster` — the atomically swapped cluster
    manifest (membership, replica groups, rebalance by segment handoff);
  * :mod:`repro.fabric.client` — :class:`FabricClient`: the
    ``submit()``/future facade that scatters a predicate, hedges reads
    across replicas, and merges per-shard rows bit-identically to a
    single-node session;
  * :mod:`repro.fabric.worker` — the multiprocess shard worker
    entrypoint (spawn a ``BitmapService`` + socket server per store).

Imports stay lazy (the worker spawns fresh interpreters; pulling jax at
package import would double every child's startup cost).
"""
from __future__ import annotations

_LAZY = {
    "Envelope": "repro.fabric.envelope",
    "encode": "repro.fabric.envelope",
    "decode": "repro.fabric.envelope",
    "ShardMap": "repro.fabric.shardmap",
    "ClusterManifest": "repro.fabric.cluster",
    "FabricClient": "repro.fabric.client",
    "FabricFuture": "repro.fabric.client",
    "ServiceHost": "repro.fabric.protocol",
    "LoopbackTransport": "repro.fabric.transport",
    "SocketTransport": "repro.fabric.transport",
    "serve_socket": "repro.fabric.transport",
    "spawn_shards": "repro.fabric.worker",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.fabric' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
