"""The fabric's message envelope and pickle-free wire codec.

Every hop in the fabric — loopback or socket, client or worker — speaks
one message shape: an :class:`Envelope` with a ``kind``, a
per-connection ``msg_id`` (replies echo it; that is the whole RPC
correlation story), an optional ``trace`` context tuple (cross-process
span propagation: the receiving side parents its spans under it), and a
``payload`` dict of plain values.

The codec is deliberately NOT pickle: a shard worker should only ever be
able to receive data, not code.  It round-trips exactly the types the
protocol needs — None, bool, int, float, str, bytes, list, tuple, dict,
and C-contiguous numpy arrays (dtype + shape + raw bytes) — and raises
on anything else, so an unserializable payload fails at the sender with
a type name instead of at the receiver with a parse error.  Frames are
length-prefixed and CRC-guarded: a corrupted frame surfaces as
:class:`WireError`, never as silently wrong bits.

Queries cross the wire as structured trees (:func:`query_to_wire` /
:func:`query_from_wire`): ``repro.engine.planner`` predicates and
``repro.db.expr`` expressions both lower to tagged lists, so the shard
side rebuilds the exact expression object and its plan cache behaves as
if the query had been submitted locally.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

__all__ = ["Envelope", "WireError", "encode", "decode",
           "query_to_wire", "query_from_wire"]

#: codec version stamped into every frame (reject, don't guess, on skew)
WIRE_VERSION = 1

_HEADER = struct.Struct("<IBI")        # payload length, version, crc32


class WireError(RuntimeError):
    """A frame failed to parse or verify (truncation, CRC, bad tag)."""


@dataclasses.dataclass(frozen=True)
class Envelope:
    """One fabric message (see module docstring)."""
    kind: str
    msg_id: int = 0
    trace: tuple | None = None         # (trace_id, span_id) or None
    payload: dict = dataclasses.field(default_factory=dict)

    def reply(self, kind: str, **payload) -> "Envelope":
        """A reply envelope correlated to this request (echoes msg_id;
        the trace context does NOT propagate back — the reply lands in
        the waiting span on the requesting side)."""
        return Envelope(kind, msg_id=self.msg_id, payload=payload)


# ------------------------------------------------------------------ values
_T_NONE, _T_TRUE, _T_FALSE, _T_INT, _T_FLOAT = b"n", b"t", b"f", b"i", b"d"
_T_STR, _T_BYTES, _T_LIST, _T_TUPLE, _T_DICT = b"s", b"b", b"l", b"u", b"m"
_T_NDARRAY = b"a"


def _enc(v, out: list) -> None:
    if v is None:
        out.append(_T_NONE)
    elif v is True:
        out.append(_T_TRUE)
    elif v is False:
        out.append(_T_FALSE)
    elif isinstance(v, int) and not isinstance(v, bool):
        b = str(v).encode()
        out.append(_T_INT + struct.pack("<I", len(b)) + b)
    elif isinstance(v, float):
        out.append(_T_FLOAT + struct.pack("<d", v))
    elif isinstance(v, str):
        b = v.encode()
        out.append(_T_STR + struct.pack("<I", len(b)) + b)
    elif isinstance(v, (bytes, bytearray)):
        out.append(_T_BYTES + struct.pack("<I", len(v)) + bytes(v))
    elif isinstance(v, np.ndarray):
        arr = np.ascontiguousarray(v)
        dt = arr.dtype.str.encode()
        shape = ",".join(str(s) for s in arr.shape).encode()
        raw = arr.tobytes()
        out.append(_T_NDARRAY + struct.pack("<III", len(dt), len(shape),
                                            len(raw)) + dt + shape + raw)
    elif isinstance(v, (list, tuple)):
        out.append((_T_LIST if isinstance(v, list) else _T_TUPLE)
                   + struct.pack("<I", len(v)))
        for item in v:
            _enc(item, out)
    elif isinstance(v, dict):
        out.append(_T_DICT + struct.pack("<I", len(v)))
        for k, item in v.items():
            if not isinstance(k, str):
                raise TypeError(f"wire dict keys must be str, got "
                                f"{type(k).__name__}")
            _enc(k, out)
            _enc(item, out)
    elif isinstance(v, np.generic):          # numpy scalar -> python
        _enc(v.item(), out)
    else:
        raise TypeError(f"type {type(v).__name__} does not cross the "
                        f"fabric wire (value {v!r:.60})")


class _Reader:
    __slots__ = ("buf", "pos")

    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        b = self.buf[self.pos:self.pos + n]
        if len(b) != n:
            raise WireError(f"truncated frame: wanted {n} bytes at "
                            f"{self.pos}, have {len(b)}")
        self.pos += n
        return b


def _dec(r: _Reader):
    tag = r.take(1)
    if tag == _T_NONE:
        return None
    if tag == _T_TRUE:
        return True
    if tag == _T_FALSE:
        return False
    if tag == _T_INT:
        (n,) = struct.unpack("<I", r.take(4))
        return int(r.take(n))
    if tag == _T_FLOAT:
        return struct.unpack("<d", r.take(8))[0]
    if tag == _T_STR:
        (n,) = struct.unpack("<I", r.take(4))
        return r.take(n).decode()
    if tag == _T_BYTES:
        (n,) = struct.unpack("<I", r.take(4))
        return r.take(n)
    if tag == _T_NDARRAY:
        nd, ns, nr = struct.unpack("<III", r.take(12))
        dt = np.dtype(r.take(nd).decode())
        shape_s = r.take(ns).decode()
        shape = tuple(int(s) for s in shape_s.split(",")) if shape_s \
            else ()
        raw = r.take(nr)
        return np.frombuffer(raw, dtype=dt).reshape(shape).copy()
    if tag in (_T_LIST, _T_TUPLE):
        (n,) = struct.unpack("<I", r.take(4))
        items = [_dec(r) for _ in range(n)]
        return items if tag == _T_LIST else tuple(items)
    if tag == _T_DICT:
        (n,) = struct.unpack("<I", r.take(4))
        return {_dec(r): _dec(r) for _ in range(n)}
    raise WireError(f"unknown wire tag {tag!r} at {r.pos - 1}")


# ---------------------------------------------------------------- envelope
def encode(env: Envelope) -> bytes:
    """Envelope -> one self-delimited CRC-guarded frame."""
    out: list[bytes] = []
    _enc({"kind": env.kind, "msg_id": env.msg_id,
          "trace": env.trace, "payload": env.payload}, out)
    body = b"".join(out)
    return _HEADER.pack(len(body), WIRE_VERSION,
                        zlib.crc32(body) & 0xFFFFFFFF) + body


def decode(frame: bytes) -> Envelope:
    """One full frame -> Envelope (raises :class:`WireError` on any
    truncation, version skew, or checksum mismatch)."""
    if len(frame) < _HEADER.size:
        raise WireError(f"frame shorter than header ({len(frame)} bytes)")
    length, version, crc = _HEADER.unpack_from(frame)
    if version != WIRE_VERSION:
        raise WireError(f"wire version {version} != {WIRE_VERSION}")
    body = frame[_HEADER.size:]
    if len(body) != length:
        raise WireError(f"frame body {len(body)} bytes, header says "
                        f"{length}")
    if zlib.crc32(body) & 0xFFFFFFFF != crc:
        raise WireError("frame checksum mismatch")
    obj = _dec(_Reader(body))
    trace = obj.get("trace")
    return Envelope(kind=obj["kind"], msg_id=obj["msg_id"],
                    trace=tuple(trace) if trace is not None else None,
                    payload=obj["payload"])


def header_size() -> int:
    return _HEADER.size


def frame_length(header: bytes) -> int:
    """Body length promised by a raw header (socket readers use this to
    know how much more to recv)."""
    length, version, _ = _HEADER.unpack(header)
    if version != WIRE_VERSION:
        raise WireError(f"wire version {version} != {WIRE_VERSION}")
    return length


# ------------------------------------------------------------------ queries
def query_to_wire(q):
    """A planner predicate / db expression -> a tagged tree of plain
    values.  Raises TypeError on anything else (pre-built plans do not
    cross the wire — the shard side plans against ITS stats)."""
    from repro.db import expr as expr_mod
    from repro.engine import planner

    if isinstance(q, planner.Key):
        return ["key", q.index]
    if isinstance(q, planner.Not):
        return ["not", query_to_wire(q.child)]
    if isinstance(q, planner.And):
        return ["and", [query_to_wire(c) for c in q.children]]
    if isinstance(q, planner.Or):
        return ["or", [query_to_wire(c) for c in q.children]]
    if isinstance(q, expr_mod.NotExpr):
        return ["enot", query_to_wire(q.child)]
    if isinstance(q, expr_mod.AndExpr):
        return ["eand", [query_to_wire(c) for c in q.children]]
    if isinstance(q, expr_mod.OrExpr):
        return ["eor", [query_to_wire(c) for c in q.children]]
    if isinstance(q, expr_mod.Eq):
        return ["eq", q.column, q.value]
    if isinstance(q, expr_mod.In):
        return ["in", q.column, list(q.values)]
    if isinstance(q, expr_mod.Between):
        return ["between", q.column, q.lo, q.hi]
    raise TypeError(f"cannot send {type(q).__name__} over the fabric "
                    "wire (expressions and predicate trees only)")


def query_from_wire(obj):
    """Inverse of :func:`query_to_wire` — rebuilds the exact expression/
    predicate object, so shard-side plan caches key identically."""
    from repro.db import expr as expr_mod
    from repro.engine import planner

    obj = list(obj)
    tag = obj[0]
    if tag == "key":
        return planner.key(obj[1])
    if tag == "not":
        return planner.Not(query_from_wire(obj[1]))
    if tag == "and":
        return planner.And(tuple(query_from_wire(c) for c in obj[1]))
    if tag == "or":
        return planner.Or(tuple(query_from_wire(c) for c in obj[1]))
    if tag == "enot":
        return expr_mod.NotExpr(query_from_wire(obj[1]))
    if tag == "eand":
        return expr_mod.AndExpr(tuple(query_from_wire(c) for c in obj[1]))
    if tag == "eor":
        return expr_mod.OrExpr(tuple(query_from_wire(c) for c in obj[1]))
    if tag == "eq":
        return expr_mod.Eq(obj[1], obj[2])
    if tag == "in":
        return expr_mod.In(obj[1], tuple(obj[2]))
    if tag == "between":
        return expr_mod.Between(obj[1], obj[2], obj[3])
    raise WireError(f"unknown query tag {tag!r}")
