"""Shard map: which store owns which records, and which shards a
predicate can touch.

Two partitioning strategies over the encoded record space (records are
``(N, num_columns)`` int32 key-word rows — see
:meth:`repro.db.Schema.encode`):

  * ``hash`` — records route by a seeded splitmix64 hash of ONE
    column's key word.  Every record with the same value of that column
    lands on the same shard, so a ``Key`` predicate on the sharded
    column prunes the scatter to exactly one shard (``And`` intersects
    its children's owner sets, ``Or`` unions them, ``Not`` and keys of
    other columns fan out to everyone).  Shard-local record blocks are
    interleaved in the global order, so the merge is the OR-splice path.
  * ``block`` — contiguous slabs of ``block_size`` records: shard ``i``
    owns global ordinals ``[i*block_size, (i+1)*block_size)`` (the last
    shard unbounded).  No predicate pruning, but per-shard results are
    contiguous runs of the global bitmap — the concatenation merge.

Either way the map is pure arithmetic on (key word, global ordinal):
deterministic, JSON-serializable (it lives inside the cluster
manifest), and identical in every process that loads the same manifest.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.engine import planner

__all__ = ["ShardMap"]

_M64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def _mix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer (uint64 in, uint64 out).  The
    sharded column's key ids are small dense integers; without a strong
    mix, ``% num_shards`` would stripe them pathologically."""
    with np.errstate(over="ignore"):   # mod-2^64 wrap is the algorithm
        x = (x + np.uint64(0x9E3779B97F4A7C15)) & _M64
        x ^= x >> np.uint64(30)
        x = (x * np.uint64(0xBF58476D1CE4E5B9)) & _M64
        x ^= x >> np.uint64(27)
        x = (x * np.uint64(0x94D049BB133111EB)) & _M64
        x ^= x >> np.uint64(31)
    return x


@dataclasses.dataclass(frozen=True)
class ShardMap:
    """See module docstring.  Build with :meth:`hashed` or
    :meth:`blocked`; the raw constructor exists for deserialization."""
    num_shards: int
    strategy: str = "hash"              # "hash" | "block"
    column: str | None = None           # hash: the sharded column
    column_index: int = 0               # its word position in records
    base: int = 0                       # its first global key id
    cardinality: int = 0                # key ids it owns
    block_size: int = 0                 # block: records per slab
    seed: int = 0                       # hash salt

    def __post_init__(self):
        if self.num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if self.strategy not in ("hash", "block"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.strategy == "block" and self.block_size < 1:
            raise ValueError("block strategy needs block_size >= 1")

    # ---------------------------------------------------------- constructors
    @classmethod
    def hashed(cls, schema, column: str, num_shards: int, *,
               seed: int = 0) -> "ShardMap":
        """Hash-partition on ``column`` of ``schema``."""
        col = schema[column]
        idx = [c.name for c in schema.columns].index(column)
        return cls(num_shards=num_shards, strategy="hash", column=column,
                   column_index=idx, base=col.base,
                   cardinality=col.cardinality, seed=seed)

    @classmethod
    def blocked(cls, num_shards: int, *, total_records: int = 0,
                block_size: int = 0) -> "ShardMap":
        """Contiguous slabs; pass the build-time ``total_records`` to
        split evenly, or pin ``block_size`` directly."""
        if block_size < 1:
            block_size = max(1, -(-max(total_records, 1) // num_shards))
        return cls(num_shards=num_shards, strategy="block",
                   block_size=block_size)

    # --------------------------------------------------------------- routing
    def shard_of_key(self, key_id: int) -> int:
        """The shard owning every record whose sharded-column word is
        ``key_id`` (hash strategy only)."""
        if self.strategy != "hash":
            raise ValueError("shard_of_key is a hash-strategy notion")
        h = _mix64(np.uint64(int(key_id)) ^ np.uint64(self.seed))
        return int(h % np.uint64(self.num_shards))

    def route(self, records, *, start_gid: int = 0) -> np.ndarray:
        """Per-record owning shard for an encoded batch appended at
        global ordinal ``start_gid``."""
        records = np.asarray(records)
        n = records.shape[0]
        if self.strategy == "hash":
            words = records[:, self.column_index].astype(np.uint64)
            return (_mix64(words ^ np.uint64(self.seed))
                    % np.uint64(self.num_shards)).astype(np.int64)
        gids = start_gid + np.arange(n, dtype=np.int64)
        return np.minimum(gids // self.block_size, self.num_shards - 1)

    def partition(self, records, *, start_gid: int = 0
                  ) -> list[tuple[int, np.ndarray, np.ndarray]]:
        """Split a batch into ``(shard_id, local_records, gids)`` parts
        (shards with no records are omitted).  ``gids`` are the global
        ordinals of each shard's records, in local append order — the
        client's merge tables."""
        records = np.asarray(records)
        shard = self.route(records, start_gid=start_gid)
        out = []
        for s in range(self.num_shards):
            ix = np.flatnonzero(shard == s)
            if ix.size:
                out.append((s, records[ix], (start_gid + ix)
                            .astype(np.int64)))
        return out

    # --------------------------------------------------------------- pruning
    def owners(self, pred) -> frozenset | None:
        """The set of shards a lowered predicate can match on, or None
        when every shard must be consulted.  An EMPTY set is a real
        answer: the predicate contradicts itself on the sharded column
        and matches nothing anywhere."""
        if self.strategy != "hash":
            return None
        return self._walk(pred)

    def _walk(self, p) -> frozenset | None:
        if isinstance(p, planner.Key):
            if self.base <= p.index < self.base + self.cardinality:
                return frozenset((self.shard_of_key(p.index),))
            return None
        if isinstance(p, planner.And):
            known = [k for k in (self._walk(c) for c in p.children)
                     if k is not None]
            if not known:
                return None
            out = known[0]
            for k in known[1:]:
                out = out & k
            return out
        if isinstance(p, planner.Or):
            parts = [self._walk(c) for c in p.children]
            if any(k is None for k in parts) or not parts:
                return None
            return frozenset().union(*parts)
        return None                     # Not / anything else: no pruning

    # ----------------------------------------------------------------- wire
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ShardMap":
        return cls(**json.loads(text))
