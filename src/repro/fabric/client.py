"""``FabricClient`` — the existing ``submit()``/future surface, scattered
over N shard stores.

The client mirrors the service's micro-batch discipline one level up:
``submit()`` enqueues and returns a :class:`FabricFuture` immediately; a
scheduler thread coalesces a wave, lowers each query to a predicate,
prunes it to its owning shards (:meth:`ShardMap.owners`), sends one
``query`` envelope per touched shard, and merges the per-shard packed
rows back into ONE global bitmap per query — bit-identical to what a
single-node :class:`~repro.serve.service.BitmapService` would return for
the same data, whatever the partitioning:

  * every shard's reply is mapped through that shard's **gid table**
    (shard-local record ordinal -> global ordinal) and OR'd into the
    global row — hash partitioning interleaves records, so this is the
    general splice;
  * a shard whose gids are one contiguous, word-aligned run (the block
    strategy) short-circuits to a direct word-wise OR of its packed row
    at the right offset — the concatenation case.

**Hedged reads**: each per-shard request goes to a seeded permutation of
the shard's replicas; if the first pick has not answered within
``hedge_delay_ms``, the next replica is launched too, and the first
completed reply wins.  Losers are ``cancel()``'ed (late replies are
counted, never delivered).  The clock, the waiter, and the permutation
seed are all injectable, so winner selection is exactly reproducible
under a fake clock — that is what the hedging tests pin down.

**Exactly-once appends**: writes route by the shard map and carry a
per-(client, shard) sequence number; a timed-out append is retried with
the SAME sequence number and deduplicated server-side, so a drop or
duplicate on either leg of the exchange can delay an acknowledgement but
never lose or double-apply an acknowledged write.

**Observability roll-up**: ``metrics()`` fans a ``metrics`` envelope to
every shard and returns the per-shard dicts plus a fabric-level energy
roll-up (summed shard ledgers — each shard still reconciles locally);
traced clients stamp each scatter's span context into the envelopes, so
shard-side ``rpc.query`` spans parent under the client's wave.
"""
from __future__ import annotations

import itertools
import os
import random
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.db import expr as expr_mod
from repro.fabric.envelope import Envelope, query_to_wire
from repro.fabric.shardmap import ShardMap
from repro.fabric.transport import ReplyTimeout
from repro.obs import trace as obs_trace
from repro.serve.service import ServiceClosed

__all__ = ["FabricClient", "FabricFuture", "FabricError"]


class FabricError(RuntimeError):
    """A shard replied ``error`` (the remote exception, re-raised
    client-side with its type name in the message)."""


class FabricFuture:
    """Handle to one fabric query — the :class:`repro.serve.service.
    QueryFuture` surface (``result``/``rows``/``count``/``ids``) over a
    merged global result row."""

    __slots__ = ("query", "_ev", "_row", "_count", "_n", "_err",
                 "trace_id", "count_only")

    def __init__(self, query, *, count_only: bool = False):
        self.query = query
        self.count_only = count_only
        self._ev = threading.Event()
        self._row: np.ndarray | None = None
        self._count = 0
        self._n = 0
        self._err: BaseException | None = None
        self.trace_id: int | None = None

    def _resolve(self, row, count: int, n: int) -> None:
        if self._ev.is_set():
            return
        self._row, self._count, self._n = row, int(count), int(n)
        self._ev.set()

    def _reject(self, err: BaseException) -> None:
        if self._ev.is_set():
            return
        self._err = err
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._ev.wait(timeout)

    def _ready(self, timeout: float | None = None) -> None:
        if not self._ev.wait(timeout):
            raise TimeoutError(f"query not served within {timeout}s")
        if self._err is not None:
            raise self._err

    def result(self, timeout: float | None = None):
        """(packed global row (W,) uint32, count) — ``W = ceil(N/32)``
        for the fabric's N total records (zero-width when the future was
        submitted ``count_only``)."""
        self._ready(timeout)
        return self._row, self._count

    def exception(self, timeout: float | None = None):
        self._ev.wait(timeout)
        return self._err

    @property
    def rows(self):
        return self.result()[0]

    @property
    def count(self) -> int:
        self._ready()
        return self._count

    @property
    def ids(self) -> np.ndarray:
        from repro.db.result import unpack_ids
        return unpack_ids(np.asarray(self.rows), self._n)

    def __repr__(self) -> str:
        state = ("failed" if self._err is not None
                 else "done" if self.done() else "pending")
        return f"<FabricFuture {state} {self.query!r:.60}>"


class _Item:
    __slots__ = ("pred", "future", "t")

    def __init__(self, pred, future, t):
        self.pred, self.future, self.t = pred, future, t


def _default_waiter(futs, timeout: float, clock) -> object | None:
    """First completed future, polling (events are per-future; the poll
    interval bounds added latency on the multi-replica path only)."""
    if len(futs) == 1:
        return futs[0] if futs[0].wait(max(timeout, 0.0)) else None
    deadline = clock() + max(timeout, 0.0)
    while True:
        for f in futs:
            if f.done():
                return f
        left = deadline - clock()
        if left <= 0:
            return None
        time.sleep(min(2e-4, left))


class FabricClient:
    """See module docstring.  ``transports`` is one replica list per
    shard (``transports[s][0]`` is the preferred primary); ``gids`` is
    one int64 global-ordinal table per shard (fresh empty fabric:
    omit)."""

    def __init__(self, transports: Sequence[Sequence], shardmap: ShardMap,
                 *, schema=None, gids: Sequence[np.ndarray] | None = None,
                 max_batch: int = 1024, max_delay_ms: float = 2.0,
                 hedge_delay_ms: float = 20.0, hedge_seed: int = 0,
                 request_timeout_s: float = 30.0,
                 request_retries: int = 2, append_retries: int = 5,
                 background: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 waiter=None, name: str = "fabric", _owned_hosts=()):
        if len(transports) != shardmap.num_shards:
            raise ValueError(f"{len(transports)} transport groups for "
                             f"{shardmap.num_shards} shards")
        self._transports = [list(g) for g in transports]
        self.shardmap = shardmap
        self.schema = schema
        self.name = name
        if gids is None:
            gids = [np.zeros(0, np.int64)] * shardmap.num_shards
        self._gids = [np.asarray(g, np.int64) for g in gids]
        self._total = int(sum(len(g) for g in self._gids))
        self.max_batch = max_batch
        self.max_delay_s = max_delay_ms / 1e3
        self.hedge_delay_s = hedge_delay_ms / 1e3
        self.hedge_seed = hedge_seed
        self.request_timeout_s = request_timeout_s
        self.request_retries = request_retries
        self.append_retries = append_retries
        self.background = background
        self.clock = clock
        self.waiter = waiter or (
            lambda futs, timeout: _default_waiter(futs, timeout, clock))
        self._owned_hosts = list(_owned_hosts)
        # append streams: one monotone sequence per (client, shard)
        self._stream = f"c{os.getpid()}-{id(self):x}"
        self._next_seq = [0] * shardmap.num_shards
        self._append_lock = threading.Lock()
        # hedging accounting (metrics()): seeded per-request permutation
        self._req_ids = itertools.count(1)
        self._hedges_launched = 0
        self._hedge_wins = 0
        self._losers_cancelled = 0
        self._append_retries_done = 0
        self._served = 0
        self._stats_lock = threading.Lock()
        # client-side micro-batch scheduler (mirrors the service's)
        self._cv = threading.Condition()
        self._pending: list[_Item] = []
        self._inflight = 0
        self._openflag = True
        self._close_lock = threading.Lock()
        self._flush_lock = threading.Lock()
        self._pool = None
        self._pool_lock = threading.Lock()
        self._thread = None
        if background:
            self._thread = threading.Thread(
                target=self._run, name=f"repro-fabric-{name}",
                daemon=True)
            self._thread.start()

    # ------------------------------------------------------------- builders
    @classmethod
    def local(cls, stores: Sequence, shardmap: ShardMap, *,
              schema=None, gids=None, service_config=None, **kw
              ) -> "FabricClient":
        """An all-in-process fabric: each element of ``stores`` is a
        ``BitmapDB`` (or a replica list of them) to wrap in a
        ``BitmapService`` + ``ServiceHost`` + loopback transport.  The
        client owns the hosts and closes them with itself."""
        from repro.fabric.protocol import ServiceHost
        from repro.fabric.transport import LoopbackTransport
        from repro.serve.service import BitmapService, ServiceConfig

        cfg = service_config or ServiceConfig()
        hosts, groups = [], []
        for sid, group in enumerate(stores):
            if not isinstance(group, (list, tuple)):
                group = [group]
            ts = []
            for ri, db in enumerate(group):
                svc = db if isinstance(db, BitmapService) \
                    else BitmapService(db, cfg)
                host = ServiceHost(svc, shard_id=sid)
                hosts.append(host)
                ts.append(LoopbackTransport(
                    host, name=f"shard{sid}r{ri}"))
            groups.append(ts)
        if schema is None:
            for group in stores:
                g0 = group[0] if isinstance(group, (list, tuple)) \
                    else group
                schema = getattr(getattr(g0, "db", g0), "schema", None)
                if schema is not None:
                    break
        return cls(groups, shardmap, schema=schema, gids=gids,
                   _owned_hosts=hosts, **kw)

    @classmethod
    def connect(cls, addresses: Sequence, shardmap: ShardMap, *,
                schema=None, gids=None, **kw) -> "FabricClient":
        """A fabric over running shard workers: ``addresses`` is one
        ``(host, port)`` (or a replica list of them) per shard."""
        from repro.fabric.transport import SocketTransport
        groups = []
        for group in addresses:
            if isinstance(group, tuple) and len(group) == 2 \
                    and isinstance(group[1], int):
                group = [group]
            groups.append([SocketTransport(tuple(a)) for a in group])
        return cls(groups, shardmap, schema=schema, gids=gids, **kw)

    # --------------------------------------------------------------- submit
    @property
    def num_records(self) -> int:
        return self._total

    @property
    def num_shards(self) -> int:
        return self.shardmap.num_shards

    def gids(self, shard: int) -> np.ndarray:
        return self._gids[shard]

    def submit(self, query, *, timeout: float | None = None,
               count_only: bool = False) -> FabricFuture:
        """Enqueue one query; returns its future immediately.  Queries
        are schema expressions or predicate trees (pre-built plans stay
        node-local and cannot cross the fabric)."""
        pred = expr_mod.lower(query, self.schema)
        fut = FabricFuture(query, count_only=count_only)
        with self._cv:
            if not self._openflag:
                raise ServiceClosed("submit() on a closed FabricClient")
            self._pending.append(_Item(pred, fut, self.clock()))
            self._inflight += 1
            self._cv.notify_all()
        if not self.background and len(self._pending) >= self.max_batch:
            self._flush_inline()
        _ = timeout                     # admission is unbounded here
        return fut

    def submit_many(self, queries: Sequence, *, count_only: bool = False
                    ) -> list[FabricFuture]:
        return [self.submit(q, count_only=count_only) for q in queries]

    def drain(self, timeout: float | None = None) -> bool:
        if not self.background:
            self._flush_inline()
        with self._cv:
            return self._cv.wait_for(lambda: self._inflight == 0,
                                     timeout=timeout)

    # ------------------------------------------------------------ scheduler
    def _run(self) -> None:
        try:
            while True:
                with self._cv:
                    while self._openflag and not self._pending:
                        self._cv.wait()
                    if not self._pending:
                        return          # closed and drained
                    deadline = self._pending[0].t + self.max_delay_s
                    while (len(self._pending) < self.max_batch
                           and self._openflag):
                        left = deadline - self.clock()
                        if left <= 0:
                            break
                        self._cv.wait(timeout=min(left, 0.05))
                    batch = self._pending[:self.max_batch]
                    del self._pending[:len(batch)]
                    self._cv.notify_all()
                self._execute_wave(batch)
        except BaseException as e:      # noqa: BLE001 — never hang callers
            with self._cv:
                self._openflag = False
                for it in self._pending:
                    it.future._reject(e)
                self._inflight -= len(self._pending)
                self._pending.clear()
                self._cv.notify_all()
            raise

    def _flush_inline(self) -> None:
        # serialized: concurrent one-shot flushers must not interleave
        # partial waves (the same race close() has with submit())
        with self._flush_lock:
            while True:
                with self._cv:
                    if not self._pending:
                        return
                    batch = self._pending[:self.max_batch]
                    del self._pending[:len(batch)]
                    self._cv.notify_all()
                self._execute_wave(batch)

    # ---------------------------------------------------------- the scatter
    def _execute_wave(self, batch: list[_Item]) -> None:
        tr = obs_trace.TRACER
        if tr is None:
            self._scatter(batch, None)
        else:
            with tr.span("fabric.scatter", size=len(batch)) as sp:
                for it in batch:
                    it.future.trace_id = sp.trace_id
                self._scatter(batch, sp.context)
        with self._cv:
            self._inflight -= len(batch)
            self._cv.notify_all()
        with self._stats_lock:
            self._served += len(batch)

    def _scatter(self, batch: list[_Item], trace) -> None:
        total = self._total
        width = (total + 31) >> 5
        # shard -> ([wave indexes], count_only?) — split full/count so an
        # envelope's reply shape is uniform
        per_shard: dict[tuple[int, bool], list[int]] = {}
        for wi, it in enumerate(batch):
            owners = self.shardmap.owners(it.pred)
            if owners is None:
                owners = range(self.num_shards)
            elif not owners:
                # the predicate contradicts itself on the sharded
                # column: provably empty, no scatter at all
                row = (None if it.future.count_only
                       else np.zeros(width, np.uint32))
                it.future._resolve(row, 0, total)
                continue
            for s in owners:
                per_shard.setdefault(
                    (s, it.future.count_only), []).append(wi)
        if not per_shard:
            return
        merged_rows: dict[int, np.ndarray] = {}    # wave ix -> global row
        counts = [0] * len(batch)
        parts = []
        pool = self._ensure_pool()
        for (s, count_only), wis in per_shard.items():
            env = Envelope("query", trace=trace, payload={
                "queries": [query_to_wire(batch[wi].pred) for wi in wis],
                "count_only": count_only})
            parts.append((s, count_only, wis,
                          pool.submit(self._shard_request, s, env)))
        mlock = threading.Lock()
        for s, count_only, wis, task in parts:
            try:
                reply = task.result()
            except BaseException as e:   # noqa: BLE001 — to the futures
                for wi in wis:
                    batch[wi].future._reject(e)
                continue
            p = reply.payload
            failed = {int(qi): msg for qi, msg in p.get("errors", [])}
            rows = p.get("rows")
            shard_n = min(int(p["num_records"]), len(self._gids[s]))
            with mlock:
                for qi, wi in enumerate(wis):
                    if qi in failed:
                        batch[wi].future._reject(FabricError(
                            f"shard {s}: {failed[qi]}"))
                        continue
                    counts[wi] += int(p["counts"][qi])
                    if not count_only:
                        out = merged_rows.get(wi)
                        if out is None:
                            out = merged_rows[wi] = np.zeros(
                                width, np.uint32)
                        self._merge_row(out, np.asarray(rows[qi]),
                                        self._gids[s], shard_n)
        for wi, it in enumerate(batch):
            if it.future.done():
                continue
            row = (None if it.future.count_only
                   else merged_rows.get(wi,
                                        np.zeros(width, np.uint32)))
            it.future._resolve(row, counts[wi], total)

    @staticmethod
    def _merge_row(out: np.ndarray, local: np.ndarray,
                   gids: np.ndarray, shard_n: int) -> None:
        """OR one shard's packed result row into the global row through
        its gid table (see module docstring for the two cases)."""
        if shard_n == 0:
            return
        gids = gids[:shard_n]
        nw = (shard_n + 31) >> 5
        start = int(gids[0])
        if (start & 31) == 0 and gids[-1] - start == shard_n - 1 \
                and (shard_n == 1
                     or bool(np.all(np.diff(gids) == 1))):
            # contiguous + word-aligned: the concatenation case
            w0 = start >> 5
            out[w0:w0 + nw] |= local[:nw]
            return
        from repro.db.result import unpack_ids
        ids = unpack_ids(local[:nw], shard_n)
        if ids.size == 0:
            return
        g = gids[ids]
        np.bitwise_or.at(out, g >> 5,
                         (np.uint32(1) << (g & 31).astype(np.uint32)))

    # --------------------------------------------------------- hedged reads
    def _shard_request(self, shard: int, env: Envelope,
                       *, hedge: bool = True,
                       timeout: float | None = None) -> Envelope:
        """One request to ``shard`` with retries (reads are idempotent);
        each attempt hedges across replicas."""
        timeout = self.request_timeout_s if timeout is None else timeout
        last: BaseException | None = None
        for _ in range(self.request_retries + 1):
            try:
                return self._hedged(shard, env, timeout, hedge=hedge)
            except ReplyTimeout as e:
                last = e
        raise last

    def _hedged(self, shard: int, env: Envelope, timeout: float,
                *, hedge: bool = True) -> Envelope:
        replicas = self._transports[shard]
        if not hedge:
            # writes and control envelopes go to the PRIMARY, never a
            # shuffled pick — a write landing on a random replica would
            # silently diverge the group
            order = [0]
        else:
            order = list(range(len(replicas)))
            if len(order) > 1:
                # the permutation (not the clock) is the seeded part:
                # same hedge_seed + request index -> same replica
                # order, always
                rng = random.Random(self.hedge_seed * 1_000_003
                                    + next(self._req_ids))
                rng.shuffle(order)
        clock = self.clock
        deadline = clock() + timeout
        launched: list = []
        launched_ix: list[int] = []
        win = None
        next_i = 0
        last_launch = 0.0
        while True:
            now = clock()
            if next_i < len(order) and (
                    not launched
                    or now >= last_launch + self.hedge_delay_s):
                launched.append(
                    replicas[order[next_i]].send(env))
                launched_ix.append(order[next_i])
                last_launch = now
                if next_i > 0:
                    with self._stats_lock:
                        self._hedges_launched += 1
                next_i += 1
            wait_until = deadline if next_i >= len(order) else min(
                deadline, last_launch + self.hedge_delay_s)
            win = self.waiter(launched, wait_until - now)
            if win is not None:
                break
            if clock() >= deadline and next_i >= len(order):
                for f in launched:
                    if f.cancel():
                        with self._stats_lock:
                            self._losers_cancelled += 1
                raise ReplyTimeout(
                    f"shard {shard}: no replica answered {env.kind!r} "
                    f"within {timeout}s")
        for ix, f in zip(launched_ix, launched):
            if f is win:
                if ix != order[0]:
                    with self._stats_lock:
                        self._hedge_wins += 1
            elif f.cancel():
                with self._stats_lock:
                    self._losers_cancelled += 1
        reply = win.result(0)
        if reply.kind == "error":
            raise FabricError(f"shard {shard} "
                              f"[{reply.payload.get('type')}]: "
                              f"{reply.payload.get('error')}")
        return reply

    # -------------------------------------------------------------- appends
    def append(self, rows) -> int:
        """Route schema rows to their shards; returns the new global
        record count once every touched shard acknowledged."""
        if self.schema is None:
            raise RuntimeError("append(rows) needs a schema; use "
                               "append_encoded for raw key words")
        return self.append_encoded(self.schema.encode(rows))

    def append_encoded(self, records) -> int:
        records = np.asarray(records, np.int32)
        if records.ndim != 2:
            raise ValueError(f"records must be (N, W), got "
                             f"{records.shape}")
        with self._append_lock:
            parts = self.shardmap.partition(records,
                                            start_gid=self._total)
            for shard, recs, gids in parts:
                seq = self._next_seq[shard] + 1
                self._append_one(shard, seq, recs)
                self._next_seq[shard] = seq
                self._gids[shard] = np.concatenate(
                    [self._gids[shard], gids])
            self._total += records.shape[0]
            return self._total

    def _append_one(self, shard: int, seq: int,
                    recs: np.ndarray) -> None:
        env = Envelope("append", payload={
            "stream": self._stream, "seq": seq, "records": recs})
        last: BaseException | None = None
        for attempt in range(self.append_retries + 1):
            try:
                # writes go to the primary only, never hedged (a hedged
                # write would double-apply on replica divergence); a
                # retry reuses the SAME seq -> server dedup
                self._shard_request(shard, env, hedge=False)
                return
            except ReplyTimeout as e:
                last = e
                if attempt < self.append_retries:
                    with self._stats_lock:
                        self._append_retries_done += 1
        raise last

    # ------------------------------------------------------------- controls
    def _broadcast(self, kind: str, **payload) -> list[dict]:
        out = []
        for s in range(self.num_shards):
            reply = self._shard_request(
                s, Envelope(kind, payload=payload), hedge=False)
            out.append(dict(reply.payload))
        return out

    def drain_shards(self, timeout_s: float | None = None) -> bool:
        return all(p.get("ok", False)
                   for p in self._broadcast("drain",
                                            timeout_s=timeout_s))

    def info(self) -> list[dict]:
        """Per-shard ``{shard_id, num_records, num_keys}`` straight from
        each primary — the server-side word on what is durably applied
        (the client's own ``num_records`` counter only says what was
        acknowledged to *this* client)."""
        return self._broadcast("info")

    def health(self) -> dict:
        shards = self._broadcast("health")
        return {"degraded": any(p.get("degraded") for p in shards),
                "shards": shards}

    def metrics(self) -> dict:
        """Fabric-level counters + per-shard service metrics + the
        energy roll-up (shard ledger totals summed)."""
        shards = self._broadcast("metrics")
        energy = [p.get("energy") or {} for p in shards]
        with self._stats_lock:
            mine = {
                "submitted": self._served + self._inflight,
                "served": self._served,
                "hedges_launched": self._hedges_launched,
                "hedge_wins": self._hedge_wins,
                "losers_cancelled": self._losers_cancelled,
                "append_retries": self._append_retries_done,
            }
        mine["late_replies"] = sum(
            t.stats()["late_replies"]
            for group in self._transports for t in group)
        return {
            **mine,
            "num_records": self._total,
            "num_shards": self.num_shards,
            "shards": shards,
            "energy": {
                "total_joules": sum(e.get("total_joules", 0.0)
                                    for e in energy),
                "active_joules": sum(
                    e.get("phase_joules", {}).get("busy", 0.0)
                    + e.get("phase_joules", {}).get("awake_idle", 0.0)
                    for e in energy),
                "standby_joules": sum(
                    e.get("phase_joules", {}).get("standby", 0.0)
                    for e in energy),
                "per_shard": energy,
            },
        }

    def transport_stats(self) -> list[list[dict]]:
        return [[t.stats() for t in group]
                for group in self._transports]

    # ------------------------------------------------------------ lifecycle
    def _ensure_pool(self):
        with self._pool_lock:
            if self._pool is None:
                from concurrent.futures import ThreadPoolExecutor
                self._pool = ThreadPoolExecutor(
                    max_workers=max(2, self.num_shards),
                    thread_name_prefix=f"fabric-io-{self.name}")
            return self._pool

    def close(self, timeout: float | None = None) -> None:
        """Drain, stop the scheduler, close owned hosts and transports.
        Idempotent AND safe to call concurrently with in-flight
        ``submit()`` — a racing submit either wins admission (and its
        future resolves before teardown) or gets ``ServiceClosed``."""
        with self._close_lock:
            with self._cv:
                already = not self._openflag
                self._openflag = False
                self._cv.notify_all()
            if already:
                return
            if not self.background:
                self._flush_inline()
            if self._thread is not None:
                self._thread.join(timeout=timeout)
                self._thread = None
            with self._cv:
                # a scheduler that died early strands accepted items:
                # reject, never hang their callers
                for it in self._pending:
                    it.future._reject(ServiceClosed(
                        "FabricClient closed before this query served"))
                self._inflight -= len(self._pending)
                self._pending.clear()
                self._cv.notify_all()
            with self._pool_lock:
                if self._pool is not None:
                    self._pool.shutdown(wait=True)
                    self._pool = None
            for group in self._transports:
                for t in group:
                    t.close()
            for host in self._owned_hosts:
                host.close(timeout=timeout)

    def __enter__(self) -> "FabricClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"<FabricClient {self.name} shards={self.num_shards} "
                f"records={self._total} "
                f"{'open' if self._openflag else 'closed'}>")
