"""``ServiceHost`` — a :class:`repro.serve.service.BitmapService` as a
message handler.

One host maps the fabric's envelope kinds onto the service API; it is
transport-agnostic — the loopback transport calls :meth:`handle`
directly, the socket server calls it per decoded frame.  ``handle`` is
asynchronous by contract: it returns as soon as the request is enqueued
and delivers the reply through the callback when ready, so a query
envelope rides the service's micro-batch scheduler exactly like a local
``submit()`` (the resolver thread waits on the futures; the scheduler
coalesces as usual).

Envelope kinds (the protocol ARCHITECTURE.md documents)::

    ping      {}                            -> pong {shard_id}
    info      {}                            -> info {shard_id,
                                               num_records, num_keys}
    query     {queries: [wire trees],       -> result {rows (Q, Nw) u32
               count_only: bool}               | None, counts (Q,) i64,
                                               num_records, errors:
                                               [[qi, message], ...]}
    append    {stream, seq,                 -> appended {seq, num_records,
               records: (N, W) i32}            duplicate: bool}
    drain     {timeout_s?}                  -> drained {ok}
    metrics   {}                            -> metrics {...}  (the
                                               ServiceMetrics dict, incl.
                                               the energy-ledger snapshot)
    health    {}                            -> health {...}
    shutdown  {}                            -> bye {}  (then the worker's
                                               on_shutdown runs)

Anything that raises maps to an ``error`` reply carrying the exception
type and message — never a dropped request.

**Exactly-once appends**: every append carries a per-stream sequence
number; the host remembers the highest applied seq per stream and
acknowledges (without re-applying) anything at or below it.  A client
that never got the ack retries the SAME seq, so drops and duplicates on
either leg converge to applied-exactly-once + acked.

**Trace propagation**: a request envelope's ``trace`` tuple becomes the
parent of the host-side ``rpc.<kind>`` span — the one rule that stitches
client and shard span trees into a single cross-process trace.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.fabric.envelope import Envelope
from repro.obs import trace as obs_trace

__all__ = ["ServiceHost"]


class ServiceHost:
    """See module docstring.  ``shard_id`` names this shard in replies
    and health artifacts; ``on_shutdown`` (worker processes pass one)
    runs after a ``shutdown`` envelope is acknowledged."""

    def __init__(self, service: "BitmapService", *, shard_id: int = 0,
                 on_shutdown=None):
        self.service = service
        self.shard_id = shard_id
        self._shutdown_cb = on_shutdown
        self._applied_seq: dict[str, int] = {}    # stream -> highest seq
        self._append_lock = threading.Lock()
        self._resolveq: queue.SimpleQueue = queue.SimpleQueue()
        self._closed = False
        self._resolver = threading.Thread(
            target=self._resolve_loop,
            name=f"fabric-host-{shard_id}", daemon=True)
        self._resolver.start()

    # -------------------------------------------------------------- dispatch
    def handle(self, env: Envelope, reply) -> None:
        """Process one request; ``reply(Envelope)`` is called exactly
        once, possibly from another thread, possibly after this
        returns."""
        tr = obs_trace.TRACER
        if tr is None:
            self._dispatch(env, reply)
            return
        with tr.span(f"rpc.{env.kind}", parent=env.trace,
                     shard=self.shard_id, msg_id=env.msg_id):
            self._dispatch(env, reply)

    def _dispatch(self, env: Envelope, reply) -> None:
        try:
            fn = getattr(self, f"_on_{env.kind}", None)
            if fn is None:
                reply(env.reply("error", type="ValueError",
                                error=f"unknown envelope kind "
                                      f"{env.kind!r}"))
                return
            fn(env, reply)
        except BaseException as e:       # noqa: BLE001 — to the wire
            reply(env.reply("error", type=type(e).__name__,
                            error=str(e)))

    # -------------------------------------------------------------- handlers
    def _on_ping(self, env: Envelope, reply) -> None:
        reply(env.reply("pong", shard_id=self.shard_id))

    def _on_info(self, env: Envelope, reply) -> None:
        db = self.service.db
        reply(env.reply("info", shard_id=self.shard_id,
                        num_records=int(db.num_records),
                        num_keys=int(db.num_keys)))

    def _on_query(self, env: Envelope, reply) -> None:
        from repro.fabric.envelope import query_from_wire
        queries = [query_from_wire(w) for w in env.payload["queries"]]
        count_only = bool(env.payload.get("count_only", False))
        # trace context is captured HERE (inside the rpc.query span) so
        # the admission/queue/serve spans the service records parent
        # under the cross-process request
        futs = [self.service.submit(q) for q in queries]
        self._resolveq.put((env, futs, count_only, reply))

    def _resolve_loop(self) -> None:
        """Waits out query futures OFF the transport thread: the socket
        reader keeps draining frames (more queries coalesce into the
        running wave) while earlier envelopes await their results."""
        while True:
            item = self._resolveq.get()
            if item is None:
                return
            env, futs, count_only, reply = item
            rows_out: list[np.ndarray] = []
            counts = np.zeros(len(futs), np.int64)
            errors: list[list] = []
            n = 0
            for qi, fut in enumerate(futs):
                try:
                    row, count = fut.result()
                    counts[qi] = int(count)
                    n = max(n, fut._n)
                    if not count_only:
                        rows_out.append(np.asarray(row, np.uint32))
                except BaseException as e:   # noqa: BLE001 — per query
                    errors.append([qi, f"{type(e).__name__}: {e}"])
                    if not count_only:
                        rows_out.append(None)
            rows = None
            if not count_only:
                # all live rows share the wave-padded word width; failed
                # slots become zero rows so the array stays rectangular
                width = max((r.shape[-1] for r in rows_out
                             if r is not None), default=0)
                rows = np.zeros((len(futs), width), np.uint32)
                for qi, r in enumerate(rows_out):
                    if r is not None:
                        rows[qi, :r.shape[-1]] = r
            try:
                reply(env.reply("result", rows=rows, counts=counts,
                                num_records=int(n), errors=errors))
            except BaseException:            # noqa: BLE001 — peer gone
                pass

    def _on_append(self, env: Envelope, reply) -> None:
        p = env.payload
        stream = p["stream"]
        seq = int(p["seq"])
        records = np.asarray(p["records"], np.int32)
        with self._append_lock:
            last = self._applied_seq.get(stream, 0)
            if seq <= last:
                reply(env.reply(
                    "appended", seq=seq, duplicate=True,
                    num_records=int(self.service.db.num_records)))
                return
            if seq != last + 1:
                reply(env.reply(
                    "error", type="GapError",
                    error=f"stream {stream!r}: seq {seq} after {last} "
                          f"(a gap means an earlier append was lost "
                          f"client-side — refuse, don't reorder)"))
                return
            n = self.service.db.append_encoded(records)
            self._applied_seq[stream] = seq
        reply(env.reply("appended", seq=seq, duplicate=False,
                        num_records=int(n)))

    def _on_drain(self, env: Envelope, reply) -> None:
        ok = self.service.drain(timeout=env.payload.get("timeout_s"))
        reply(env.reply("drained", ok=bool(ok)))

    def _on_metrics(self, env: Envelope, reply) -> None:
        reply(env.reply("metrics", shard_id=self.shard_id,
                        **_plain(self.service.metrics().to_dict())))

    def _on_health(self, env: Envelope, reply) -> None:
        reply(env.reply("health", shard_id=self.shard_id,
                        **_plain(self.service.health())))

    def _on_shutdown(self, env: Envelope, reply) -> None:
        reply(env.reply("bye", shard_id=self.shard_id))
        if self._shutdown_cb is not None:
            self._shutdown_cb()

    # ------------------------------------------------------------- lifecycle
    def close(self, timeout: float | None = None) -> None:
        """Stop the resolver (after it drains queued work) and close the
        underlying service.  Idempotent."""
        if not self._closed:
            self._closed = True
            self._resolveq.put(None)
            self._resolver.join(timeout=timeout)
        self.service.close(timeout=timeout)


def _plain(obj):
    """Wire-encodable copy of a metrics/health tree: numpy scalars to
    Python, tuples preserved, Nones kept (the codec handles the rest)."""
    if isinstance(obj, dict):
        return {str(k): _plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_plain(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, float) and obj != obj:      # NaN -> None (wire)
        return None
    return obj
