"""Multiprocess shard workers: one ``BitmapDB`` + ``BitmapService`` +
socket server per spawned process.

:func:`spawn_shards` launches N workers (``multiprocessing`` spawn
context — each child is a fresh interpreter that imports jax on its own)
and returns a :class:`ShardFleet` with their bound addresses; the parent
then builds a :class:`~repro.fabric.client.FabricClient` over them with
``FabricClient.connect``.  Each worker:

  * opens its store (``store_path`` with a committed manifest resumes
    it; a bare path creates a durable store; neither -> in-memory) and
    optionally ingests a records array handed to it at spawn;
  * optionally installs a JSONL-sink :class:`~repro.obs.trace.Tracer`
    and, on shutdown, writes ``shard-<id>-health.json`` /
    ``shard-<id>-metrics.json`` — the per-shard artifacts the CI
    fabric-smoke job uploads;
  * serves until a ``shutdown`` envelope arrives (the fleet's
    ``close()`` sends one per worker, then joins with a terminate
    fallback so a wedged worker cannot hang the parent).
"""
from __future__ import annotations

import json
import multiprocessing as mp
import os
import threading

import numpy as np

__all__ = ["ShardFleet", "spawn_shards"]


def _shard_main(conn, shard_id: int, store_path: str | None,
                schema_text: str | None, num_keys: int | None,
                records: np.ndarray | None, config_kw: dict,
                artifact_dir: str | None) -> None:
    """Worker entrypoint (spawn target — top-level and import-light
    until inside, so child startup stays cheap)."""
    from repro import db as db_mod
    from repro.db.schema import Schema
    from repro.fabric.protocol import ServiceHost
    from repro.fabric.transport import serve_socket
    from repro.obs import trace as obs_trace
    from repro.serve.service import BitmapService, ServiceConfig
    from repro.store import format as fmt

    tracer = None
    sink_f = None
    if artifact_dir:
        os.makedirs(artifact_dir, exist_ok=True)
        sink_f = open(os.path.join(artifact_dir,
                                   f"shard-{shard_id}-trace.jsonl"),
                      "w", buffering=1)

        def sink(d, _f=sink_f):
            _f.write(json.dumps(d) + "\n")

        tracer = obs_trace.install(obs_trace.Tracer(sink=sink))

    schema = Schema.from_json(schema_text) if schema_text else None
    if store_path and os.path.exists(os.path.join(store_path, "CURRENT")):
        session = db_mod.BitmapDB.open(store_path, num_keys=num_keys)
    elif store_path:
        session = db_mod.BitmapDB(schema, num_keys=num_keys,
                                  path=store_path)
    else:
        session = db_mod.BitmapDB(schema, num_keys=num_keys)
    if records is not None and records.shape[0]:
        session.append_encoded(records)

    service = BitmapService(session, ServiceConfig(**config_kw))
    done = threading.Event()
    host = ServiceHost(service, shard_id=shard_id,
                       on_shutdown=done.set)
    server = serve_socket(host)
    conn.send(("ready", server.address))
    conn.close()
    try:
        done.wait()
    finally:
        if artifact_dir:
            try:
                # atomic + seamed (format.write): a fault plan can tear
                # or drop these exactly like any other durable artifact
                fmt.write_json_atomic(
                    os.path.join(artifact_dir,
                                 f"shard-{shard_id}-metrics.json"),
                    _jsonable(service.metrics().to_dict()))
                fmt.write_json_atomic(
                    os.path.join(artifact_dir,
                                 f"shard-{shard_id}-health.json"),
                    _jsonable(service.health()))
            except Exception:           # noqa: BLE001 — artifacts only
                pass
        server.close()
        host.close()
        if tracer is not None:
            obs_trace.uninstall(tracer)
        if sink_f is not None:
            sink_f.close()


def _jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, float) and obj != obj:
        return None
    return obj


class ShardFleet:
    """Handle to a set of spawned shard workers."""

    def __init__(self, procs, addresses):
        self.procs = procs
        self.addresses: list[tuple[str, int]] = addresses
        self._closed = False

    def __enter__(self) -> "ShardFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, timeout: float = 30.0) -> None:
        """Ask every worker to shut down (a ``shutdown`` envelope over a
        short-lived connection), then join; terminate stragglers."""
        if self._closed:
            return
        self._closed = True
        from repro.fabric.envelope import Envelope
        from repro.fabric.transport import SocketTransport
        for addr in self.addresses:
            try:
                t = SocketTransport(addr, connect_timeout=2.0)
                try:
                    t.request(Envelope("shutdown"), timeout=5.0)
                finally:
                    t.close()
            except OSError:
                pass                    # already gone
        for p in self.procs:
            p.join(timeout=timeout / max(len(self.procs), 1))
        for p in self.procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=5.0)


def spawn_shards(num_shards: int, *, schema=None, num_keys=None,
                 store_paths=None, shard_records=None,
                 service_config: dict | None = None,
                 artifact_dir: str | None = None,
                 start_timeout_s: float = 120.0) -> ShardFleet:
    """Launch ``num_shards`` worker processes and wait for their bound
    addresses.  ``shard_records`` (optional) is one encoded ``(N, W)``
    int32 array per shard, ingested before the worker reports ready —
    the parent typically produced it with ``ShardMap.partition`` and
    keeps the matching gid tables for its client."""
    ctx = mp.get_context("spawn")
    schema_text = schema.to_json() if schema is not None else None
    procs, conns = [], []
    for sid in range(num_shards):
        parent, child = ctx.Pipe()
        recs = None if shard_records is None else \
            np.asarray(shard_records[sid], np.int32)
        sp = None if store_paths is None else store_paths[sid]
        p = ctx.Process(
            target=_shard_main,
            args=(child, sid, sp, schema_text, num_keys, recs,
                  dict(service_config or {}), artifact_dir),
            name=f"repro-shard-{sid}", daemon=True)
        p.start()
        child.close()
        procs.append(p)
        conns.append(parent)
    addresses = []
    try:
        for sid, conn in enumerate(conns):
            if not conn.poll(start_timeout_s):
                raise TimeoutError(f"shard {sid} did not come up within "
                                   f"{start_timeout_s}s")
            tag, addr = conn.recv()
            if tag != "ready":
                raise RuntimeError(f"shard {sid} failed to start: "
                                   f"{addr}")
            addresses.append(tuple(addr))
            conn.close()
    except BaseException:
        for p in procs:
            p.terminate()
        raise
    return ShardFleet(procs, addresses)
