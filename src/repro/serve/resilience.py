"""Retry and circuit-breaker primitives for the self-healing serving path.

The serving stack distinguishes three failure shapes and answers each
with a different mechanism (see :mod:`repro.serve.service` for the
wiring):

  * **transient** (an EIO blip, a full disk about to be freed, an
    injected hiccup) — retried with exponential backoff and
    *deterministic* jitter (:class:`RetryPolicy`: the jitter stream is a
    seeded PRNG, so a chaos run replays byte-for-byte);
  * **backend-specific** (the bulk/pallas executor keeps failing while
    ``ref`` serves fine) — a :class:`CircuitBreaker` per preferred
    backend trips after ``failure_threshold`` confirmed failures and
    routes whole waves to the fallback backend until a cooldown probe
    succeeds (degraded mode: slower, never wrong);
  * **persistent data corruption** — not handled here at all: that is
    the store's quarantine/scrub/repair machinery
    (:meth:`repro.store.SegmentStore.scrub`).

Stdlib-only; usable from the maintenance executor and the service alike.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Callable, Iterator

__all__ = ["RetryPolicy", "CircuitBreaker", "is_transient"]


def is_transient(exc: BaseException) -> bool:
    """Failure-shape classifier the retry paths share: I/O errors (every
    injected fault of that family is a real ``OSError``) and explicitly
    transient faults retry; corruption and programming errors do not —
    corruption goes to quarantine/scrub, bugs go to the caller."""
    from repro.store.format import CorruptFileError
    if isinstance(exc, CorruptFileError):
        return False
    if isinstance(exc, OSError):
        return True
    # injected transient faults, without a hard dependency on the fabric
    return type(exc).__name__ == "InjectedFault"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic jitter.

    ``delays(seed)`` yields ``max_attempts - 1`` sleep durations (attempt
    k retries after ``base * growth**k``, jittered by up to ``jitter`` of
    itself, capped at ``max_delay_s``).  The jitter stream is a
    ``random.Random(seed)`` — two runs with the same seed back off
    identically, which is what makes chaos schedules reproducible."""
    max_attempts: int = 4          # 1 initial try + 3 retries
    base_delay_s: float = 0.005
    growth: float = 2.0
    max_delay_s: float = 0.25
    jitter: float = 0.5            # fraction of the delay, added

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")

    def delays(self, seed: int = 0) -> Iterator[float]:
        rng = random.Random(seed)
        d = self.base_delay_s
        for _ in range(self.max_attempts - 1):
            yield min(self.max_delay_s, d * (1 + self.jitter * rng.random()))
            d *= self.growth

    def call(self, fn: Callable, *, seed: int = 0,
             retryable: Callable[[BaseException], bool] = is_transient,
             on_retry: Callable[[int, BaseException], None] | None = None,
             sleep: Callable[[float], None] = time.sleep):
        """Run ``fn()`` under this policy.  ``on_retry(attempt, exc)``
        observes each retry (metrics hooks); the final failure (or a
        non-retryable one) propagates unchanged."""
        delays = self.delays(seed)
        attempt = 0
        while True:
            try:
                return fn()
            except BaseException as e:          # noqa: BLE001 — classified
                attempt += 1
                delay = next(delays, None)
                if delay is None or not retryable(e):
                    raise
                if on_retry is not None:
                    on_retry(attempt, e)
                sleep(delay)


class CircuitBreaker:
    """Minimal three-state breaker (closed -> open -> half-open).

    ``allow()`` answers "may the protected path be tried right now?":
    closed -> yes; open -> no until ``cooldown_s`` elapsed, then ONE
    caller wins the half-open probe slot; half-open -> no (a probe is in
    flight).  ``record_success``/``record_failure`` move the state:
    ``failure_threshold`` consecutive failures trip it, a probe success
    closes it, a probe failure re-opens (and restarts the cooldown).

    The clock is injectable for tests (``clock=fake``); all transitions
    are lock-protected — the service scheduler and one-shot submitters
    may consult the same breaker concurrently."""

    def __init__(self, *, failure_threshold: int = 3,
                 cooldown_s: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._state = "closed"
        self._consecutive = 0
        self._opened_at = 0.0
        self.trips = 0                 # lifetime open transitions
        self.failures = 0              # lifetime recorded failures

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        with self._lock:
            if self._state == "closed":
                return True
            if self._state == "open" \
                    and self._clock() - self._opened_at >= self.cooldown_s:
                self._state = "half-open"      # this caller is the probe
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self._consecutive = 0
            if self._state != "closed":
                self._state = "closed"

    def record_failure(self) -> None:
        with self._lock:
            self.failures += 1
            self._consecutive += 1
            if self._state == "half-open" \
                    or (self._state == "closed"
                        and self._consecutive >= self.failure_threshold):
                self._state = "open"
                self._opened_at = self._clock()
                self.trips += 1

    def snapshot(self) -> dict:
        """One consistent view for ``service.health()``."""
        with self._lock:
            return {"state": self._state,
                    "consecutive_failures": self._consecutive,
                    "failures": self.failures, "trips": self.trips,
                    "cooldown_s": self.cooldown_s,
                    "failure_threshold": self.failure_threshold}

    def __repr__(self) -> str:
        return f"<CircuitBreaker {self.state} trips={self.trips}>"
