"""Serving steps: batched prefill (returns last-position logits + a KV/state
cache padded to the decode horizon), single-token decode, and batched
structured retrieval over a bitmap index (the paper's query workload served
through the engine's bucketed batch executor)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.engine import batch as _engine_batch
from repro.models.config import ModelConfig
from repro.models.model import model_forward


def make_prefill_step(cfg: ModelConfig, max_len: int | None = None):
    def prefill_step(params, batch):
        logits, cache = model_forward(
            params, cfg, batch["tokens"],
            visual=batch.get("visual"),
            mrope_positions=batch.get("mrope_positions"),
            frames=batch.get("frames"),
            mode="prefill", max_len=max_len)
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, batch):
        logits, cache = model_forward(
            params, cfg, batch["tokens"], cache=batch["cache"], mode="decode")
        return logits, cache
    return decode_step


def make_bitmap_query_step(index, *, backend: str = "auto"):
    """Batched structured-retrieval step over a bitmap index: the returned
    ``query_step(predicates)`` serves many predicate trees per dispatch
    (plan-shape bucketing in ``repro.engine.batch``) and yields
    (rows (Q, Nw) uint32, counts (Q,) int32) in request order — the
    serving-path analogue of ``make_prefill_step`` for the paper's query
    workload.

    ``index`` is either an in-memory
    :class:`repro.engine.policy.BitmapIndex` or a segment-backed
    :class:`repro.store.StoredIndex` (a spilled/recovered index served
    segment-parallel — no materialized full buffer)."""
    if hasattr(index, "parts"):            # repro.store.StoredIndex
        def query_step(predicates):
            return _engine_batch.execute_many_segments(
                index.parts, predicates, backend=backend)
        return query_step

    packed, num_records = index.packed, index.num_records

    def query_step(predicates):
        return _engine_batch.execute_many(packed, predicates,
                                          num_records=num_records,
                                          backend=backend)

    return query_step


def greedy_generate(params, cfg: ModelConfig, tokens, steps: int,
                    max_len: int | None = None, **kw):
    """Simple batched greedy loop for the examples (prefill + N decodes)."""
    B, S = tokens.shape
    max_len = max_len or (S + steps)
    logits, cache = model_forward(params, cfg, tokens, mode="prefill",
                                  max_len=max_len, **kw)
    out = [jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)]
    for _ in range(steps - 1):
        logits, cache = model_forward(params, cfg, out[-1][:, None],
                                      cache=cache, mode="decode")
        out.append(jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1))
    return jnp.stack(out, axis=1)
