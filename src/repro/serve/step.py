"""Serving steps: batched prefill (returns last-position logits + a KV/state
cache padded to the decode horizon), single-token decode, and batched
structured retrieval over a bitmap index (the paper's query workload served
through the engine's bucketed batch executor)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import model_forward


def make_prefill_step(cfg: ModelConfig, max_len: int | None = None):
    def prefill_step(params, batch):
        logits, cache = model_forward(
            params, cfg, batch["tokens"],
            visual=batch.get("visual"),
            mrope_positions=batch.get("mrope_positions"),
            frames=batch.get("frames"),
            mode="prefill", max_len=max_len)
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, batch):
        logits, cache = model_forward(
            params, cfg, batch["tokens"], cache=batch["cache"], mode="decode")
        return logits, cache
    return decode_step


def make_bitmap_query_step(index, *, backend: str = "auto"):
    """Batched structured-retrieval step over a bitmap index: the returned
    ``query_step(queries)`` serves many queries per dispatch (plan-shape
    bucketing through the :mod:`repro.db` facade) and yields
    (rows (Q, Nw) uint32, counts (Q,) int32) in request order — the
    serving-path analogue of ``make_prefill_step`` for the paper's query
    workload.  Queries are engine predicate trees, pre-built plans, or
    (when the session carries a schema) ``repro.db`` expressions.

    ``index`` is a :class:`repro.db.BitmapDB` session (served as-is — its
    schema, stats and plan cache apply), an in-memory
    :class:`repro.engine.policy.BitmapIndex`, or a segment-backed
    :class:`repro.store.StoredIndex` (a spilled/recovered index served
    segment-parallel — stacked into one vmapped dispatch per bucket when
    the segment word counts are uniform)."""
    from repro import db as _db
    if isinstance(index, _db.BitmapDB):
        return index.serve_step()
    return _db.BitmapDB.from_index(index, backend=backend).serve_step()


def greedy_generate(params, cfg: ModelConfig, tokens, steps: int,
                    max_len: int | None = None, **kw):
    """Simple batched greedy loop for the examples (prefill + N decodes)."""
    B, S = tokens.shape
    max_len = max_len or (S + steps)
    logits, cache = model_forward(params, cfg, tokens, mode="prefill",
                                  max_len=max_len, **kw)
    out = [jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)]
    for _ in range(steps - 1):
        logits, cache = model_forward(params, cfg, out[-1][:, None],
                                      cache=cache, mode="decode")
        out.append(jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1))
    return jnp.stack(out, axis=1)
