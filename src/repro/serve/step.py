"""Serving steps: batched prefill (returns last-position logits + a KV/state
cache padded to the decode horizon), single-token decode, and batched
structured retrieval over a bitmap index (the paper's query workload served
through the engine's bucketed batch executor)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import model_forward


def make_prefill_step(cfg: ModelConfig, max_len: int | None = None):
    def prefill_step(params, batch):
        logits, cache = model_forward(
            params, cfg, batch["tokens"],
            visual=batch.get("visual"),
            mrope_positions=batch.get("mrope_positions"),
            frames=batch.get("frames"),
            mode="prefill", max_len=max_len)
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, batch):
        logits, cache = model_forward(
            params, cfg, batch["tokens"], cache=batch["cache"], mode="decode")
        return logits, cache
    return decode_step


def make_bitmap_query_step(index, *, backend: str = "auto"):
    """Batched structured-retrieval step over a bitmap index: the returned
    ``query_step(queries)`` serves many queries per dispatch (plan-shape
    bucketing through the :mod:`repro.db` facade) and yields
    (rows (Q, Nw) uint32, counts (Q,) int32) in request order — the
    serving-path analogue of ``make_prefill_step`` for the paper's query
    workload.  Queries are engine predicate trees, pre-built plans, or
    (when the session carries a schema) ``repro.db`` expressions.

    Since PR 5 this is a thin shim over a synchronous one-shot
    :class:`repro.serve.service.BitmapService` (``background=False``: no
    threads, no deferred maintenance — appends keep their synchronous
    spill semantics): each ``query_step(queries)`` call submits the batch
    and drains it in coalesced dispatches, bit-identical to the direct
    ``query_many`` path.  Callers that want cross-caller coalescing,
    admission control, standby, and background maintenance should hold
    the service itself — ``BitmapDB.serve()`` /
    :meth:`repro.serve.service.BitmapService.open`.

    ``index`` is a :class:`repro.db.BitmapDB` session (served as-is — its
    schema, stats and plan cache apply), an in-memory
    :class:`repro.engine.policy.BitmapIndex`, or a segment-backed
    :class:`repro.store.StoredIndex` (a spilled/recovered index served
    segment-parallel — stacked into one vmapped dispatch per bucket when
    the segment word counts are uniform)."""
    from repro.serve.service import BitmapService, ServiceConfig

    svc = BitmapService.open(index, backend=backend,
                             config=ServiceConfig(background=False,
                                                  maintenance=False,
                                                  pad_output=False,
                                                  max_batch=1 << 20,
                                                  max_queue=1 << 20))
    db = svc.db

    def query_step(queries):
        futs = [svc.submit(q) for q in queries]
        svc.drain()
        if not futs:
            return db.query_many([]).materialize()
        rows, counts = futs[0]._rows, futs[0]._counts
        if rows is not None \
                and all(f._err is None and f._rows is rows for f in futs) \
                and [f._qi for f in futs] == list(range(len(futs))):
            return rows, counts        # one coalesced batch: zero-copy
        # multiple coalesced batches — or a failed query, which .rows
        # re-raises here exactly as the pre-shim step did
        return (jnp.stack([f.rows for f in futs]),
                jnp.stack([jnp.asarray(f.result()[1]) for f in futs]))

    query_step.service = svc
    return query_step


def greedy_generate(params, cfg: ModelConfig, tokens, steps: int,
                    max_len: int | None = None, **kw):
    """Simple batched greedy loop for the examples (prefill + N decodes)."""
    B, S = tokens.shape
    max_len = max_len or (S + steps)
    logits, cache = model_forward(params, cfg, tokens, mode="prefill",
                                  max_len=max_len, **kw)
    out = [jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)]
    for _ in range(steps - 1):
        logits, cache = model_forward(params, cfg, out[-1][:, None],
                                      cache=cache, mode="decode")
        out.append(jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1))
    return jnp.stack(out, axis=1)
