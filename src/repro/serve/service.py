"""`BitmapService` — the async serving port over a `BitmapDB` session.

The paper's core is duty-cycled silicon: full-throughput bitwise passes
while work is queued, clock-gated near-zero-power standby the moment it
is not.  The serving surface this module replaces (`serve_step`'s bare
function) could not express that cycle — every caller hand-assembled its
own batches, and concurrent callers never coalesced into the wide
dispatches that make the engine's bucketed executors pay off.  The
service is the missing lifecycle port:

  * **submit/drain/close** — ``submit(query)`` returns a
    :class:`QueryFuture` immediately; a deadline-driven micro-batch
    scheduler coalesces everything submitted within ``max_delay_ms`` (or
    up to ``max_batch``) from ANY number of threads into ONE
    ``query_many`` batch — plan-shape bucketing then serves the whole
    coalesced batch in a handful of vmapped dispatches.  Results are
    bit-identical to sequential ``serve_step`` calls, resolved in
    submission order (a caller's futures never complete out of order).
  * **admission control** — a bounded queue (``max_queue``):
    ``admission="block"`` applies backpressure to submitters,
    ``admission="reject"`` raises :class:`ServiceOverloaded` (load-shed).
  * **standby** — idle past ``idle_after_ms``, the scheduler quiesces
    into a standby state; the energy meter switches from active to
    standby power (the calibrated silicon model via
    :class:`repro.core.elastic.ElasticScheduler` — CG+RBB by default),
    and the next submission wakes it.  ``metrics()`` reports the
    active/standby joule split, latency percentiles, throughput, energy
    per query, coalesced batch sizes, and the session's plan-cache
    health.
  * **background maintenance** — durable sessions detach segment spill,
    compaction, and gc from the append path onto a
    :class:`repro.serve.maintenance.MaintenanceExecutor`: ``append()``
    only logs to the WAL and splices in memory; the flush threshold
    enqueues a two-phase background spill (crash between file write and
    manifest swap loses nothing).  Serving reads a snapshot-consistent
    packed view throughout.

``background=False`` gives a one-shot synchronous service (no threads):
submissions queue, ``drain()``/``flush()`` executes everything on the
calling thread in coalesced batches — what
:func:`repro.serve.step.make_bitmap_query_step` wraps.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from typing import Sequence

import jax
import numpy as np

from repro.core.bic import BICConfig, PaperConfig
from repro.core.elastic import ElasticScheduler, EnergyReport, PowerState
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.energy import EnergyLedger
from repro.serve.resilience import CircuitBreaker, RetryPolicy, is_transient

__all__ = ["BitmapService", "ServiceConfig", "ServiceMetrics",
           "QueryFuture", "ServiceOverloaded", "ServiceClosed",
           "DeadlineExceeded"]


class ServiceOverloaded(RuntimeError):
    """Admission control rejected (or timed out) a submission.  Carries
    the admission decision's inputs as fields (and in the message), so a
    load-shedding caller can adapt instead of parse."""

    def __init__(self, reason: str, *, queue_depth: int | None = None,
                 limit: int | None = None, admission: str | None = None):
        detail = [reason]
        if queue_depth is not None:
            detail.append(f"queue_depth={queue_depth}")
        if limit is not None:
            detail.append(f"limit={limit}")
        if admission is not None:
            detail.append(f"admission={admission!r}")
        super().__init__(" ".join([detail[0]]
                                  + ([f"({', '.join(detail[1:])})"]
                                     if len(detail) > 1 else [])))
        self.queue_depth = queue_depth
        self.limit = limit
        self.admission = admission


class ServiceClosed(RuntimeError):
    """submit() after close()."""


class DeadlineExceeded(RuntimeError):
    """A query's per-request deadline budget expired before its wave
    dispatched; the future rejects instead of serving stale-late."""


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of one :class:`BitmapService` (see module docstring)."""
    max_batch: int = 256          # widest coalesced dispatch
    max_delay_ms: float = 2.0     # oldest request waits at most this long
    max_queue: int = 8192         # admission bound (queued, not in-flight)
    admission: str = "block"      # "block" (backpressure) | "reject"
    idle_after_ms: float = 100.0  # awake-idle this long -> standby
    background: bool = True       # False: one-shot synchronous mode
    maintenance: bool = True      # background spill/compact/gc (durable)
    #: serve batches with power-of-two padded result arrays (futures
    #: index their real slice): varying coalesced batch sizes then reuse
    #: compiled shapes instead of paying first-sight jit retraces
    pad_output: bool = True
    latency_window: int = 8192    # per-request latency samples kept
    # --- self-healing knobs (see ARCHITECTURE.md, "Fault fabric")
    #: every submission's default deadline budget (None = no deadline);
    #: ``submit(deadline_ms=)`` overrides per query
    default_deadline_ms: float | None = None
    wave_retries: int = 2         # transient wave failures retried
    retry_base_ms: float = 5.0    # first retry backoff (grows, jittered)
    breaker_threshold: int = 3    # confirmed backend failures to trip
    breaker_cooldown_s: float = 2.0
    #: backend degraded waves fall back to (the reference executor:
    #: slowest, simplest, last to break)
    fallback_backend: str = "ref"
    #: enqueue a background CRC scrub of the committed segments on every
    #: standby entry (durable sessions) — idle time buys integrity
    scrub_on_standby: bool = True
    bic_config: BICConfig = PaperConfig
    power_state: PowerState = PowerState()

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.admission not in ("block", "reject"):
            raise ValueError(f"admission must be 'block' or 'reject', "
                             f"got {self.admission!r}")
        if self.wave_retries < 0:
            raise ValueError("wave_retries must be >= 0")
        if self.default_deadline_ms is not None \
                and self.default_deadline_ms <= 0:
            raise ValueError("default_deadline_ms must be positive")


class QueryFuture:
    """Handle to one submitted query.  Resolves to its slice of the
    coalesced batch; ``.rows``/``.count``/``.ids`` block until then
    (mirroring :class:`repro.db.Result`)."""

    __slots__ = ("query", "_ev", "_rows", "_counts", "_qi", "_n", "_err",
                 "resolve_seq", "trace_id")

    def __init__(self, query):
        self.query = query
        self._ev = threading.Event()
        self._rows = None
        self._counts = None
        self._qi = 0
        self._n = 0
        self._err: BaseException | None = None
        #: global resolution sequence number (set when served) — lets a
        #: caller verify its futures completed in submission order
        self.resolve_seq: int = -1
        #: the query's trace id when a tracer was installed at submit
        #: (joins this future to its admission/queue/serve spans)
        self.trace_id: int | None = None

    def _resolve(self, rows, counts, qi: int, n: int) -> None:
        self._rows, self._counts, self._qi, self._n = rows, counts, qi, n
        self._ev.set()

    def _reject(self, err: BaseException) -> None:
        self._err = err
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._ev.wait(timeout)

    def _ready(self, timeout: float | None = None) -> None:
        if not self._ev.wait(timeout):
            raise TimeoutError(f"query not served within {timeout}s")
        if self._err is not None:
            raise self._err

    def result(self, timeout: float | None = None):
        """(packed row (Nw,) uint32, count) — the engine arrays, exactly
        what a sequential ``serve_step([q])`` call would return for this
        query.  Blocks until served; raises what the query raised."""
        self._ready(timeout)
        return self._rows[self._qi], self._counts[self._qi]

    def exception(self, timeout: float | None = None):
        self._ev.wait(timeout)
        return self._err

    @property
    def rows(self):
        return self.result()[0]

    @property
    def count(self) -> int:
        self._ready()
        return int(self._counts[self._qi])

    @property
    def ids(self) -> np.ndarray:
        """Matching record ordinals (sorted)."""
        from repro.db.result import unpack_ids
        return unpack_ids(np.asarray(self.rows), self._n)

    def __repr__(self) -> str:
        state = ("failed" if self._err is not None
                 else "done" if self.done() else "pending")
        return f"<QueryFuture {state} {self.query!r:.60}>"


@dataclasses.dataclass
class ServiceMetrics:
    """One consistent snapshot of a service's meters (see
    :meth:`BitmapService.metrics`)."""
    served: int
    batches: int
    rejected: int
    inflight: int
    state: str
    uptime_seconds: float
    queries_per_sec: float
    latency_p50_ms: float
    latency_p99_ms: float
    latency_mean_ms: float
    batch_mean: float
    batch_max: int
    busy_seconds: float
    awake_idle_seconds: float
    standby_seconds: float
    standby_entries: int
    wakes: int
    active_joules: float
    standby_joules: float
    energy_per_query_j: float
    plan_cache: dict
    maintenance: dict | None
    health: dict
    #: energy-ledger snapshot: per-phase joules, pJ-per-query,
    #: pJ-per-indexed-bit, operating points (see repro.obs.energy)
    energy: dict | None = None

    def to_dict(self) -> dict:
        """Plain-dict form (what the fabric protocol puts on the wire
        and what artifact writers serialize)."""
        return dataclasses.asdict(self)


class _Item:
    __slots__ = ("query", "future", "t", "deadline", "aspan", "qspan")

    def __init__(self, query, future, t, deadline=None):
        self.query, self.future, self.t = query, future, t
        self.deadline = deadline       # absolute perf_counter, or None
        # traced submits carry their admission + live queue spans here;
        # both are recorded in ONE batch at wave pickup, so submitter
        # threads never contend on the tracer ring lock
        self.aspan = None
        self.qspan = None


class BitmapService:
    """The lifecycle port (use :meth:`open`, or
    :meth:`repro.db.BitmapDB.serve`); also a context manager."""

    def __init__(self, db: "BitmapDB", config: ServiceConfig):
        self._db = db
        self.config = config
        self._cv = threading.Condition()
        self._pending: collections.deque[_Item] = collections.deque()
        self._inflight = 0             # accepted, not yet resolved
        self._openflag = True
        self._state = "active"
        self._close_lock = threading.Lock()
        self._flush_lock = threading.Lock()
        self._runtime = None           # attach_runtime (shared duty cycle)
        # --- energy meter: calibrated silicon powers, one virtual core.
        # The ledger OWNS the service's EnergyReport: every joule enters
        # through its charge(), so per-query attribution reconciles with
        # the scheduler totals by construction.
        self._sched = ElasticScheduler(1, config.bic_config,
                                       config.power_state)
        self._ledger = EnergyLedger(self._sched)
        self._energy = self._ledger.report
        self._elock = threading.Lock()
        self._mark = time.perf_counter()
        self._t_open = self._mark
        # --- meters: one typed registry; metrics()/health() are views.
        # Metric locks are leaves (never held while taking another lock),
        # so updates are safe under the cv AND reads never deadlock.
        self.registry = obs_metrics.Registry()
        reg = self.registry
        self._resolve_seq = 0
        self._wave_ids = itertools.count(1)
        # bounded lifetime-uniform reservoir: p50/p99 stay stable (and
        # memory flat) over multi-hour runs, unlike a sliding window
        self._lat = reg.reservoir("latency_ms",
                                  capacity=config.latency_window, seed=21)
        self._lat_hist = reg.histogram("latency_ms_hist",
                                       obs_metrics.LATENCY_BUCKETS_MS)
        self._batch_sizes = collections.deque(maxlen=4096)
        self._served_c = reg.counter("served_total")
        self._batches_c = reg.counter("batches_total")
        self._rejected_c = reg.counter("rejected_total")
        self._standby_entries_c = reg.counter("standby_entries_total")
        self._wakes_c = reg.counter("wakes_total")
        self._inflight_g = reg.gauge("inflight")
        self._queue_g = reg.gauge("queue_depth")
        # --- self-healing state (see _execute)
        self._retry = RetryPolicy(max_attempts=config.wave_retries + 1,
                                  base_delay_s=config.retry_base_ms / 1e3)
        self._breaker = CircuitBreaker(
            failure_threshold=config.breaker_threshold,
            cooldown_s=config.breaker_cooldown_s)
        self._wave_retries_c = reg.counter(
            "wave_retries_total", "transient wave failures retried")
        self._degraded_waves_c = reg.counter(
            "degraded_waves_total", "waves served by the fallback")
        self._fallback_queries_c = reg.counter(
            "fallback_queries_total", "queries those waves carried")
        self._deadline_rejected_c = reg.counter(
            "deadline_rejected_total", "futures rejected past-deadline")
        self._isolated_failures_c = reg.counter(
            "isolated_failures_total", "per-query failures isolated")
        # graft the lower layers' registries: ONE exportable metric tree
        sub = getattr(db, "registry", None)
        if sub is not None:
            reg.attach("db", sub)
        store = getattr(db, "store", None)
        if store is not None and getattr(store, "registry", None) is not None:
            reg.attach("store", store.registry)
        reg.attach("engine", obs_metrics.GLOBAL)
        # --- background maintenance (durable sessions only)
        self._maint = None
        self._maint_ex = None
        si = getattr(db, "indexer", None)
        if config.maintenance and si is not None and si.store is not None:
            from repro.serve.maintenance import (IndexMaintenance,
                                                 MaintenanceExecutor)
            self._maint_ex = MaintenanceExecutor()
            self._maint = IndexMaintenance(si, self._maint_ex)
        # --- scheduler thread
        self._thread = None
        if config.background:
            self._thread = threading.Thread(
                target=self._run, name="repro-bitmap-service", daemon=True)
            self._thread.start()

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def open(cls, index, *, config: ServiceConfig | None = None,
             backend: str = "auto", **kw) -> "BitmapService":
        """Open a service over a :class:`repro.db.BitmapDB` session (or
        anything :func:`repro.serve.step.make_bitmap_query_step` accepts:
        a raw ``BitmapIndex`` / ``StoredIndex`` is wrapped read-only).
        Extra keywords construct the :class:`ServiceConfig`."""
        if config is not None and kw:
            raise ValueError("pass config= or individual keywords, "
                             "not both")
        from repro import db as _db
        if not isinstance(index, _db.BitmapDB):
            index = _db.BitmapDB.from_index(index, backend=backend)
        return cls(index, config or ServiceConfig(**kw))

    def __enter__(self) -> "BitmapService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def db(self) -> "BitmapDB":
        return self._db

    @property
    def state(self) -> str:
        """"active" | "standby" | "closed"."""
        with self._cv:
            if not self._openflag and self._inflight == 0:
                return "closed"
            return self._state

    # --------------------------------------------------------------- submit
    def submit(self, query, *, timeout: float | None = None,
               deadline_ms: float | None = None) -> QueryFuture:
        """Enqueue one query (expression / predicate / pre-built plan —
        anything the session's ``query_many`` accepts); returns its
        :class:`QueryFuture` immediately.  Admission control applies:
        with a full queue, ``block`` waits (``timeout`` bounds it),
        ``reject`` raises :class:`ServiceOverloaded`.

        ``deadline_ms`` (default ``config.default_deadline_ms``) is the
        query's end-to-end latency budget: if its wave has not
        dispatched by then — retries, degraded-mode fallbacks, and
        queue time all count against it — the future rejects with
        :class:`DeadlineExceeded` instead of serving arbitrarily late."""
        cfg = self.config
        if deadline_ms is None:
            deadline_ms = cfg.default_deadline_ms
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        tr = obs_trace.TRACER
        t_sub = time.perf_counter() if tr is not None else 0.0
        while True:
            flush_first = False
            with self._cv:
                if not self._openflag:
                    raise ServiceClosed(
                        "submit() on a closed BitmapService")
                if len(self._pending) >= cfg.max_queue:
                    if not cfg.background:
                        # one-shot mode has no consumer thread: the
                        # submitter IS the executor, so a full queue
                        # flushes here instead of deadlocking
                        flush_first = True
                    elif cfg.admission == "reject":
                        self._rejected_c.inc()
                        raise ServiceOverloaded(
                            "queue full",
                            queue_depth=len(self._pending),
                            limit=cfg.max_queue, admission=cfg.admission)
                    else:
                        left = (None if deadline is None
                                else deadline - time.perf_counter())
                        if (left is not None and left <= 0) \
                                or not self._cv.wait(timeout=left):
                            self._rejected_c.inc()
                            raise ServiceOverloaded(
                                f"queue full after {timeout}s "
                                "backpressure",
                                queue_depth=len(self._pending),
                                limit=cfg.max_queue,
                                admission=cfg.admission)
                        continue              # re-check queue + openflag
                else:
                    now = time.perf_counter()
                    fut = QueryFuture(query)
                    depth = len(self._pending)
                    it = _Item(query, fut, now,
                               None if deadline_ms is None
                               else now + deadline_ms / 1e3)
                    if tr is not None:
                        # per-query trace: admission (submit -> accept)
                        # then a live queue span ended at wave pickup
                        tid = tr.new_trace()
                        fut.trace_id = tid
                        it.aspan = tr.make("admission", trace_id=tid,
                                           t0=t_sub, t1=now,
                                           queue_depth=depth)
                        it.qspan = tr.make("queue", trace_id=tid,
                                           parent_id=it.aspan.span_id,
                                           t0=now)
                    self._pending.append(it)
                    self._inflight += 1
                    self._cv.notify_all()
                    break
            if flush_first:
                self._flush_inline()
        if not cfg.background and len(self._pending) >= cfg.max_batch:
            self._flush_inline()
        return fut

    def submit_many(self, queries: Sequence, *,
                    timeout: float | None = None) -> list[QueryFuture]:
        return [self.submit(q, timeout=timeout) for q in queries]

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every accepted submission has resolved (exactly
        once — nothing dropped, nothing duplicated); returns False on
        timeout.  In one-shot mode this is also what executes."""
        if not self.config.background:
            self._flush_inline()
        with self._cv:
            return self._cv.wait_for(lambda: self._inflight == 0,
                                     timeout=timeout)

    def close(self, timeout: float | None = None) -> None:
        """Drain, stop the scheduler, flush + detach background
        maintenance.  Idempotent AND safe to call concurrently — with
        another ``close()`` (the loser waits, then no-ops) and with
        in-flight ``submit()`` (a racing submit either wins admission
        and resolves before the scheduler exits, or raises
        :class:`ServiceClosed`)."""
        with self._close_lock:
            with self._cv:
                already = not self._openflag
                self._openflag = False
                self._cv.notify_all()
            if not self.config.background:
                self._flush_inline()
            if self._thread is not None:
                self._thread.join(timeout=timeout)
                self._thread = None
            if not already and self._maint is not None:
                # detach FIRST (restores synchronous spills) so an
                # append racing this close can never hit a closed
                # executor
                self._maint.detach()
                self._maint_ex.close(timeout=timeout)
            with self._elock:
                self._charge_locked(time.perf_counter())

    def warmup(self, queries: Sequence, *, max_batch: int | None = None
               ) -> int:
        """Pre-compile every bucketed executor the scheduler can hit for
        this query population BEFORE traffic arrives: for each distinct
        plan shape among ``queries``, run one dispatch at every
        power-of-two bucket size up to ``max_batch`` — on EVERY backend
        the cost model might route a wave to (``costmodel.candidates()``
        for an ``auto`` session, the pinned backend otherwise).  The
        bucket-executor caches are backend-keyed, so a cost-model backend
        switch mid-traffic then lands on an already-compiled executor
        instead of stalling a wave on compilation.  Coalesced batch
        compositions vary run to run (thread timing decides what lands
        in a window), so without this a first-sight (bucket size,
        backend) pair pays a jit compile mid-serving — a latency spike
        standby can't hide.  Returns the number of warm dispatches."""
        from repro.engine import batch as engine_batch
        from repro.engine import costmodel, planner

        db = self._db
        reps: dict = {}
        for q in queries:
            pl = db._plan_for(q)
            if isinstance(pl, planner.CompositePlan):
                continue                # served out-of-band, no executor
            _, shape, _, _ = engine_batch._lowered(pl)
            if shape is not None and shape not in reps:
                reps[shape] = pl
        cap = max(1, max_batch if max_batch is not None
                  else self.config.max_batch)
        # pinned sessions also warm the breaker's fallback backend: a
        # degraded wave must not pay a first-sight compile on top of the
        # failure that degraded it (auto candidates already include ref)
        names = (costmodel.candidates() if db.backend == "auto"
                 else tuple(dict.fromkeys(
                     (db.backend, self.config.fallback_backend))))
        view = db._view()
        segmented = hasattr(view, "parts")
        dispatches = 0
        pad = self.config.pad_output
        for pl in reps.values():
            s = 1
            while s <= cap:
                for name in names:
                    if segmented:
                        engine_batch.execute_many_segments(
                            view.parts, [pl] * s, backend=name)
                    else:
                        engine_batch.execute_many(
                            view.packed, [pl] * s,
                            num_records=view.num_records, backend=name,
                            pad_output=pad)
                    dispatches += 1
                if s == cap:
                    break
                s = min(s * 2, cap)
        return dispatches

    # -------------------------------------------------- shared duty cycle
    def attach_runtime(self, runtime) -> "BitmapService":
        """Share ONE active⇄standby duty cycle and ONE
        :class:`~repro.obs.energy.EnergyLedger` between indexing and
        serving: the :class:`~repro.engine.runtime.MulticoreRuntime`'s
        tick reports charge into THIS service's ledger (so the energy
        snapshot/pJ-per-indexed-bit roll-ups cover both), and
        :meth:`run_tick` drives the service's power state alongside the
        indexing tick — wake at tick start, drop back to standby when a
        tick ends with nothing queued."""
        with self._cv:
            self._runtime = runtime
        runtime.bind_ledger(self._ledger)
        return self

    def run_tick(self, records, keys, tick_seconds: float, **kw):
        """One indexing tick through the attached runtime, synchronized
        with the serving duty cycle (see :meth:`attach_runtime`).
        Accepts exactly :meth:`repro.engine.runtime.MulticoreRuntime.
        run_tick`'s arguments and returns its ``TickResult``."""
        rt = self._runtime
        if rt is None:
            raise RuntimeError("no runtime attached — call "
                               "attach_runtime(MulticoreRuntime) first")
        wl = 0 if records is None else records.shape[0]
        if wl:
            with self._cv:
                if self._state == "standby":
                    with self._elock:
                        self._charge_locked(time.perf_counter())
                    self._state = "active"
                    self._wakes_c.inc()
        out = rt.run_tick(records, keys, tick_seconds, **kw)
        if wl:
            with self._cv:
                idle = not self._pending and self._inflight == 0
            if idle:
                self.standby()
        return out

    def standby(self) -> None:
        """Explicitly drop into standby now (the idle timer does this on
        its own after ``idle_after_ms``); the next submission wakes."""
        with self._cv:
            if self._state == "active":
                with self._elock:
                    self._charge_locked(time.perf_counter())
                self._state = "standby"
                self._standby_entries_c.inc()
        self._schedule_standby_scrub()

    def _schedule_standby_scrub(self) -> None:
        """Standby entry enqueues one background CRC scrub (deduplicated
        by the executor): the duty cycle's idle phase doubles as the
        integrity-checking window."""
        if not self.config.scrub_on_standby or self._maint is None:
            return
        try:
            self._maint.schedule_scrub()
        except RuntimeError:
            pass                       # executor already closed (shutdown)

    # ------------------------------------------------------------ scheduler
    def _run(self) -> None:
        try:
            self._run_loop()
        except BaseException as e:      # noqa: BLE001 — never hang callers
            with self._cv:
                self._openflag = False
                while self._pending:
                    it = self._pending.popleft()
                    it.future._reject(e)
                    self._inflight -= 1
                self._cv.notify_all()
            raise

    def _run_loop(self) -> None:
        cfg = self.config
        idle_after = cfg.idle_after_ms / 1e3
        max_delay = cfg.max_delay_ms / 1e3
        cv = self._cv
        while True:
            entered_standby = False
            with cv:
                # wait for work; a long-enough lull clock-gates us
                idle_t0 = time.perf_counter()
                while self._openflag and not self._pending:
                    if self._state == "active":
                        if not cv.wait(timeout=idle_after) \
                                and not self._pending \
                                and time.perf_counter() - idle_t0 \
                                >= idle_after:
                            with self._elock:
                                self._charge_locked(time.perf_counter())
                            self._state = "standby"
                            self._standby_entries_c.inc()
                            entered_standby = True
                            break
                    else:
                        cv.wait()
            if entered_standby:
                # outside the cv: the scrub enqueue takes the executor's
                # lock, and submissions must not wait on it
                self._schedule_standby_scrub()
            with cv:
                while self._openflag and not self._pending:
                    cv.wait()                   # standby: wait for a wake
                if not self._pending:
                    break                       # closed and drained
                if self._state == "standby":
                    with self._elock:
                        self._charge_locked(time.perf_counter())
                    self._state = "active"
                    self._wakes_c.inc()
                # batch window: the OLDEST request's deadline drives it
                deadline = self._pending[0].t + max_delay
                while (len(self._pending) < cfg.max_batch
                       and self._openflag):
                    left = deadline - time.perf_counter()
                    if left <= 0:
                        break
                    cv.wait(timeout=left)
                take = min(len(self._pending), cfg.max_batch)
                batch = [self._pending.popleft() for _ in range(take)]
                cv.notify_all()                 # queue space freed
            self._execute(batch)

    def _flush_inline(self) -> None:
        """One-shot mode: run everything queued, on the calling thread,
        in coalesced batches.  Serialized: concurrent one-shot
        submitters (or a racing ``close()``) must not interleave
        ``_execute`` — the resolve-sequence counter and the energy marks
        assume one executor at a time."""
        with self._flush_lock:
            while True:
                with self._cv:
                    if not self._pending:
                        return
                    take = min(len(self._pending), self.config.max_batch)
                    batch = [self._pending.popleft()
                             for _ in range(take)]
                    self._cv.notify_all()
                self._execute(batch)

    def _wave(self, queries: list, backend: str | None) -> tuple:
        """One coalesced dispatch: (rows, counts, n).  ``backend=None``
        serves on the session's preferred backend; a name routes the
        whole wave there (the breaker's degraded path)."""
        rb = self._db.query_many(queries, pad_output=self.config.pad_output,
                                 backend=backend)
        # read the record count AFTER query_many snapshots its view:
        # rows past the view are masked zero, so an at-most-newer n
        # can only be a harmless over-bound for .ids — the stale
        # ordering would silently drop freshly appended matches
        n = self._db.num_records
        tr = obs_trace.TRACER
        if tr is None:
            rows, counts = rb.materialize()
            jax.block_until_ready(rows)
        else:
            with tr.span("device.execute", queries=len(queries),
                         backend=backend or self._db.backend):
                rows, counts = rb.materialize()
                jax.block_until_ready(rows)
        return rows, counts, n

    def _serve_wave(self, queries: list) -> tuple[tuple | None, str]:
        """The self-healing dispatch ladder for one wave of queries.

        1. **retry** — transient failures (I/O blips, injected faults)
           on the preferred backend back off and retry, with
           deterministic jitter seeded by the wave number.
        2. **breaker + fallback** — when retries exhaust AND the same
           wave succeeds on ``fallback_backend``, the failure is
           confirmed backend-specific: the breaker records it (tripping
           after ``breaker_threshold``) and the wave is served degraded
           — slower, never wrong.  An open breaker skips the preferred
           backend entirely until a cooldown probe closes it.
        3. **give up the wave** — both paths failed; the caller
           falls through to per-query isolation (a poisoned QUERY, not
           a broken backend, so the breaker records nothing).

        Returns ``(result | None, mode)`` with mode one of
        ``"preferred"``/``"fallback"``/``"failed"``."""
        cfg = self.config
        fallback = cfg.fallback_backend
        have_fallback = self._db.backend != fallback

        def preferred():
            return self._wave(queries, None)

        def on_retry(attempt, exc):
            self._wave_retries_c.inc()

        if self._breaker.allow():
            try:
                out = self._retry.call(preferred,
                                       seed=self._batches_c.value,
                                       retryable=is_transient,
                                       on_retry=on_retry)
            except BaseException:               # noqa: BLE001 — ladder
                if not have_fallback:
                    # no second opinion available: cannot distinguish a
                    # broken backend from a poisoned query, so the
                    # breaker learns nothing
                    return None, "failed"
                try:
                    out = self._wave(queries, fallback)
                except BaseException:           # noqa: BLE001 — ladder
                    # both backends failed -> the queries are the
                    # problem; the breaker learns nothing from them
                    return None, "failed"
                # fallback succeeded where the preferred backend kept
                # failing: THAT is a confirmed backend failure
                self._breaker.record_failure()
                return out, "fallback"
            self._breaker.record_success()
            return out, "preferred"
        if not have_fallback:
            return None, "failed"
        try:
            return self._wave(queries, fallback), "fallback"
        except BaseException:                   # noqa: BLE001 — ladder
            return None, "failed"

    def _execute(self, batch: list[_Item]) -> None:
        tr = obs_trace.TRACER
        if tr is None:
            self._execute_impl(batch, None, 0)
            return
        # the coalesce span roots its OWN per-wave trace; each query's
        # queue span ends here carrying wave=wid, which joins the
        # per-query traces to the wave's coalesce/dispatch/reassembly
        # subtree (and its serve spans carry it back)
        wid = next(self._wave_ids)
        t_pick = tr.clock()
        ended = []
        for it in batch:
            sp = it.qspan
            if sp is not None:
                sp.t1 = t_pick
                sp.attrs["wave"] = wid
                ended.append(it.aspan)
                ended.append(sp)
        tr.record_batch(ended)
        with tr.span("coalesce", wave=wid, size=len(batch)):
            self._execute_impl(batch, tr, wid)

    def _execute_impl(self, batch: list[_Item], tr, wid: int) -> None:
        with self._elock:                       # waiting span was "awake"
            self._charge_locked(time.perf_counter())
        lats: list[float] = []
        # deadline budgets: queries whose budget expired in the queue are
        # excluded from the dispatch (their rejection is sequenced with
        # the wave's resolutions below, preserving per-caller order)
        now = time.perf_counter()
        live = [it for it in batch
                if it.deadline is None or now <= it.deadline]
        expired = len(batch) - len(live)
        out, mode = (self._serve_wave([it.query for it in live])
                     if live else ((None, None, 0), "preferred"))
        if mode == "failed":
            # wave-level failure survived retry AND fallback (e.g. one
            # bad key id poisons planning): isolate per query so one
            # caller's typo cannot fail another caller's future
            for it in batch:
                self._resolve_seq += 1
                it.future.resolve_seq = self._resolve_seq
                if it.deadline is not None and it.deadline < now:
                    it.future._reject(DeadlineExceeded(
                        f"deadline budget exhausted before dispatch "
                        f"({(now - it.t) * 1e3:.1f}ms in queue)"))
                    continue
                try:
                    r, c = self._db.query_many([it.query]).materialize()
                    jax.block_until_ready(r)
                    it.future._resolve(r, c, 0, self._db.num_records)
                except BaseException as e:      # noqa: BLE001 — to future
                    self._isolated_failures_c.inc()
                    it.future._reject(e)
            done = time.perf_counter()
        else:
            rows, counts, n = out
            done = time.perf_counter()
            if tr is None:
                qi = 0
                for it in batch:
                    self._resolve_seq += 1
                    it.future.resolve_seq = self._resolve_seq
                    if it.deadline is not None and it.deadline < now:
                        it.future._reject(DeadlineExceeded(
                            f"deadline budget exhausted before dispatch "
                            f"({(now - it.t) * 1e3:.1f}ms in queue)"))
                        continue
                    lats.append(done - it.t)
                    it.future._resolve(rows, counts, qi, n)
                    qi += 1
            else:
                with tr.span("reassembly", wave=wid, size=len(batch),
                             expired=expired):
                    qi = 0
                    for it in batch:
                        self._resolve_seq += 1
                        it.future.resolve_seq = self._resolve_seq
                        if it.deadline is not None and it.deadline < now:
                            it.future._reject(DeadlineExceeded(
                                f"deadline budget exhausted before "
                                f"dispatch ({(now - it.t) * 1e3:.1f}ms "
                                f"in queue)"))
                            continue
                        lats.append(done - it.t)
                        it.future._resolve(rows, counts, qi, n)
                        qi += 1
        with self._elock:                       # execution span was "busy"
            self._charge_locked(time.perf_counter(), busy=True)
        # attribute THIS wave's accumulated joules across its queries
        # (always, traced or not, so the unattributed pool drains per
        # wave and reconcile() holds at any quiescent point)
        served = ([it for it in batch if it.future._err is None]
                  if mode == "failed" else live)
        pjs = (self._ledger.attribute(
            [it.future.trace_id or 0 for it in served])
            if served else [])
        if tr is not None:
            # per-query serve span in the QUERY's trace: parented under
            # its queue span, carrying wave/mode/pJ attribution
            serves = []
            for it, pj in zip(served, pjs):
                if it.future.trace_id is None or it.qspan is None:
                    continue        # tracer installed mid-flight
                serves.append(tr.make(
                    "serve", trace_id=it.future.trace_id,
                    parent_id=it.qspan.span_id, t0=now, t1=done,
                    wave=wid, mode=mode, pj=pj))
            tr.record_batch(serves)
        for v in lats:
            self._lat.observe(v * 1e3)
            self._lat_hist.observe(v * 1e3)
        self._served_c.add(len(batch))
        self._batches_c.inc()
        self._deadline_rejected_c.add(expired)
        if mode == "fallback":
            self._degraded_waves_c.inc()
            self._fallback_queries_c.add(len(live))
        with self._cv:          # inflight gates drain(); cv-guarded
            self._batch_sizes.append(len(batch))
            self._inflight -= len(batch)
            self._cv.notify_all()               # drain()ers

    # --------------------------------------------------------------- energy
    def _charge_locked(self, now: float, *, busy: bool = False) -> None:
        """Charge the span since the last mark at the CURRENT mode's
        power: executing -> active power over busy time; awake-idle ->
        active power too (the clock is not gated — exactly why standby
        exists); standby -> the calibrated CG+RBB standby power."""
        dt = now - self._mark
        self._mark = now
        if dt <= 0:
            return
        phase = ("busy" if busy
                 else "awake_idle" if self._state == "active"
                 else "standby")
        self._ledger.charge(phase, dt)

    @property
    def energy(self) -> EnergyReport:
        """The live energy report (charged through the last state
        change/dispatch; ``metrics()`` charges up to now first)."""
        return self._energy

    # -------------------------------------------------------------- metrics
    def health(self) -> dict:
        """The self-healing surface in one dict: circuit-breaker state,
        store quarantines/repairs, retry and degraded-mode counters, and
        per-kind maintenance failure accounting.  ``degraded`` is True
        whenever the service is currently serving around a failure
        (breaker not closed, or a segment quarantined) — correct but
        slower, repair in progress."""
        breaker = self._breaker.snapshot()
        store = getattr(self._db, "store", None)
        store_health = store.health() if store is not None else None
        maint = (self._maint_ex.stats() if self._maint_ex is not None
                 else None)
        counters = {
            "wave_retries": self._wave_retries_c.value,
            "degraded_waves": self._degraded_waves_c.value,
            "fallback_queries": self._fallback_queries_c.value,
            "deadline_rejected": self._deadline_rejected_c.value,
            "isolated_failures": self._isolated_failures_c.value,
        }
        degraded = breaker["state"] != "closed" or bool(
            store_health and store_health["quarantined"])
        return {"degraded": degraded,
                "breaker": breaker,
                "fallback_backend": self.config.fallback_backend,
                "store": store_health,
                "maintenance_failures": (
                    {"failures": maint["failures"],
                     "retries": maint["retries"],
                     "last_failure": maint["last_failure"]}
                    if maint is not None else None),
                **counters}

    @property
    def ledger(self):
        """The service's :class:`repro.obs.energy.EnergyLedger` (owns
        :attr:`energy`; exposes per-query pJ and ``reconcile()``)."""
        return self._ledger

    def metrics(self) -> ServiceMetrics:
        with self._elock:
            self._charge_locked(time.perf_counter())
        with self._cv:          # consistent snapshot vs a live scheduler
            sizes = np.asarray(self._batch_sizes, np.int64)
            inflight = self._inflight
            queued = len(self._pending)
        self._inflight_g.set(inflight)
        self._queue_g.set(queued)
        served = self._served_c.value
        now = time.perf_counter()
        total_j = self._energy.total_joules
        maint = self._maint_ex.stats() if self._maint_ex is not None \
            else None
        phase_s = self._ledger.phase_seconds
        db = self._db
        nrec = getattr(db, "num_records", 0)
        nkeys = getattr(db, "num_keys", 0)
        return ServiceMetrics(
            served=served, batches=self._batches_c.value,
            rejected=self._rejected_c.value,
            inflight=inflight, state=self.state,
            uptime_seconds=now - self._t_open,
            queries_per_sec=served / max(now - self._t_open, 1e-9),
            latency_p50_ms=self._lat.percentile(50),
            latency_p99_ms=self._lat.percentile(99),
            latency_mean_ms=self._lat.mean,
            batch_mean=float(sizes.mean()) if sizes.size else 0.0,
            batch_max=int(sizes.max()) if sizes.size else 0,
            busy_seconds=phase_s["busy"],
            awake_idle_seconds=phase_s["awake_idle"],
            standby_seconds=phase_s["standby"],
            standby_entries=self._standby_entries_c.value,
            wakes=self._wakes_c.value,
            active_joules=self._energy.active_joules,
            standby_joules=self._energy.standby_joules,
            energy_per_query_j=total_j / served if served else 0.0,
            plan_cache=self._db.cache_stats()
            if hasattr(self._db, "cache_stats") else {},
            maintenance=maint,
            health=self.health(),
            energy=self._ledger.snapshot(num_records=nrec,
                                         num_keys=nkeys))

    def __repr__(self) -> str:
        return (f"<BitmapService {self.state} "
                f"served={self._served_c.value} "
                f"pending={len(self._pending)} over {self._db!r}>")
