from repro.serve.step import make_prefill_step, make_decode_step  # noqa: F401
from repro.serve.step import make_bitmap_query_step  # noqa: F401
from repro.serve.service import (BitmapService, QueryFuture,  # noqa: F401
                                 ServiceClosed, ServiceConfig,
                                 ServiceMetrics, ServiceOverloaded)
from repro.serve.maintenance import (IndexMaintenance,  # noqa: F401
                                     MaintenanceExecutor)
