from repro.serve.step import make_prefill_step, make_decode_step  # noqa: F401
from repro.serve.step import make_bitmap_query_step  # noqa: F401
from repro.serve.service import (BitmapService, DeadlineExceeded,  # noqa: F401
                                 QueryFuture, ServiceClosed, ServiceConfig,
                                 ServiceMetrics, ServiceOverloaded)
from repro.serve.maintenance import (IndexMaintenance,  # noqa: F401
                                     MaintenanceExecutor)
from repro.serve.resilience import (CircuitBreaker,  # noqa: F401
                                    RetryPolicy, is_transient)
