"""Background store maintenance: spill, compaction, and gc off the append
path.

The paper's duty cycle only pays off if the ingest path stays on its fast
track during peak load: a synchronous segment spill (device readback +
checksummed file write + manifest swap) or a compaction cascade in the
middle of ``append()`` is exactly the stall the silicon avoids by
double-buffering its transpose flush.  This module is the software
analogue:

  * :class:`MaintenanceExecutor` — one daemon worker thread draining a
    deduplicated task queue.  ``submit(kind, fn)`` enqueues unless a task
    of that ``kind`` is already pending, so an append storm that crosses
    the flush threshold a thousand times schedules ONE spill.
  * :class:`IndexMaintenance` — wires a durable
    :class:`repro.engine.runtime.StreamingIndexer` onto an executor: the
    indexer's threshold spill becomes an enqueue (appends return
    immediately), the spill itself runs the two-phase
    ``prepare_spill`` / ``commit_spill`` protocol on the worker (crash
    between the phases loses nothing — the WAL still covers every
    block), and a committed spill chains a compaction pass, which chains
    a gc sweep.  Each task reports stats (records flushed, segments
    merged, bytes reclaimed) into the executor's log.

Serving stays consistent throughout: queries snapshot the in-memory
packed view (a functional jax array pinned with its record count by the
indexer mutex), so a spill or merge mid-flight never changes a result
bit.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable

__all__ = ["MaintenanceExecutor", "IndexMaintenance"]


class MaintenanceExecutor:
    """One background worker, a deduplicated task queue, and a bounded
    log of what ran.  Tasks are ``fn() -> dict`` (the dict is the task's
    stats line); exceptions are captured into :attr:`errors`, never
    propagated into the worker loop."""

    def __init__(self, *, name: str = "repro-maintenance",
                 log_limit: int = 256):
        self._cv = threading.Condition()
        self._queue: collections.deque = collections.deque()
        self._pending: set[str] = set()
        self._running: str | None = None
        self._open = True
        self.counts: collections.Counter = collections.Counter()
        self.log: collections.deque = collections.deque(maxlen=log_limit)
        self.errors: list[tuple[str, BaseException]] = []
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    def submit(self, kind: str, fn: Callable[[], dict | None]) -> bool:
        """Enqueue ``fn`` under ``kind`` unless one is already pending;
        returns whether it was enqueued.  Never blocks (the whole point:
        this is what the append path calls)."""
        with self._cv:
            if not self._open:
                raise RuntimeError("maintenance executor is closed")
            if kind in self._pending:
                return False
            self._pending.add(kind)
            self._queue.append((kind, fn))
            self._cv.notify_all()
            return True

    def flush(self, timeout: float | None = None) -> bool:
        """Block until the queue is empty and no task is running (tasks
        enqueued by running tasks included); returns False on timeout."""
        with self._cv:
            return self._cv.wait_for(
                lambda: not self._queue and self._running is None,
                timeout=timeout)

    def close(self, *, timeout: float | None = None) -> None:
        """Drain outstanding tasks, then stop the worker.  Idempotent."""
        with self._cv:
            if not self._open:
                return
            self.flush(timeout=timeout)
            self._open = False
            self._cv.notify_all()
        self._thread.join(timeout=timeout)

    def stats(self) -> dict:
        """Completed-task counters + the most recent stats line per
        kind."""
        with self._cv:
            last: dict[str, dict] = {}
            for kind, info in self.log:
                last[kind] = info
            return {"completed": dict(self.counts),
                    "pending": len(self._queue),
                    "errors": len(self.errors), "last": last}

    # ------------------------------------------------------------- worker
    def _run(self) -> None:
        while True:
            with self._cv:
                while self._open and not self._queue:
                    self._cv.wait()
                if not self._queue:
                    return                      # closed and drained
                kind, fn = self._queue.popleft()
                self._pending.discard(kind)
                self._running = kind
            try:
                info = fn()
            except BaseException as e:          # noqa: BLE001 — logged
                info = {"error": repr(e)}
                with self._cv:
                    self.errors.append((kind, e))
            with self._cv:
                self.counts[kind] += 1
                self.log.append((kind, info or {}))
                self._running = None
                self._cv.notify_all()


class IndexMaintenance:
    """Moves a durable session's spill/compaction/gc onto a
    :class:`MaintenanceExecutor` (see module docstring).  ``detach()``
    restores synchronous threshold spills and the store's auto
    compaction."""

    def __init__(self, indexer, executor: MaintenanceExecutor):
        if indexer is None or indexer.store is None:
            raise ValueError("IndexMaintenance needs a store-attached "
                             "StreamingIndexer")
        self.si = indexer
        self.store = indexer.store
        self.ex = executor
        self._auto_compact_prev = self.store.auto_compact
        self.store.auto_compact = False        # compaction is OUR task now
        self.si.set_spill_hook(self.schedule_spill)

    def schedule_spill(self) -> None:
        """The indexer's threshold hook: runs on the appending thread,
        only enqueues (deduplicated)."""
        self.ex.submit("spill", self._spill)

    def schedule_compact(self) -> None:
        self.ex.submit("compact", self._compact)

    def schedule_gc(self) -> None:
        self.ex.submit("gc", self._gc)

    def detach(self) -> None:
        self.si.set_spill_hook(None)
        self.store.auto_compact = self._auto_compact_prev

    # -------------------------------------------------------------- tasks
    def _spill(self) -> dict:
        token = self.si.prepare_spill()        # slow: readback + file write
        if token is None:
            return {"flushed_records": 0}
        try:
            self.si.commit_spill(token)        # fast: manifest swap
        except BaseException:
            self.si.abort_spill(token)
            raise
        self.schedule_compact()
        self.schedule_gc()                     # rotated WALs are garbage now
        meta = token[0]
        return {"flushed_records": meta.num_records, "segment": meta.file}

    def _compact(self) -> dict:
        st = self.store.compact()
        if st.merges:
            self.schedule_gc()                 # merges created garbage
        return {"merges": st.merges, "segments_merged": st.segments_merged,
                "bytes_written": st.bytes_written,
                "bytes_reclaimed": st.bytes_reclaimed}

    def _gc(self) -> dict:
        st = self.store.gc()
        return {"removed": len(st.removed),
                "bytes_reclaimed": st.bytes_reclaimed,
                "skipped_inflight": len(st.skipped_inflight)}
