"""Background store maintenance: spill, compaction, gc, and scrub off the
append path.

The paper's duty cycle only pays off if the ingest path stays on its fast
track during peak load: a synchronous segment spill (device readback +
checksummed file write + manifest swap) or a compaction cascade in the
middle of ``append()`` is exactly the stall the silicon avoids by
double-buffering its transpose flush.  This module is the software
analogue:

  * :class:`MaintenanceExecutor` — one daemon worker thread draining a
    deduplicated task queue.  ``submit(kind, fn)`` enqueues unless a task
    of that ``kind`` is already pending, so an append storm that crosses
    the flush threshold a thousand times schedules ONE spill.  Task
    bodies run under a :class:`repro.serve.resilience.RetryPolicy`:
    transient failures (an EIO blip, an injected hiccup) back off and
    retry on the worker; only the final failure of a task lands in the
    per-kind failure counters and ``last_failure`` record that
    ``stats()`` (and through it ``service.metrics()``) surfaces.
  * :class:`IndexMaintenance` — wires a durable
    :class:`repro.engine.runtime.StreamingIndexer` onto an executor: the
    indexer's threshold spill becomes an enqueue (appends return
    immediately), the spill itself runs the two-phase
    ``prepare_spill`` / ``commit_spill`` protocol on the worker (crash
    between the phases loses nothing — the WAL still covers every
    block), and a committed spill chains a compaction pass, which chains
    a gc sweep.  A ``scrub`` task CRC-verifies every committed segment
    and repairs corruption from the live in-memory index (the replica
    that is, by construction, bit-identical to what the segment held) —
    the service schedules one on every standby entry, turning idle time
    into integrity checking.  Each task reports stats into the
    executor's log.

Serving stays consistent throughout: queries snapshot the in-memory
packed view (a functional jax array pinned with its record count by the
indexer mutex), so a spill, merge, or segment repair mid-flight never
changes a result bit.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable

import numpy as np

from repro.fault import seam
from repro.obs import trace as obs_trace
from repro.serve.resilience import RetryPolicy, is_transient

__all__ = ["MaintenanceExecutor", "IndexMaintenance"]


class MaintenanceExecutor:
    """One background worker, a deduplicated task queue, and a bounded
    log of what ran.  Tasks are ``fn() -> dict`` (the dict is the task's
    stats line); transient exceptions retry under ``retry_policy``, and
    a task's FINAL exception is captured into :attr:`errors` /
    :attr:`failures` / :attr:`last_failure`, never propagated into the
    worker loop."""

    def __init__(self, *, name: str = "repro-maintenance",
                 log_limit: int = 256,
                 retry_policy: RetryPolicy | None = None):
        self._cv = threading.Condition()
        self._queue: collections.deque = collections.deque()
        self._pending: set[str] = set()
        self._running: str | None = None
        self._open = True
        self.retry_policy = (retry_policy if retry_policy is not None
                             else RetryPolicy())
        self.counts: collections.Counter = collections.Counter()
        self.log: collections.deque = collections.deque(maxlen=log_limit)
        self.errors: list[tuple[str, BaseException]] = []
        self.failures: collections.Counter = collections.Counter()
        self.retries: collections.Counter = collections.Counter()
        #: kind -> repr of its most recent final failure
        self.last_failure: dict[str, str] = {}
        self._task_seq = 0             # retry-jitter seed (deterministic)
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    def submit(self, kind: str, fn: Callable[[], dict | None]) -> bool:
        """Enqueue ``fn`` under ``kind`` unless one is already pending;
        returns whether it was enqueued.  Never blocks (the whole point:
        this is what the append path calls)."""
        with self._cv:
            if not self._open:
                raise RuntimeError("maintenance executor is closed")
            if kind in self._pending:
                return False
            self._pending.add(kind)
            # capture the submitter's span context NOW: the worker's
            # maintenance.<kind> span parents to the operation that
            # scheduled the task (e.g. the wave whose append crossed the
            # spill threshold), not to wherever the worker happens to be
            self._queue.append((kind, fn, obs_trace.current_context()))
            self._cv.notify_all()
            return True

    def flush(self, timeout: float | None = None) -> bool:
        """Block until the queue is empty and no task is running (tasks
        enqueued by running tasks included); returns False on timeout."""
        with self._cv:
            return self._cv.wait_for(
                lambda: not self._queue and self._running is None,
                timeout=timeout)

    def close(self, *, timeout: float | None = None) -> None:
        """Drain outstanding tasks, then stop the worker.  Idempotent."""
        with self._cv:
            if not self._open:
                return
            self.flush(timeout=timeout)
            self._open = False
            self._cv.notify_all()
        self._thread.join(timeout=timeout)

    def kill(self) -> None:
        """Crash simulation: stop the worker WITHOUT draining — queued
        tasks are dropped on the floor, exactly like the process dying
        between maintenance passes.  The chaos harness uses this to
        place crash instants; everything dropped must be recoverable
        from WAL + manifest alone."""
        with self._cv:
            self._open = False
            self._queue.clear()
            self._pending.clear()
            self._cv.notify_all()
        self._thread.join(timeout=5.0)

    def stats(self) -> dict:
        """Completed-task counters, per-kind failure/retry accounting,
        and the most recent stats line per kind.  ``errors`` stays an
        int (total final failures) for drop-in assertion compatibility;
        ``failures``/``retries`` break it down per kind and
        ``last_failure`` carries each kind's most recent exception."""
        with self._cv:
            last: dict[str, dict] = {}
            for kind, info in self.log:
                last[kind] = info
            return {"completed": dict(self.counts),
                    "pending": len(self._queue),
                    "errors": len(self.errors),
                    "failures": dict(self.failures),
                    "retries": dict(self.retries),
                    "last_failure": dict(self.last_failure),
                    "last": last}

    # ------------------------------------------------------------- worker
    def _run(self) -> None:
        while True:
            with self._cv:
                while self._open and not self._queue:
                    self._cv.wait()
                if not self._queue:
                    return                      # closed/killed and drained
                kind, fn, ctx = self._queue.popleft()
                self._pending.discard(kind)
                self._running = kind
                self._task_seq += 1
                seed = self._task_seq

            def body(kind=kind, fn=fn):
                # the seam fires per ATTEMPT: a scheduled task_error on
                # occurrence k is transient by construction — the retry
                # advances past it
                seam.fire("maintenance.task", kind=kind)
                return fn()

            def on_retry(attempt, exc, kind=kind):
                with self._cv:
                    self.retries[kind] += 1

            try:
                with obs_trace.maybe_span(f"maintenance.{kind}",
                                          parent=ctx):
                    info = self.retry_policy.call(
                        body, seed=seed, retryable=is_transient,
                        on_retry=on_retry)
            except BaseException as e:          # noqa: BLE001 — logged
                info = {"error": repr(e)}
                with self._cv:
                    self.errors.append((kind, e))
                    self.failures[kind] += 1
                    self.last_failure[kind] = repr(e)
            with self._cv:
                self.counts[kind] += 1
                self.log.append((kind, info or {}))
                self._running = None
                self._cv.notify_all()


class IndexMaintenance:
    """Moves a durable session's spill/compaction/gc/scrub onto a
    :class:`MaintenanceExecutor` (see module docstring).  ``detach()``
    restores synchronous threshold spills and the store's auto
    compaction."""

    def __init__(self, indexer: "StreamingIndexer",
                 executor: MaintenanceExecutor):
        if indexer is None or indexer.store is None:
            raise ValueError("IndexMaintenance needs a store-attached "
                             "StreamingIndexer")
        self.si = indexer
        self.store = indexer.store
        self.ex = executor
        self._auto_compact_prev = self.store.auto_compact
        self.store.auto_compact = False        # compaction is OUR task now
        self.si.set_spill_hook(self.schedule_spill)

    def schedule_spill(self) -> None:
        """The indexer's threshold hook: runs on the appending thread,
        only enqueues (deduplicated)."""
        self.ex.submit("spill", self._spill)

    def schedule_compact(self) -> None:
        self.ex.submit("compact", self._compact)

    def schedule_gc(self) -> None:
        self.ex.submit("gc", self._gc)

    def schedule_scrub(self) -> None:
        """CRC-verify + self-heal the committed segments in the
        background (the service enqueues this on standby entry)."""
        self.ex.submit("scrub", self._scrub)

    def detach(self) -> None:
        self.si.set_spill_hook(None)
        self.store.auto_compact = self._auto_compact_prev

    # -------------------------------------------------------------- tasks
    def _spill(self) -> dict:
        token = self.si.prepare_spill()        # slow: readback + file write
        if token is None:
            return {"flushed_records": 0}
        try:
            self.si.commit_spill(token)        # fast: manifest swap
        except BaseException:
            self.si.abort_spill(token)
            raise
        self.schedule_compact()
        self.schedule_gc()                     # rotated WALs are garbage now
        meta = token[0]
        return {"flushed_records": meta.num_records, "segment": meta.file}

    def _compact(self) -> dict:
        st = self.store.compact()
        if st.merges:
            self.schedule_gc()                 # merges created garbage
        return {"merges": st.merges, "segments_merged": st.segments_merged,
                "bytes_written": st.bytes_written,
                "bytes_reclaimed": st.bytes_reclaimed}

    def _gc(self) -> dict:
        st = self.store.gc()
        return {"removed": len(st.removed),
                "bytes_reclaimed": st.bytes_reclaimed,
                "skipped_inflight": len(st.skipped_inflight)}

    def _replica(self, meta) -> np.ndarray | None:
        """A known-good copy of a segment's packed words, re-extracted
        from the live in-memory index (which covers every record the
        store does — appends splice in memory first).  None when the
        view doesn't cover the segment (shouldn't happen on a live
        session; scrub then quarantines instead of repairing)."""
        from repro.engine import policy
        buf, n = self.si.view()
        if meta.start_record + meta.num_records > n:
            return None
        return np.asarray(policy.extract_packed(
            buf, meta.start_record, meta.num_records))

    def _scrub(self) -> dict:
        st = self.store.scrub(repair=self._replica)
        if st.repaired:
            self.schedule_gc()                 # repairs may leave .tmp debris
        return {"checked": st.checked, "corrupt": len(st.corrupt),
                "repaired": len(st.repaired),
                "quarantined": len(st.quarantined)}
