"""Bitmap Index Creation (BIC) — the paper's core, as a composable JAX module.

Mirrors Fig. 3 of the paper: a BIC core indexes N records by M keys through
CAM-match -> buffer -> transpose, producing an M x N bitmap index on which
multi-dimensional queries are bitwise row operations.  The paper's fabricated
core used M=8 keys, N=16 records, W=32 8-bit words per record
(``PaperConfig`` below); this module generalizes all three.

Two execution paths:
  * ``backend="pallas"``  — the TPU kernels (interpret-mode on CPU).
  * ``backend="ref"``     — the pure-jnp oracle (used for differential tests).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

PACK = 32


@dataclasses.dataclass(frozen=True)
class BICConfig:
    """Geometry of one BIC core."""
    num_keys: int = 8          # M
    num_records: int = 16      # N
    words_per_record: int = 32 # W
    word_bits: int = 8         # 8-bit words in the paper
    backend: Literal["pallas", "ref"] = "pallas"

    @property
    def memory_bits(self) -> int:
        """Paper §IV accounting: one CAM cell costs 32 RAM bits, buffer is N*M."""
        cam_bits = self.words_per_record * PACK * self.word_bits
        buffer_bits = self.num_records * self.num_keys
        return cam_bits + buffer_bits


# The fabricated proof-of-concept chip (paper §IV): 8,320 memory bits.
PaperConfig = BICConfig(num_keys=8, num_records=16, words_per_record=32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitmapIndex:
    """Key-major packed bitmap index: rows = keys, columns = records."""
    packed: jax.Array          # (M, ceil(N/32)) uint32
    num_records: int

    def tree_flatten(self):
        return (self.packed,), self.num_records

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    @property
    def num_keys(self) -> int:
        return self.packed.shape[0]

    def row(self, key_idx: int) -> jax.Array:
        return self.packed[key_idx]

    def to_dense(self) -> jax.Array:
        """(M, N) {0,1} — for tests and small examples only."""
        return ref.unpack_bits(self.packed, self.num_records)


class BICCore:
    """One BIC core: ``create`` builds the index, ``query`` executes
    multi-dimensional predicates over it."""

    def __init__(self, config: BICConfig = PaperConfig):
        self.config = config

    def create(self, records: jax.Array, keys: jax.Array) -> BitmapIndex:
        """records (N, W) int, keys (M,) int -> key-major BitmapIndex."""
        n, w = records.shape
        if self.config.backend == "ref":
            npad = -n % PACK
            mpad = -keys.shape[0] % PACK
            rec = jnp.pad(records.astype(jnp.int32), ((0, npad), (0, 0)),
                          constant_values=-1)
            ks = jnp.pad(keys.astype(jnp.int32), (0, mpad), constant_values=-2)
            packed = ref.create_index(rec, ks)[: keys.shape[0]]
        else:
            packed = ops.create_index(records, keys)
        return BitmapIndex(packed, num_records=n)

    def query(self, index: BitmapIndex, include: Sequence[int] = (),
              exclude: Sequence[int] = ()) -> tuple[jax.Array, jax.Array]:
        """The paper's example: ``query(idx, include=[2, 4], exclude=[5])``
        answers "all objects containing A2 and A4 but not A5".

        Returns (packed result row, matching-object count)."""
        sel = list(include) + list(exclude)
        if not sel:
            raise ValueError("query needs at least one operand row")
        rows = index.packed[jnp.asarray(sel, dtype=jnp.int32)]
        invert = jnp.asarray([0] * len(include) + [1] * len(exclude),
                             dtype=jnp.int32)
        if self.config.backend == "ref":
            result, count = ref.bitmap_query(rows, invert)
            # Mask pad bits beyond num_records (inverted rows set them).
            result, count = _mask_tail(result, index.num_records)
        else:
            result, count = ops.query(rows, invert)
            result, count = _mask_tail(result, index.num_records)
        return result, count

    def batch_create(self, records: jax.Array, keys: jax.Array) -> BitmapIndex:
        """Index B batches of records with shared keys by flattening the
        batch into the record axis (the multi-core layout of Fig. 4 stores
        batches contiguously in external memory)."""
        b, n, w = records.shape
        return self.create(records.reshape(b * n, w), keys)


def _mask_tail(result: jax.Array, num_records: int) -> tuple[jax.Array, jax.Array]:
    """Zero bits >= num_records (they exist only due to 32-bit packing)."""
    nw = result.shape[0]
    valid = (jnp.arange(nw * PACK, dtype=jnp.uint32) < num_records)
    mask = ref.pack_bits(valid)          # (nw,)
    masked = result & mask
    count = jax.lax.population_count(masked).astype(jnp.int32).sum()
    return masked, count
