"""Bitmap Index Creation (BIC) — the paper's core, as a composable JAX module.

Mirrors Fig. 3 of the paper: a BIC core indexes N records by M keys through
CAM-match -> buffer -> transpose, producing an M x N bitmap index on which
multi-dimensional queries are bitwise row operations.  The paper's fabricated
core used M=8 keys, N=16 records, W=32 8-bit words per record
(``PaperConfig`` below); this module generalizes all three.

Execution is delegated through the :mod:`repro.db` facade — querying an
index wraps it in a read-only ``BitmapDB`` session, so ``BICCore.query`` /
``query_many`` serve through exactly the path production uses (bucketed
batch executors; the legacy ``include=``/``exclude=`` lists go through the
facade's deprecation shim, byte-identical).  ``BICCore.create`` still
dispatches the backend registry directly:

  * ``backend="pallas"`` — the TPU kernels (interpret-mode on CPU).
  * ``backend="ref"``    — the pure-jnp oracle (differential tests).
  * ``backend="auto"``   — pallas on TPU, ref elsewhere.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import jax

from repro.engine import backends as _backends
from repro.engine import planner as _planner
from repro.engine.policy import PACK, BitmapIndex

__all__ = ["PACK", "BICConfig", "PaperConfig", "BitmapIndex", "BICCore"]


@dataclasses.dataclass(frozen=True)
class BICConfig:
    """Geometry of one BIC core."""
    num_keys: int = 8          # M
    num_records: int = 16      # N
    words_per_record: int = 32 # W
    word_bits: int = 8         # 8-bit words in the paper
    backend: Literal["pallas", "ref", "auto"] = "auto"

    @property
    def memory_bits(self) -> int:
        """Paper §IV accounting: one CAM cell costs 32 RAM bits, buffer is N*M."""
        cam_bits = self.words_per_record * PACK * self.word_bits
        buffer_bits = self.num_records * self.num_keys
        return cam_bits + buffer_bits


# The fabricated proof-of-concept chip (paper §IV): 8,320 memory bits.
PaperConfig = BICConfig(num_keys=8, num_records=16, words_per_record=32)


class BICCore:
    """One BIC core: ``create`` builds the index, ``query`` executes
    multi-dimensional predicates over it."""

    def __init__(self, config: BICConfig = PaperConfig):
        self.config = config

    def create(self, records: jax.Array, keys: jax.Array) -> BitmapIndex:
        """records (N, W) int, keys (M,) int -> key-major BitmapIndex."""
        backend = _backends.get_backend(self.config.backend)
        return BitmapIndex(backend.create_index(records, keys),
                           num_records=records.shape[0])

    def session(self, index: BitmapIndex):
        """Wrap ``index`` in a read-only :class:`repro.db.BitmapDB` query
        session (the facade every query below routes through)."""
        from repro import db as _db
        return _db.BitmapDB.from_index(index, backend=self.config.backend)

    def query(self, index: BitmapIndex, include: Sequence[int] = (),
              exclude: Sequence[int] = (), *,
              where: _planner.Pred | None = None
              ) -> tuple[jax.Array, jax.Array]:
        """The paper's example: ``query(idx, include=[2, 4], exclude=[5])``
        answers "all objects containing A2 and A4 but not A5" (the legacy
        key-list surface — a deprecation shim in :mod:`repro.db` keeps it
        byte-identical).

        ``where`` accepts an arbitrary AND/OR/NOT predicate tree instead,
        e.g. ``query(idx, where=(key(2) | key(7)) & ~key(5))``, or a
        :mod:`repro.db` schema expression when you hold one.

        Returns (packed result row, matching-object count)."""
        from repro import db as _db
        if where is None:
            where = _db.include_exclude_pred(include, exclude)
        elif include or exclude:
            raise ValueError("pass either include/exclude or where=, not both")
        return self.session(index).query(where).raw

    def query_many(self, index: BitmapIndex,
                   predicates: Sequence[_planner.Pred]
                   ) -> tuple[jax.Array, jax.Array]:
        """Serve a whole batch of ``where=``-style predicate trees (or
        pre-built plans) in a handful of vmapped dispatches — the facade
        buckets plans by shape instead of looping ``query`` per tree.

        Returns (rows (Q, Nw) uint32, counts (Q,) int32) in input order,
        bit-identical to calling :meth:`query` per predicate."""
        return self.session(index).serve_step()(predicates)

    def batch_create(self, records: jax.Array, keys: jax.Array) -> BitmapIndex:
        """Index B batches of records with shared keys by flattening the
        batch into the record axis (the multi-core layout of Fig. 4 stores
        batches contiguously in external memory)."""
        b, n, w = records.shape
        return self.create(records.reshape(b * n, w), keys)
