"""Calibrated analytical model of the BIC chip's silicon measurements.

TPUs expose no V_dd / V_bb knobs, so the paper's device-level results
(Figs. 6-8, Table I) are reproduced with an analytical model *calibrated to
every datapoint the paper reports* — clearly simulation, not measurement
(see DESIGN.md §5).  The model is used by the benchmarks to regenerate the
paper's figures and by the elastic scheduler to account energy.

Components
  * frequency  : alpha-power law  f(V) = K (V - V_th)^alpha / V
  * active pwr : P = C_eff V^2 f  (+ active leakage, negligible at these V)
  * standby    : I_stb(V_dd, V_bb) = I_slc + I_gidl
       - I_slc : subthreshold leakage, one decade per 0.5 V of reverse V_bb
                 (paper Fig. 8), with a floor.
       - I_gidl: gate-induced drain leakage, grows with V_dd and reverse
                 V_bb — reproduces the paper's observed crossover where at
                 V_dd > 0.8 V the V_bb = -2 V curve exceeds the -1.5 V one.

Calibration anchors (all from the paper):
  f(0.4 V)=10.1 MHz, f(1.2 V)=41 MHz; P(0.4)=0.17 mW, P(1.2)=6.68 mW;
  E(1.2 V)=162.9 pJ/cycle; CG-only standby 10.6 uW @ 0.4 V;
  CG+RBB standby 2.64 nW @ 0.4 V (I_stb = 6.6 nA @ V_bb = -2 V);
  memory = 8.125 Kbit = 8,320 bits -> SPB = 0.31 pW/bit.
"""
from __future__ import annotations

import dataclasses
import math

# ---------------------------------------------------------------- frequency
V_TH = 0.25           # effective threshold [V]
# alpha from the two measured frequency anchors:
#   f(1.2)/f(0.4) = (0.95/0.15)^alpha * (0.4/1.2)  =>  alpha = 1.3545
ALPHA = math.log((41.0 / 10.1) * 3.0) / math.log(0.95 / 0.15)
K_FREQ = 41.0e6 * 1.2 / (1.2 - V_TH) ** ALPHA     # pins f(1.2 V) = 41 MHz


def frequency(vdd: float) -> float:
    """Max operating frequency [Hz] at supply ``vdd`` [V] (paper Fig. 6)."""
    if vdd <= V_TH:
        return 0.0
    return K_FREQ * (vdd - V_TH) ** ALPHA / vdd


# -------------------------------------------------------------- active power
# Effective switched capacitance, least-squares over the paper's anchors
# (0.4 V, 0.17 mW), (0.55 V, 0.6 mW @ 22 MHz), (1.2 V, 6.68 mW):
C_EFF = 6.68e-3 / (1.2 ** 2 * 41.0e6)             # pins E(1.2 V)=162.9 pJ


def active_power(vdd: float, freq: float | None = None) -> float:
    """Active-mode power [W] (paper Fig. 6, right axis)."""
    f = frequency(vdd) if freq is None else freq
    return C_EFF * vdd * vdd * f


def energy_per_cycle(vdd: float) -> float:
    """Energy per cycle [J] (paper Fig. 7) — C_eff V^2, so 162.9 pJ @ 1.2 V."""
    return C_EFF * vdd * vdd


# ------------------------------------------------------------- standby power
# CG-only standby @ 0.4 V is 10.6 uW -> I_slc(V_bb=0, 0.4 V) = 26.5 uA.
I_SLC0 = 10.6e-6 / 0.4        # [A] at V_dd = 0.4 V, V_bb = 0
SLC_DECADE_PER_V = 2.0        # one decade per 0.5 V reverse bias (Fig. 8)
SLC_VDD_SENS = 0.6            # mild I_slc growth with V_dd (DIBL-like)
I_SLC_FLOOR = 6.1e-9          # [A] junction-limited floor (pins I_stb(-2 V)=6.6 nA)
# GIDL: negligible at low V_dd, dominant at V_dd > ~0.8 V with deep reverse
# V_bb (paper Fig. 8 crossover).
GIDL_A = 2.0e-12              # [A] prefactor
GIDL_VDD_EXP = 6.0            # sharp V_dd dependence
GIDL_VBB_PER_V = 1.2          # decades per volt of reverse bias


def standby_current(vdd: float, vbb: float = 0.0) -> float:
    """I_stb [A] in standby (clock gated) at back-gate bias ``vbb`` <= 0 V.

    Reproduces Fig. 8: decade/0.5 V subthreshold reduction, a ~6 nA floor at
    V_bb = -2 V / V_dd = 0.4 V, and the GIDL takeover at high V_dd.
    """
    rev = max(0.0, -vbb)
    i_slc = (I_SLC0 * 10.0 ** (SLC_VDD_SENS * (vdd - 0.4))
             * 10.0 ** (-SLC_DECADE_PER_V * rev))
    i_slc = max(i_slc, I_SLC_FLOOR * 10.0 ** (SLC_VDD_SENS * (vdd - 0.4)))
    i_gidl = GIDL_A * (vdd / 0.4) ** GIDL_VDD_EXP * 10.0 ** (GIDL_VBB_PER_V * rev)
    return i_slc + i_gidl


def standby_power(vdd: float, vbb: float = 0.0, *, clock_gated: bool = True) -> float:
    """Standby power [W].  CG removes dynamic power; RBB (vbb < 0) removes
    leakage.  ``clock_gated=False`` returns active idle power instead."""
    if not clock_gated:
        return active_power(vdd)
    return standby_current(vdd, vbb) * vdd


# ------------------------------------------------------------------- chip DB
MEMORY_BITS = 8320            # 8.125 Kbit (paper §IV: 8,192 CAM + 128 buffer)


def standby_power_per_bit(vdd: float = 0.4, vbb: float = -2.0) -> float:
    """SPB [W/bit] — the paper's headline 0.31 pW/bit."""
    return standby_power(vdd, vbb) / MEMORY_BITS


@dataclasses.dataclass(frozen=True)
class ChipRow:
    """One row of Table I."""
    name: str
    technology: str
    area_mm2: float
    memory_kbits: float
    standby_technique: str
    standby_power_uw: float | None

    @property
    def spb_pw_per_bit(self) -> float | None:
        if self.standby_power_uw is None:
            return None
        return self.standby_power_uw * 1e6 / (self.memory_kbits * 1024)


TABLE_I = [
    ChipRow("Ref. [12]", "65 nm", 0.43, 36.0, "PG", 842.0),
    ChipRow("Ref. [13]", "40 nm LP", 0.07, 10.0, "PG", 201.0),
    ChipRow("Ref. [14]", "65 nm SOTB", 1.60, 64.0, "CG+RBB", 0.12),
    ChipRow("Ref. [15]", "28 nm FDSOI", 0.33, 8.0, "-", 8.0 * 1024 * 1.74e-6),
    ChipRow("This work", "65 nm SOTB", 0.21, 8.125, "CG+RBB",
            None),  # filled from the model at report time
]

# Paper-reported datapoints used by the benchmark suite to score the model.
PAPER_ANCHORS = {
    "freq_mhz": {0.4: 10.1, 1.2: 41.0},
    "active_mw": {0.4: 0.17, 1.2: 6.68},
    "energy_pj_12": 162.9,
    "standby_cg_uw_04": 10.6,
    "standby_rbb_nw_04": 2.64,
    "istb_min_na": 6.6,
    "spb_pw_bit": 0.31,
}
