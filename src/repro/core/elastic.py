"""Elastic multi-core *policy*: energy accounting and straggler scheduling.

The paper deploys Z BIC cores, feeds each a batch from external memory, and
puts idle cores in standby (CG + RBB).  The TPU translation:

  * "Z cores"            -> Z devices along the ``data`` mesh axis; the
                            engine runtime (``repro.engine.runtime``)
                            shard_maps one BIC pipeline per device.
  * "standby idle cores" -> the elastic scheduler activates only
                            ceil(workload / batches_per_core) cores per tick
                            and accounts the rest at standby power using the
                            calibrated model (core/power.py).
  * stragglers           -> longest-processing-time dynamic assignment
                            (work stealing): batches are handed to the
                            earliest-finishing core instead of statically
                            striped, bounding makespan at max(LPT) instead
                            of max(static stripe x slowest core).

Actual sharded execution lives in :mod:`repro.engine.runtime`
(``MulticoreRuntime`` fuses it with this module's energy accounting);
``multicore_create_index`` below is a thin compatibility wrapper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro import compat  # noqa: F401  (mesh API shims for jax 0.4.x)

import jax

from repro.core.bic import BICConfig, PaperConfig
from repro.core import power


# ------------------------------------------------------------- multi-core op
def multicore_create_index(records: jax.Array, keys: jax.Array,
                           mesh, axis: str = "data",
                           *, backend: str = "auto") -> jax.Array:
    """Compatibility wrapper over the engine runtime's sharded build.

    records (Z*B, N, W) sharded over ``axis``; keys replicated.  Returns
    (Z*B, M, ceil(N/32)).  See ``repro.engine.runtime``.
    """
    from repro.engine.runtime import multicore_create_index as _impl
    return _impl(records, keys, mesh, axis, backend=backend)


# -------------------------------------------------------- elastic energy sim
@dataclasses.dataclass(frozen=True)
class PowerState:
    """Operating point of one core."""
    vdd_active: float = 1.2
    vdd_standby: float = 0.4
    vbb_standby: float = -2.0
    use_rbb: bool = True


@dataclasses.dataclass
class EnergyReport:
    active_joules: float = 0.0
    standby_joules: float = 0.0
    busy_core_seconds: float = 0.0
    idle_core_seconds: float = 0.0
    batches: int = 0

    @property
    def total_joules(self) -> float:
        return self.active_joules + self.standby_joules

    def merge(self, other: "EnergyReport") -> "EnergyReport":
        """Accumulate another report into this one, field by field."""
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self


def cycles_per_batch(cfg: BICConfig = PaperConfig) -> int:
    """BIC core cycle count for one batch: N records x (load + M key probes)
    + M transpose flush cycles (paper §III dataflow)."""
    return cfg.num_records * (cfg.num_keys + 1) + cfg.num_keys


class ElasticScheduler:
    """Workload-aware core activation with energy accounting.

    Each tick: ``workload`` batches arrive; the scheduler activates the
    minimum number of cores that finishes within the tick, puts the rest in
    standby (CG, optionally +RBB), and integrates energy with the calibrated
    silicon model.
    """

    def __init__(self, num_cores: int, cfg: BICConfig = PaperConfig,
                 state: PowerState = PowerState()):
        self.num_cores = num_cores
        self.cfg = cfg
        self.state = state
        self.freq = power.frequency(state.vdd_active)
        self.batch_seconds = cycles_per_batch(cfg) / self.freq
        self.p_active = power.active_power(state.vdd_active)
        vbb = state.vbb_standby if state.use_rbb else 0.0
        self.p_standby = power.standby_power(state.vdd_standby, vbb)

    def cores_needed(self, workload: int, tick_seconds: float) -> int:
        cap_per_core = max(1, int(tick_seconds / self.batch_seconds))
        return min(self.num_cores, math.ceil(workload / cap_per_core))

    def calibrate(self, measured_mbps_per_core: float) -> None:
        """Re-derive the per-core batch time from a *measured* per-core
        indexing throughput, so ``cores_needed`` and the busy-time model
        track the device actually executing instead of the paper clock.
        MB/s is in PAPER units — one 8-bit record word per byte, the same
        accounting as ``cycles_per_batch`` and ``TickResult.measured_mbps``
        — so both sides of the division stay consistent.  Ignores
        non-positive measurements."""
        if measured_mbps_per_core <= 0:
            return
        batch_bytes = self.cfg.num_records * self.cfg.words_per_record
        self.batch_seconds = batch_bytes / (measured_mbps_per_core * 1e6)

    def account(self, workload: int, tick_seconds: float, *,
                busy_seconds: float | None = None) -> EnergyReport:
        """Energy for ONE tick of ``workload`` batches.  By default the
        busy time comes from the model (workload count x per-core batch
        time); pass ``busy_seconds`` to charge active energy over a
        measured dispatch wall-clock instead."""
        rep = EnergyReport()
        z = self.cores_needed(workload, tick_seconds) if workload else 0
        if z:
            model_busy = min(tick_seconds,
                             (workload / max(z, 1)) * self.batch_seconds)
            busy = (model_busy if busy_seconds is None
                    else min(tick_seconds, busy_seconds))
        else:
            busy = 0.0
        rep.active_joules += z * self.p_active * busy
        # active cores idle-standby for the remainder of the tick too
        rep.standby_joules += (
            z * self.p_standby * (tick_seconds - busy)
            + (self.num_cores - z) * self.p_standby * tick_seconds)
        rep.busy_core_seconds += z * busy
        rep.idle_core_seconds += self.num_cores * tick_seconds - z * busy
        rep.batches += workload
        return rep

    def run(self, workloads: Sequence[int], tick_seconds: float) -> EnergyReport:
        rep = EnergyReport()
        for wl in workloads:
            rep.merge(self.account(wl, tick_seconds))
        return rep


# ------------------------------------------------------ straggler mitigation
def lpt_schedule(batch_costs: Sequence[float], speeds: Sequence[float]
                 ) -> tuple[float, list[int]]:
    """Dynamic longest-processing-time assignment to heterogeneous cores.

    Returns (makespan, assignment core-index per batch).  This is the
    work-stealing policy the distributed runtime uses when a core (device
    host) runs slow: batches go to the earliest-available core.
    """
    finish = [0.0] * len(speeds)
    order = sorted(range(len(batch_costs)), key=lambda i: -batch_costs[i])
    assign_of = [0] * len(batch_costs)
    for i in order:
        core = min(range(len(speeds)),
                   key=lambda c: finish[c] + batch_costs[i] / speeds[c])
        finish[core] += batch_costs[i] / speeds[core]
        assign_of[i] = core
    return max(finish) if finish else 0.0, assign_of


def static_schedule(batch_costs: Sequence[float], speeds: Sequence[float]
                    ) -> float:
    """Baseline: round-robin striping (no straggler awareness)."""
    finish = [0.0] * len(speeds)
    for i, c in enumerate(batch_costs):
        core = i % len(speeds)
        finish[core] += c / speeds[core]
    return max(finish) if finish else 0.0
