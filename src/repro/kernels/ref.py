"""Pure-jnp reference oracles for the BIC kernels.

Conventions (shared by kernels, oracles and tests):
  * A *record* is a row of W integer words (the paper uses 32 x 8-bit words).
  * ``cam_match``  : records (N, W) x keys (M,) -> record-major match bits,
                     packed along the key axis  -> (N, M/32) uint32.
  * ``bit_transpose``: packed (R, C/32) uint32 -> packed (C, R/32) uint32,
                     i.e. bit (r, c) of the logical R x C bit-matrix moves
                     to bit (c, r).
  * Packing is LSB-first: bit j of word w covers logical column w*32 + j.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PACK = 32
_U32 = jnp.uint32

# Canonical padding/sentinel policy (single source of truth; the engine
# layer re-exports these via repro.engine.policy):
#   * records pad with RECORD_SENTINEL — a padded record matches no key;
#   * keys pad with KEY_SENTINEL — a padded key matches no record, and the
#     two sentinels differ so sentinel never matches sentinel.
# Application data must not use the sentinel values as real key material.
RECORD_SENTINEL = -1
KEY_SENTINEL = -2


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def num_words(n: int) -> int:
    """Packed uint32 words needed for ``n`` bits."""
    return -(-n // PACK)


def pad_records(records: jax.Array, n_to: int | None = None) -> jax.Array:
    """Pad (N, W) records to ``n_to`` rows (default: next PACK multiple)
    with the record sentinel, as int32."""
    n = records.shape[0]
    n_to = round_up(n, PACK) if n_to is None else n_to
    return jnp.pad(records.astype(jnp.int32), ((0, n_to - n), (0, 0)),
                   constant_values=RECORD_SENTINEL)


def pad_keys(keys: jax.Array, m_to: int | None = None) -> jax.Array:
    """Pad (M,) keys to ``m_to`` entries (default: next PACK multiple) with
    the key sentinel, as int32."""
    m = keys.shape[0]
    m_to = round_up(m, PACK) if m_to is None else m_to
    return jnp.pad(keys.astype(jnp.int32), (0, m_to - m),
                   constant_values=KEY_SENTINEL)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a (..., L) bool/int array into (..., L/32) uint32, LSB-first.

    L must be a multiple of 32 (callers pad).
    """
    *lead, L = bits.shape
    assert L % PACK == 0, f"pack_bits: L={L} not a multiple of {PACK}"
    b = bits.astype(_U32).reshape(*lead, L // PACK, PACK)
    weights = (_U32(1) << jnp.arange(PACK, dtype=_U32))
    return (b * weights).sum(axis=-1).astype(_U32)


def unpack_bits(packed: jax.Array, length: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_bits` -> (..., L) uint32 of {0, 1}."""
    *lead, Lw = packed.shape
    shifts = jnp.arange(PACK, dtype=_U32)
    bits = (packed[..., None] >> shifts) & _U32(1)
    bits = bits.reshape(*lead, Lw * PACK)
    if length is not None:
        bits = bits[..., :length]
    return bits


def cam_match_unpacked(records: jax.Array, keys: jax.Array) -> jax.Array:
    """(N, W) records x (M,) keys -> (N, M) {0,1}: record n contains key m."""
    eq = records[:, None, :] == keys[None, :, None]          # (N, M, W)
    return jnp.any(eq, axis=-1).astype(_U32)


def cam_match(records: jax.Array, keys: jax.Array) -> jax.Array:
    """Reference for the cam_match kernel: packed (N, M/32) uint32."""
    return pack_bits(cam_match_unpacked(records, keys))


def bit_transpose(packed: jax.Array, nrows: int | None = None) -> jax.Array:
    """Reference packed bit-matrix transpose.

    packed: (R, C/32) uint32 for a logical R x C bit matrix, R % 32 == 0.
    Returns (C, R/32) uint32.
    """
    R, Cw = packed.shape
    assert R % PACK == 0
    bits = unpack_bits(packed)            # (R, C)
    return pack_bits(bits.T)              # (C, R/32)


def bitmap_query(rows: jax.Array, invert: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reference fused bitmap query.

    rows   : (K, Nw) packed uint32 — the K operand index rows.
    invert : (K,) {0,1} — 1 means the row enters the AND negated.
    Returns (result_row (Nw,) uint32, popcount () int32) for
    AND_k (invert_k ? ~rows_k : rows_k).
    """
    inv = invert.astype(_U32)[:, None]
    terms = rows ^ (inv * _U32(0xFFFFFFFF))
    result = terms[0]
    for k in range(1, rows.shape[0]):
        result = result & terms[k]
    count = jax.lax.population_count(result).astype(jnp.int32).sum()
    return result, count


def create_index(records: jax.Array, keys: jax.Array) -> jax.Array:
    """Full reference BIC pipeline: records (N, W), keys (M,) ->
    key-major bitmap index, packed (M, N/32) uint32.  N, M % 32 == 0."""
    record_major = cam_match(records, keys)       # (N, M/32)
    return bit_transpose(record_major)            # (M, N/32)
