"""Pallas TPU kernel: fused bitmap query execution.

The point of a bitmap index is that a multi-dimensional query like
"A2 AND A4 AND (NOT A5)" is a streaming pass over K packed index rows.
Done naively that is K-1 separate elementwise passes (2(K-1) reads +
K-1 writes of the row length); the fused kernel reads each operand row
once, folds the masked AND in VMEM and emits both the result row and its
popcount (selectivity) in a single pass — the TPU analogue of the ASIC
streaming the BI rows through a logic tree.

rows (K, Nw) uint32, invert (K,) int32 -> (result (Nw,), count ()).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32


def _query_kernel(invert_ref, rows_ref, out_ref, count_ref):
    rows = rows_ref[...]                      # (K, BN) uint32
    inv = invert_ref[...]                     # (K,) int32 in SMEM
    k = rows.shape[0]

    def body(i, acc):
        row = jax.lax.dynamic_slice_in_dim(rows, i, 1, axis=0)[0]
        flip = (inv[i].astype(_U32) * _U32(0xFFFFFFFF))
        return acc & (row ^ flip)

    first = jax.lax.dynamic_slice_in_dim(rows, 0, 1, axis=0)[0]
    first = first ^ (inv[0].astype(_U32) * _U32(0xFFFFFFFF))
    result = jax.lax.fori_loop(1, k, body, first)
    out_ref[...] = result

    # Sequential-grid accumulation of the popcount.
    block_count = jax.lax.population_count(result).astype(jnp.int32).sum()

    @pl.when(pl.program_id(0) == 0)
    def _init():
        count_ref[0] = 0

    count_ref[0] += block_count


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def bitmap_query(rows: jax.Array, invert: jax.Array, *,
                 block_n: int = 2048, interpret: bool = True
                 ) -> tuple[jax.Array, jax.Array]:
    """AND_k (invert_k ? ~rows_k : rows_k) with fused popcount.

    rows (K, Nw) uint32, invert (K,) int -> (result (Nw,) uint32, count int32).
    Nw % block_n == 0 (ops.py pads).
    """
    K, Nw = rows.shape
    assert Nw % block_n == 0
    grid = (Nw // block_n,)
    result, count = pl.pallas_call(
        _query_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # invert: whole array
            pl.BlockSpec((K, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Nw,), _U32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(invert.astype(jnp.int32), rows.astype(_U32))
    return result, count[0]
