"""Pallas TPU kernels: fused bitmap query execution.

The point of a bitmap index is that a multi-dimensional query like
"A2 AND A4 AND (NOT A5)" is a streaming pass over K packed index rows.
Done naively that is K-1 separate elementwise passes (2(K-1) reads +
K-1 writes of the row length); the fused kernel reads each operand row
once, folds the masked AND in VMEM and emits both the result row and its
popcount (selectivity) in a single pass — the TPU analogue of the ASIC
streaming the BI rows through a logic tree.

rows (K, Nw) uint32, invert (K,) int32 -> (result (Nw,), count ()).

:func:`bulk_program` extends the same idea to a whole bucket of lowered
pass programs (the bulk backend's TPU path, see :mod:`repro.engine.bulk`):
the grid walks word tiles of the augmented index; per tile, every literal
of every query gathers from the VMEM-resident tile and the full
AND-over-literals / xor / AND-over-passes / OR-over-groups tree folds
before one write of the tile's result words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32


def _query_kernel(invert_ref, rows_ref, out_ref, count_ref):
    rows = rows_ref[...]                      # (K, BN) uint32
    inv = invert_ref[...]                     # (K,) int32 in SMEM
    k = rows.shape[0]

    def body(i, acc):
        row = jax.lax.dynamic_slice_in_dim(rows, i, 1, axis=0)[0]
        flip = (inv[i].astype(_U32) * _U32(0xFFFFFFFF))
        return acc & (row ^ flip)

    first = jax.lax.dynamic_slice_in_dim(rows, 0, 1, axis=0)[0]
    first = first ^ (inv[0].astype(_U32) * _U32(0xFFFFFFFF))
    result = jax.lax.fori_loop(1, k, body, first)
    out_ref[...] = result

    # Sequential-grid accumulation of the popcount.
    block_count = jax.lax.population_count(result).astype(jnp.int32).sum()

    @pl.when(pl.program_id(0) == 0)
    def _init():
        count_ref[0] = 0

    count_ref[0] += block_count


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def bitmap_query(rows: jax.Array, invert: jax.Array, *,
                 block_n: int = 2048, interpret: bool = True
                 ) -> tuple[jax.Array, jax.Array]:
    """AND_k (invert_k ? ~rows_k : rows_k) with fused popcount.

    rows (K, Nw) uint32, invert (K,) int -> (result (Nw,) uint32, count int32).
    Nw % block_n == 0 (ops.py pads).
    """
    K, Nw = rows.shape
    assert Nw % block_n == 0
    grid = (Nw // block_n,)
    result, count = pl.pallas_call(
        _query_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # invert: whole array
            pl.BlockSpec((K, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Nw,), _U32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(invert.astype(jnp.int32), rows.astype(_U32))
    return result, count[0]


def _bulk_kernel(sels_ref, invs_ref, post_ref, aug_ref, out_ref):
    blk = aug_ref[...]                        # (M+1, BN) — the resident tile
    sels = sels_ref[...]                      # (Q, G, P, L) int32
    invs = invs_ref[...]                      # (Q, G, P, L) int32
    post = post_ref[...]                      # (Q, G, P) uint32 xor masks
    q, g, p, l = sels.shape
    flip = invs.astype(_U32) * _U32(0xFFFFFFFF)
    acc = jnp.full((q, g, p, blk.shape[1]), 0xFFFFFFFF, _U32)
    for li in range(l):                       # static unroll: bucket L
        opnd = jnp.take(blk, sels[..., li], axis=0)       # (q, g, p, BN)
        acc = acc & (opnd ^ flip[..., li, None])
    acc = acc ^ post[..., None]               # De-Morgan OR-pass mask
    grp = acc[:, :, 0]
    for pi in range(1, p):
        grp = grp & acc[:, :, pi]
    out = grp[:, 0]
    for gi in range(1, g):
        out = out | grp[:, gi]
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def bulk_program(aug: jax.Array, sels: jax.Array, invs: jax.Array,
                 post: jax.Array, *, block_n: int = 512,
                 interpret: bool = True) -> jax.Array:
    """Whole-bucket bulk sweep: aug (M+1, Nw) uint32 augmented packed
    index (all-ones identity row at M), sels/invs (Q, G, P, L) selector/
    inversion arrays, post (Q, G, P) uint32 xor masks -> rows (Q, Nw).

    Result = OR over groups of [AND over passes of [(AND over literals of
    possibly-inverted gathered rows) ^ post]].  Tail bits past the logical
    record count are NOT masked here (the engine masks once per plan).
    The word axis pads to ``block_n`` with zero words — padded selector
    gathers read zeros and the extra columns are sliced off.
    """
    m1, nw = aug.shape
    q = sels.shape[0]
    nwp = -(-nw // block_n) * block_n
    augp = jnp.pad(aug.astype(_U32), ((0, 0), (0, nwp - nw)))
    grid = (nwp // block_n,)
    rows = pl.pallas_call(
        _bulk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),            # sels
            pl.BlockSpec(memory_space=pl.ANY),            # invs
            pl.BlockSpec(memory_space=pl.ANY),            # post
            pl.BlockSpec((m1, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((q, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, nwp), _U32),
        interpret=interpret,
    )(sels.astype(jnp.int32), invs.astype(jnp.int32), post.astype(_U32),
      augp)
    return rows[:, :nw]
