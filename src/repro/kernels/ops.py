"""Public entry points for the BIC Pallas kernels.

These wrappers accept arbitrary shapes (padding to kernel tile multiples),
pick sane block sizes, and auto-select interpret mode: on CPU the kernels
run through the Pallas interpreter (bit-exact, used by the test suite); on
TPU they compile to Mosaic.  ``ref.py`` holds the pure-jnp oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bit_transpose as _bt
from repro.kernels import bitmap_ops as _bq
from repro.kernels import cam_match as _cm
from repro.kernels import ref
# The canonical padding/sentinel policy lives with the packing conventions
# in ref.py; these wrappers only add kernel-specific block alignment.
from repro.kernels.ref import PACK, pad_keys, pad_records
from repro.kernels.ref import round_up as _round_up


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_block(total: int, preferred: int, multiple: int) -> int:
    """Largest divisor-friendly block: min(preferred, total), multiple-aligned."""
    b = min(preferred, total)
    b = max(multiple, b - (b % multiple))
    while total % b:
        b -= multiple
    return b


def cam_match(records: jax.Array, keys: jax.Array, *,
              interpret: bool | None = None) -> jax.Array:
    """records (N, W) int, keys (M,) int -> packed (N, ceil(M/32)) uint32.

    Pads N to a block multiple and M to 32; padded records use a sentinel
    value no real key can match, padded keys match nothing by construction
    (sentinel differs from the record pad sentinel).
    """
    if interpret is None:
        interpret = not _on_tpu()
    N, W = records.shape
    (M,) = keys.shape
    Mp = _round_up(M, PACK)
    block_m = _pick_block(Mp, 1024, PACK)
    block_n = _pick_block(_round_up(N, 8), 256, 8)
    Np = _round_up(N, block_n)
    rec = pad_records(records, Np)
    ks = pad_keys(keys, Mp)
    out = _cm.cam_match(rec, ks, block_n=block_n, block_m=block_m,
                        interpret=interpret)
    return out[:N]


def transpose(packed: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Packed (R, Cw) uint32 -> (Cw*32, ceil(R/32)) uint32 (zero-padded R)."""
    if interpret is None:
        interpret = not _on_tpu()
    R, Cw = packed.shape
    Rp = _round_up(R, PACK)
    block_c = _pick_block(Cw, 64, 1)
    x = jnp.pad(packed.astype(jnp.uint32), ((0, Rp - R), (0, 0)))
    return _bt.bit_transpose(x, block_c=block_c, interpret=interpret)


def query(rows: jax.Array, invert: jax.Array, *,
          interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused AND_k (invert_k ? ~row_k : row_k) + popcount over packed rows.

    rows (K, Nw) uint32.  NOTE: inverted rows make the *padding* words all-1s;
    we therefore mask padded words back to zero before the popcount by
    padding every row with 0 and additionally ANDing an all-ones literal row
    is unnecessary — instead we pad with a non-inverted all-zero row, which
    forces padded result words to 0 regardless of inversions.
    """
    if interpret is None:
        interpret = not _on_tpu()
    K, Nw = rows.shape
    block_n = _pick_block(_round_up(Nw, 8), 2048, 8)
    Nwp = _round_up(Nw, block_n)
    pad_cols = Nwp - Nw
    r = jnp.pad(rows.astype(jnp.uint32), ((0, 0), (0, pad_cols)))
    inv = invert.astype(jnp.int32)
    if pad_cols and bool(K):
        # Guard: if every operand is inverted, padded words become all-ones.
        # Append one non-inverted row that is all-ones in the real region and
        # zero in the pad, restoring correctness without branching.
        guard = jnp.concatenate([
            jnp.full((1, Nw), 0xFFFFFFFF, dtype=jnp.uint32),
            jnp.zeros((1, pad_cols), dtype=jnp.uint32)], axis=1)
        r = jnp.concatenate([r, guard], axis=0)
        inv = jnp.concatenate([inv, jnp.zeros((1,), jnp.int32)])
    result, count = _bq.bitmap_query(r, inv, block_n=block_n,
                                     interpret=interpret)
    return result[:Nw], count


def create_index(records: jax.Array, keys: jax.Array, *,
                 interpret: bool | None = None) -> jax.Array:
    """Full BIC pipeline (CAM match -> buffer -> TM transpose).

    records (N, W), keys (M,) -> key-major packed bitmap (M, ceil(N/32)).
    Matches ``ref.create_index`` for 32-aligned shapes and is the kernel
    realization of Fig. 3 of the paper.
    """
    record_major = cam_match(records, keys, interpret=interpret)  # (N, Mw)
    key_major = transpose(record_major, interpret=interpret)      # (Mw*32, ceil(N/32))
    return key_major[: keys.shape[0]]


__all__ = ["cam_match", "transpose", "query", "create_index", "ref"]
