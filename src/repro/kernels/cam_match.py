"""Pallas TPU kernel: CAM match stage of the BIC core.

The ASIC's CAM compares one key per cycle against a 32-word record held in
match-line registers.  On TPU the analogue of the parallel match lines is the
VPU lane grid: we tile BN records x BM keys into VMEM, broadcast each key
across lanes and OR-reduce the per-word equality over the record-word axis.
Match bits never leave VMEM unpacked — they are packed 32-per-uint32 before
the store, which is the TPU analogue of the paper's register-file buffer
(and cuts HBM write traffic by 32x).

Block shapes: records (BN, W) int32, keys (BM,) int32 -> out (BN, BM/32) u32.
BM is a multiple of 32; the lane dim of the output block is BM/32 so BM=4096
gives a 128-lane-aligned store.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 32
_U32 = jnp.uint32


def _cam_match_kernel(records_ref, keys_ref, out_ref, *, block_m: int):
    """One (BN records) x (BM keys) tile."""
    records = records_ref[...]                       # (BN, W) int32
    keys = keys_ref[...]                             # (BM,)  int32
    bn, w = records.shape

    # (BN, BM) match matrix: OR over the record-word axis of per-word equality.
    # Loop over W (small: 32 in the paper) to keep the VMEM working set at
    # BN x BM bits rather than BN x BM x W.
    def body(i, acc):
        word = jax.lax.dynamic_slice_in_dim(records, i, 1, axis=1)  # (BN, 1)
        return acc | (word == keys[None, :])

    match = jax.lax.fori_loop(
        0, w, body, jnp.zeros((bn, block_m), dtype=jnp.bool_))

    # Pack along the key axis, LSB-first: (BN, BM/32) uint32.
    m = match.astype(_U32).reshape(bn, block_m // PACK, PACK)
    weights = (_U32(1) << jnp.arange(PACK, dtype=_U32))
    out_ref[...] = (m * weights[None, None, :]).sum(axis=-1).astype(_U32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def cam_match(records: jax.Array, keys: jax.Array, *,
              block_n: int = 256, block_m: int = 1024,
              interpret: bool = True) -> jax.Array:
    """records (N, W) int32, keys (M,) int32 -> packed (N, M/32) uint32.

    N % block_n == 0, M % block_m == 0, block_m % 32 == 0 (wrappers in
    ops.py pad arbitrary shapes).
    """
    N, W = records.shape
    (M,) = keys.shape
    assert M % block_m == 0 and N % block_n == 0 and block_m % PACK == 0

    grid = (N // block_n, M // block_m)
    return pl.pallas_call(
        functools.partial(_cam_match_kernel, block_m=block_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, W), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m // PACK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M // PACK), _U32),
        interpret=interpret,
    )(records.astype(jnp.int32), keys.astype(jnp.int32))
