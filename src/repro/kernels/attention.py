"""Pallas TPU kernel: fused flash-attention forward (the LM hot-spot).

The pure-JAX chunked attention in models/flash.py is the portable path used
by the dry-run; this kernel is the TPU runtime replacement for the forward
pass: one (q-block × kv-block) tile per grid step, online-softmax state in
VMEM scratch, output written on the last kv block.  The TPU grid iterates
the trailing dimension sequentially, which is exactly the kv-streaming
order flash attention wants; MXU-aligned block shapes (multiples of 128)
are chosen by the ops.py wrapper.

Validated in interpret mode against models/flash.py (see tests).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, d_ref, *,
                      causal: bool, scale: float, bq: int, bk: int,
                      nk: int, seq_len: int):
    i = pl.program_id(1)              # q block
    j = pl.program_id(2)              # kv block (sequential, innermost)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jnp.dot(q, k.T)                               # (bq, bk) on the MXU

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < seq_len
    if causal:
        ok &= k_pos <= q_pos
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    d_ref[...] = d_ref[...] * corr + p.sum(axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(d_ref[...], 1e-37)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, block_q: int = 256,
                        block_k: int = 256, interpret: bool = True
                        ) -> jax.Array:
    """q/k/v: (BH, S, hd) with kv heads pre-broadcast.  Returns (BH, S, hd).

    S is padded to block multiples; hd should be a multiple of 128 on real
    TPU (any size in interpret mode)."""
    BH, S, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    bq = min(block_q, S)
    bk = min(block_k, S)
    nq, nk = -(-S // bq), -(-S // bk)
    pad_q, pad_k = nq * bq - S, nk * bk - S
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_flash_fwd_kernel, causal=causal, scale=scale,
                          bq=bq, bk=bk, nk=nk, seq_len=S),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :S]
