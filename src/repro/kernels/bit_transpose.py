"""Pallas TPU kernel: Transpose-Matrix (TM) stage of the BIC core.

The ASIC's TM swaps buffer rows into BI columns with a wire permutation.
With bits packed 32-per-uint32 (see cam_match.py) the TPU analogue is a
*bit-block* transpose: every aligned 32x32 bit tile is transposed in-register
with a 5-round butterfly (Hacker's Delight 7-7), then tiles are permuted.
No unpack to bytes ever happens, so VMEM/HBM traffic stays at 1 bit/bit.

The butterfly is vectorised across the lane axis: a (32, BC) uint32 block is
BC independent 32x32 bit tiles, and each round combines a row with its
partner row (index XOR j) via masked shifts.  Partner selection uses two
jnp.rolls + a select instead of a sublane gather, which lowers to cheap
sublane shifts on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 32
_U32 = jnp.uint32

# Butterfly rounds (plain ints — jnp constants are built inside the trace,
# Pallas rejects captured array consts): round j swaps the high-j bit-half of
# each "up" row (index bit j clear) with the low-j half of its partner.
_ROUNDS = (
    (16, 0x0000FFFF),
    (8, 0x00FF00FF),
    (4, 0x0F0F0F0F),
    (2, 0x33333333),
    (1, 0x55555555),
)


def _transpose32(x: jax.Array) -> jax.Array:
    """Transpose each 32x32 bit tile in a (32, BC) uint32 block (in-bit).

    LSB-first convention: output word b bit r == input word r bit b.
    """
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    for j, mi in _ROUNDS:
        m = jnp.uint32(mi)
        ju = jnp.uint32(j)
        is_up = (rows & j) == 0                       # row with index-bit j clear
        partner = jnp.where(is_up, jnp.roll(x, -j, axis=0), jnp.roll(x, j, axis=0))
        # up row k   : swap high(x[k]) with low(x[k+j]):  t=((x>>j)^p)&m ; x^=t<<j
        # down row k+j:                                   t=((p>>j)^x)&m ; x^=t
        t_up = ((x >> ju) ^ partner) & m
        t_dn = ((partner >> ju) ^ x) & m
        x = jnp.where(is_up, x ^ (t_up << ju), x ^ t_dn)
    return x


def _bit_transpose_kernel(in_ref, out_ref, *, block_c: int):
    x = in_ref[...]                                   # (32, BC) uint32
    y = _transpose32(x)                               # y[b, c] = out word for column c, bit b
    # Output row within the block is c*32 + b  ->  (BC, 32) -> (BC*32, 1).
    out_ref[...] = y.T.reshape(block_c * PACK, 1)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def bit_transpose(packed: jax.Array, *, block_c: int = 64,
                  interpret: bool = True) -> jax.Array:
    """Packed (R, C/32) uint32 -> packed (C, R/32) uint32.

    R % 32 == 0 and (C/32) % block_c == 0 (ops.py pads arbitrary shapes).
    """
    R, Cw = packed.shape
    assert R % PACK == 0 and Cw % block_c == 0
    grid = (R // PACK, Cw // block_c)
    return pl.pallas_call(
        functools.partial(_bit_transpose_kernel, block_c=block_c),
        grid=grid,
        in_specs=[pl.BlockSpec((PACK, block_c), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_c * PACK, 1), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((Cw * PACK, R // PACK), _U32),
        interpret=interpret,
    )(packed.astype(_U32))
