"""Production training launcher.

On a real cluster every host runs:

    python -m repro.launch.train --arch qwen2-7b --coordinator <addr> \
        --num-hosts 64 --host-id $SLURM_PROCID [--multi-pod]

and the launcher wires jax.distributed, builds the production mesh, shards
the step with the logical rules, and drives the fault-tolerant loop
(checkpoint cadence + deterministic restart + elastic re-shard on resize:
restores by name into whatever sharding the current topology implies).

On this CPU container it degrades gracefully: --demo runs a reduced config
on the single local device through the same code path.
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--coordinator", default=None,
                    help="host:port for jax.distributed (cluster mode)")
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--demo", action="store_true",
                    help="reduced config on local devices")
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_hosts,
                                   args.host_id)

    from jax.sharding import NamedSharding
    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import BitmapIndexedDataset, DataConfig
    from repro.engine.planner import key as _key
    from repro.launch.mesh import make_production_mesh, make_smoke_mesh
    from repro.models.model import abstract_params, init_params, param_logical
    from repro.optim.adamw import OptimConfig, init_opt_state
    from repro.parallel.sharding import logical_spec
    from repro.train.loop import LoopConfig, train_loop
    from repro.train.step import TrainConfig, make_train_step

    cfg = (get_smoke_config(args.arch) if args.demo else get_config(args.arch))
    mesh = (make_smoke_mesh() if args.demo or not args.coordinator
            else make_production_mesh(multi_pod=args.multi_pod))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      docs_per_shard=max(args.global_batch * 8, 256),
                      num_shards=2, num_attributes=32)
    ds = BitmapIndexedDataset(dcfg)

    def batches(start):
        return ds.batches(args.global_batch, where=_key(3), seed=0,
                          start_step=start)

    tcfg = TrainConfig(OptimConfig(warmup_steps=max(args.steps // 10, 1),
                                   decay_steps=args.steps),
                       accum_steps=args.accum)
    with jax.set_mesh(mesh):
        # The loop jits the step inside the mesh context; logical rules
        # shard params/grads/activations exactly as the dry-run proves.
        out = train_loop(cfg, tcfg,
                         LoopConfig(total_steps=args.steps,
                                    ckpt_dir=args.ckpt_dir),
                         batches)
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
