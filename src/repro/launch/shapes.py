"""Assigned input shapes and abstract input specs for every architecture.

Four shapes per LM arch (40 cells total):
  train_4k    : train_step,  seq 4096,  global_batch 256
  prefill_32k : prefill_step, seq 32768, global_batch 32
  decode_32k  : decode_step, KV cache 32768, global_batch 128
  long_500k   : decode_step, cache 524288, global_batch 1 — sub-quadratic
                archs only (SSM / hybrid); skipped for pure full-attention
                archs per the assignment (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import init_cache

I32 = jnp.int32
F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """None if the (arch, shape) cell runs; else why it is skipped."""
    if shape.name == "long_500k":
        if cfg.block == "attn" and (cfg.sliding_window is None
                                    or cfg.global_every is not None):
            return ("pure full-attention arch: 500k decode requires "
                    "sub-quadratic attention (assignment rule)")
        if cfg.enc_dec:
            return "enc-dec full attention: 500k decode out of scope"
    return None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract (ShapeDtypeStruct) model inputs for one cell — weak-type
    correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}
        if cfg.vlm:
            specs["visual"] = _sds((B, cfg.visual_prefix, cfg.d_model), F32)
            specs["mrope_positions"] = _sds((3, B, S), I32)
        if cfg.enc_dec:
            specs["frames"] = _sds((B, cfg.enc_frames, cfg.d_model), F32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _sds((B, S), I32)}
        if cfg.vlm:
            specs["visual"] = _sds((B, cfg.visual_prefix, cfg.d_model), F32)
            specs["mrope_positions"] = _sds((3, B, S), I32)
        if cfg.enc_dec:
            specs["frames"] = _sds((B, cfg.enc_frames, cfg.d_model), F32)
        return specs
    # decode: one new token against a seq_len cache
    specs = {"tokens": _sds((B, 1), I32),
             "cache": init_cache(cfg, B, S, abstract=True)}
    return specs


def demo_batch(cfg: ModelConfig, kind: str, batch: int, seq: int,
               key: jax.Array) -> dict:
    """Concrete small inputs for CPU smoke tests."""
    ks = jax.random.split(key, 4)
    toks = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size, I32)
    out: dict = {}
    if kind == "train":
        out["tokens"] = toks
        out["labels"] = jnp.roll(toks, -1, axis=1)
    elif kind == "prefill":
        out["tokens"] = toks
    else:
        out["tokens"] = toks[:, :1]
        out["cache"] = init_cache(cfg, batch, seq)
    if cfg.vlm and kind != "decode":
        out["visual"] = jax.random.normal(
            ks[1], (batch, cfg.visual_prefix, cfg.d_model), F32) * 0.02
        out["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(seq, dtype=I32)[None, None], (3, batch, seq))
    if cfg.enc_dec and kind != "decode":
        out["frames"] = jax.random.normal(
            ks[2], (batch, cfg.enc_frames, cfg.d_model), F32) * 0.02
    return out
