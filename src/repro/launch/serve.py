"""Production serving launcher: batched prefill + decode over the
production mesh, with bitmap-indexed request scheduling (see
examples/serve_lm.py for the single-host walkthrough).

    python -m repro.launch.serve --arch qwen2-7b --batch 8 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--demo", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.models.model import init_params
    from repro.serve.step import greedy_generate

    cfg = get_smoke_config(args.arch) if args.demo else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)))
    kw = {}
    if cfg.enc_dec:
        kw["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.enc_frames, cfg.d_model)) * 0.02, jnp.float32)
    t0 = time.time()
    out = greedy_generate(params, cfg, prompts, steps=args.steps, **kw)
    dt = time.time() - t0
    print(f"{out.size} tokens in {dt:.2f}s ({out.size/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
