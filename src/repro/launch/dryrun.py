"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell against the production meshes (16x16 single-pod, 2x16x16 multi-pod)
and record memory/cost/collective analysis for the roofline report.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --arch all [--multi-pod] [--out results/]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any other import: jax locks the device count on first init.

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, canonical, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, batch_specs, skip_reason
from repro.models.model import (abstract_params, cache_logical,
                                param_logical)
from repro.optim.adamw import OptimConfig, abstract_opt_state
from repro.parallel.sharding import logical_spec
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import TrainConfig, make_train_step

ACCUM_STEPS = int(os.environ.get("DRYRUN_ACCUM", "4"))
# Wider models need more microbatching to keep the per-device activation
# working set inside 16 GB HBM; capped so the per-device microbatch stays >= 1.
ACCUM_BY_ARCH = {"command_r_plus_104b": 16, "granite_20b": 8}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective traffic estimate from the compiled HLO: result-shape
    bytes (x2 for all-reduce: ring reduce+broadcast), operand bytes for
    reduce-scatter."""
    out = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s*=?\s*(all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", s)
        if not m:
            continue
        result_part, op = m.groups()
        if op == "reduce-scatter":
            args = s.split(op, 1)[1]
            b = _shape_bytes(args)
        else:
            b = _shape_bytes(result_part)
        if op == "all-reduce":
            b *= 2
        out[op] += b
    out["total"] = sum(out.values())
    return out


def build_step_and_specs(cfg, shape, mesh, variant: str = ""):
    """Returns (step_fn, args (abstract), in_shardings, out_shardings)."""
    import re as _re
    from repro.parallel import sharding as _sh
    if "serve_tp" in variant and shape.kind != "train":
        # serving: weights TP-only in bf16 (no FSDP gathers over 'data')
        rules = dict(_sh.DEFAULT_RULES)
        rules["fsdp"] = None
        _sh.set_rules(rules)
        params = {k: jax.ShapeDtypeStruct(v.shape, jnp.bfloat16)
                  for k, v in abstract_params(cfg).items()}
    elif "no_fsdp" in variant:
        # train with TP-only weights (DP-replicated): kills the per-micro
        # weight all-gathers; only viable when params fit TP-sharded.
        rules = dict(_sh.DEFAULT_RULES)
        rules["fsdp"] = None
        _sh.set_rules(rules)
        params = abstract_params(cfg)
    else:
        _sh.set_rules(dict(_sh.DEFAULT_RULES))
        params = abstract_params(cfg)
    p_logical = param_logical(cfg)
    p_spec = {k: logical_spec(params[k].shape, p_logical[k]) for k in params}
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec)
    batch = batch_specs(cfg, shape)

    def batch_spec(name, x):
        if name == "mrope_positions":
            return logical_spec(x.shape, (None, "batch", None))
        if name == "pos" or not x.ndim:
            return P()
        return logical_spec(x.shape, ("batch",) + (None,) * (x.ndim - 1))

    if shape.kind == "train":
        # Microbatching keeps per-device activations inside 16 GB HBM; the
        # cap ensures the per-device microbatch stays an integer >= 1.
        dp_size = 1
        for ax in ("pod", "data"):
            dp_size *= dict(mesh.shape).get(ax, 1)
        accum = ACCUM_BY_ARCH.get(cfg.name.replace("-", "_").replace(".", "_"),
                                  ACCUM_STEPS)
        m = _re.search(r"accum(\d+)", variant)
        if m:
            accum = int(m.group(1))
        accum = max(1, min(accum, shape.global_batch // dp_size))
        step = make_train_step(
            cfg, TrainConfig(OptimConfig(), accum_steps=accum))
        opt = abstract_opt_state(params, OptimConfig())
        o_spec = {"m": p_spec, "v": p_spec, "step": P()}
        o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_spec)
        b_shard = {k: NamedSharding(mesh, batch_spec(k, v))
                   for k, v in batch.items()}
        args = (params, opt, batch)
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard, None)
        return step, args, in_sh, out_sh, (0, 1)

    c_logical = cache_logical(cfg)

    def cache_spec_of(name, x):
        return logical_spec(x.shape, c_logical[name]) if name != "pos" else P()

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, max_len=shape.seq_len)
        b_shard = {k: NamedSharding(mesh, batch_spec(k, v))
                   for k, v in batch.items()}
        args = (params, batch)
        in_sh = (p_shard, b_shard)
        return step, args, in_sh, None, ()

    # decode: donate the cache (aliased in -> out, halves live memory)
    step = make_decode_step(cfg)
    cache = batch["cache"]
    c_shard = {k: NamedSharding(mesh, cache_spec_of(k, v))
               for k, v in cache.items()}
    b_shard = {"tokens": NamedSharding(mesh, batch_spec("tokens",
                                                        batch["tokens"])),
               "cache": c_shard}
    args = (params, batch)
    in_sh = (p_shard, b_shard)
    out_sh = (None, c_shard)
    return step, args, in_sh, out_sh, (1,)


def _compile_and_analyze(cfg, shape, mesh, variant: str = "") -> dict:
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_step_and_specs(
        cfg, shape, mesh, variant)
    kw = {"in_shardings": in_sh, "donate_argnums": donate}
    if out_sh is not None:
        kw["out_shardings"] = out_sh
    jitted = jax.jit(fn, **kw)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0) if cost else None,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else None,
        "collective_bytes": coll,
        "memory": {
            k: getattr(mem, k, None) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")} if mem else None,
    }


# Hillclimb variants (§Perf): applied as config overrides on top of the arch.
def _moe_ep(cfg):
    import dataclasses as _dc
    return _dc.replace(cfg, moe=_dc.replace(cfg.moe, ep_pad=True))


VARIANTS = {
    "block_skip": lambda cfg: _replace(cfg, flash_block_skip=True),
    "remat_dots": lambda cfg: _replace(cfg, remat="dots"),
    "no_remat": lambda cfg: _replace(cfg, remat="none"),
    "seq_sp": lambda cfg: _replace(cfg, seq_sharded=True),
    "ulysses": lambda cfg: _replace(cfg, ulysses_attn=True),
    "moe_ep": _moe_ep,
    # accumN: accumulation-step override, handled in build_step_and_specs
    "accum1": lambda cfg: cfg,
    "accum2": lambda cfg: cfg,
    "accum4": lambda cfg: cfg,
    "accum8": lambda cfg: cfg,
    # serve_tp: serving cells drop FSDP (weights TP-only, bf16) — no
    # per-layer weight gathers over 'data'; handled in build/run.
    "serve_tp": lambda cfg: cfg,
    "no_fsdp": lambda cfg: cfg,
}


def _replace(cfg, **kw):
    import dataclasses as _dc
    return _dc.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             with_l0: bool = True, variant: str = "") -> dict:
    """Compile one (arch, shape, mesh) cell.

    XLA's cost_analysis counts a while-loop body ONCE (trip counts are not
    applied), so a scanned layer stack under-reports flops/bytes by ~L.  We
    therefore also compile a num_layers=0 variant: the roofline report uses
    corrected = L0 + L * (full - L0), plus an analytic term for the
    attention chunk loops (see benchmarks/roofline.py).
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    for v in filter(None, variant.split(",")):
        cfg = VARIANTS[v](cfg)
    shape = SHAPES[shape_name]
    cell = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "variant": variant}
    reason = skip_reason(cfg, shape)
    if reason:
        cell["status"] = "skipped"
        cell["reason"] = reason
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    with jax.set_mesh(mesh):
        full = _compile_and_analyze(cfg, shape, mesh, variant)
        cell.update(full)
        if with_l0:
            cfg0 = _dc.replace(cfg, num_layers=0,
                               enc_layers=0 if cfg.enc_dec else cfg.enc_layers)
            try:
                cell["l0"] = _compile_and_analyze(cfg0, shape, mesh, variant)
            except Exception as e:  # noqa: BLE001
                cell["l0"] = {"error": f"{type(e).__name__}: {e}"}
    cell["status"] = "ok"
    cell["num_devices"] = int(mesh.devices.size)
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="",
                    help="comma-separated config overrides (see VARIANTS)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [canonical(args.arch)]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}-{shape_name}-{'mp' if mp else 'sp'}"
                if args.variant:
                    tag += "-" + args.variant.replace(",", "+")
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached", flush=True)
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    cell = run_cell(arch, shape_name, mp,
                                    variant=args.variant)
                except Exception as e:  # noqa: BLE001
                    cell = {"arch": arch, "shape": shape_name,
                            "mesh": "2x16x16" if mp else "16x16",
                            "variant": args.variant,
                            "status": "error", "error": f"{type(e).__name__}: {e}",
                            "traceback": traceback.format_exc()[-2000:]}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(cell, f, indent=2)
                print(f"[dryrun] {tag}: {cell['status']} "
                      f"(compile={cell.get('compile_s', '-')}s)", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
