"""Production mesh construction.  A function (not a module constant) so
importing never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.
"""
from __future__ import annotations

from repro import compat  # noqa: F401  (AxisType / make_mesh shims, jax 0.4.x)

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model); the pod axis is an
    outer data-parallel axis (DCN-linked) — see parallel/sharding.py."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh():
    """Whatever devices exist (CPU tests: 1 device) on a (data,) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
