"""Checkpointing + restart: the fault-tolerance substrate.

Design (single-host file backend standing in for a distributed blob store):
  * Serialization rides on :mod:`repro.store.format` — the same versioned,
    CRC-checksummed array-file container the bitmap segment store uses —
    so a torn or bit-flipped checkpoint raises ``CorruptFileError`` on
    restore instead of silently resuming from garbage.
  * Atomic writes — array files replace atomically and the step directory
    lands via tmp dir + rename, so a crash mid-save never corrupts the
    latest checkpoint (restart always finds a complete step).
  * The full training state is captured: params, optimizer moments, step,
    data-sampler state — restart is bit-deterministic.
  * ``CheckpointManager`` adds retention, periodic cadence, and a
    best-effort async mode (snapshot to host memory, write on a thread) so
    the TPU step loop is not blocked by I/O — the standard large-run trick.
  * Elastic restart: ``restore_checkpoint`` takes the *current* param tree
    (any sharding/topology); values are restored by name, so a job restarted
    on a different device count re-shards transparently under pjit.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

from repro.store import format as fmt


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(jax.device_get(tree))
    return out


def _unflatten_into(tree: Any, flat: dict[str, np.ndarray], prefix: str = ""):
    if isinstance(tree, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(tree)]
        return type(tree)(vals)
    return flat[prefix[:-1]]


def _write_step_dir(ckpt_dir: str, step: int, flat: dict) -> str:
    """The shared write path: checksummed array file (store substrate)
    inside a tmp dir, then an atomic dir rename."""
    tmp = os.path.join(ckpt_dir, f"tmp-{step}")
    final = os.path.join(ckpt_dir, f"step-{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    fmt.write_array_file(os.path.join(tmp, "arrays.bin"), flat,
                         meta={"step": step, "keys": sorted(flat)})
    os.replace(tmp, final)
    fmt.fsync_dir(ckpt_dir)
    return final


def save_checkpoint(ckpt_dir: str, step: int, state: dict) -> str:
    """Atomic: write to <dir>/tmp-<step>, fsync, rename to <dir>/step-<step>."""
    os.makedirs(ckpt_dir, exist_ok=True)
    return _write_step_dir(ckpt_dir, step, _flatten(state))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step-")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, state_like: dict,
                       step: int | None = None) -> tuple[dict, int]:
    """Restore by name into a tree shaped like ``state_like`` (values may be
    ShapeDtypeStructs or differently-sharded arrays — elastic restart)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step-{step:08d}")
    flat, _ = fmt.read_array_file(os.path.join(path, "arrays.bin"))
    return _unflatten_into(state_like, flat), step


class CheckpointManager:
    """Cadence + retention + async save."""

    def __init__(self, ckpt_dir: str, *, every_steps: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.ckpt_dir = ckpt_dir
        self.every_steps = every_steps
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def maybe_save(self, step: int, state: dict, *, force: bool = False):
        if not force and (step == 0 or step % self.every_steps):
            return
        self.wait()                       # one in-flight save at a time
        if os.path.exists(os.path.join(self.ckpt_dir, f"step-{step:08d}")):
            return                        # already saved (force after cadence)
        snapshot = _flatten(state)        # device -> host before returning

        def _write():
            _write_step_dir(self.ckpt_dir, step, snapshot)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("-")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step-"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step-{s:08d}"),
                          ignore_errors=True)
