"""Streaming multi-core runtime: sharded index builds fused with the elastic
energy model, and incremental append into existing packed indexes.

The paper's Fig. 4 deployment feeds Z independent BIC cores from external
memory and powers idle cores down.  The seed simulated the energy side
(``ElasticScheduler``) separately from the execution side
(``multicore_create_index``); this module fuses them:

  * :func:`multicore_create_index` — shard_map dispatch of the full BIC
    pipeline, one engine backend per device, no cross-core communication
    during indexing (moved here from ``core/elastic.py``; that module keeps
    a thin compatibility wrapper).
  * :class:`StreamingIndexer` — incremental append of record blocks into an
    existing packed index with NO full rebuild: each block is indexed alone
    and bit-spliced onto the packed tail (a shift/carry merge when the
    current record count is not 32-aligned).  The splice runs **jitted
    against a geometrically grown capacity buffer** with the record count
    traced, so steady-state appends of a given block size reuse ONE trace
    instead of re-dispatching an unjitted splice per block;
    :meth:`StreamingIndexer.append_many` goes further and indexes a whole
    batch of blocks in one backend dispatch, folding all the splices in a
    single jitted ``lax.scan``.
  * :class:`MulticoreRuntime` — drives ticks of a workload stream through
    the sharded build AND integrates active/standby energy with the
    calibrated silicon model.  ``run_tick(queries=...)`` additionally serves
    a batch of predicate trees against the freshly built tick index through
    :mod:`repro.engine.batch`.  Every tick's dispatch is wall-clock
    measured and folded into a throughput EWMA; with
    ``calibrate_energy=True`` the elastic model charges active energy over
    the *measured* busy time and re-derives its per-core batch time from
    the measured MB/s — joules track the actual device, not only the paper
    clock.  With ``store_dir=...`` the runtime additionally maintains one
    durable per-core index (``repro.store.SegmentStore`` per core):
    per-batch block indexes splice into per-core streaming indexers, spill
    to segments at the flush threshold, and a restarted runtime recovers
    them bit-identically from manifest + WAL.
  * ``StreamingIndexer.attach_store`` / ``spill`` / ``restore`` — the
    durability hooks: raw blocks are WAL-logged *before* the in-memory
    splice, the tail past the durable prefix flushes as an immutable
    segment (extracted at its unaligned offset by
    :func:`repro.engine.policy.extract_packed`), and recovery replays
    committed segments + surviving WAL blocks into a bit-identical index.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
import time
from typing import Callable, Iterable, Sequence

from repro import compat  # noqa: F401  (jax.shard_map / mesh shims on 0.4.x)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.engine import backends, batch as engine_batch, policy
from repro.core.bic import BICConfig, PaperConfig
from repro.core.elastic import ElasticScheduler, EnergyReport, PowerState
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace
from repro.obs.energy import EnergyLedger

_RECORDS_INDEXED = _obs_metrics.GLOBAL.counter(
    "engine_records_indexed_total",
    "records appended through streaming indexers")


# ------------------------------------------------------------- sharded build
def multicore_create_index(records: jax.Array, keys: jax.Array,
                           mesh: Mesh, axis: str = "data",
                           *, backend: str = "auto") -> jax.Array:
    """records (Z*B, N, W) sharded over ``axis``; keys replicated.

    Each device runs the full BIC pipeline on its local batches — the
    paper's Fig. 4 dataflow (no cross-core communication during indexing;
    results are resharded only on readout).  Batch counts that do not
    divide the mesh axis are zero-padded for dispatch and sliced off the
    result.  Returns (Z*B, M, ceil(N/32)).
    """
    be = backends.get_backend(backend)
    zb = records.shape[0]
    z = dict(mesh.shape)[axis]
    pad = -zb % z
    if pad:
        records = jnp.pad(records, ((0, pad), (0, 0), (0, 0)))

    def per_core(rec_block, keys_rep):
        return jax.vmap(lambda rec: be.create_index(rec, keys_rep))(rec_block)

    fn = jax.shard_map(
        per_core, mesh=mesh,
        in_specs=(P(axis, None, None), P()),
        out_specs=P(axis, None, None),
        check_vma=False)   # pallas_call has no replication rule on jax 0.4.x
    out = fn(records, keys)
    return out[:zb] if pad else out


# -------------------------------------------------------- incremental append
_U32 = jnp.uint32


# The shift/carry merge itself lives in :func:`repro.engine.policy
# .splice_packed` (shared with the segment-parallel OR-fold in
# ``engine.batch``); this module owns the jitted entry points.
_splice = jax.jit(policy.splice_packed)


@functools.partial(jax.jit, static_argnames="block_records")
def _fold_scan(buf, num_records0, blocks, block_records):
    """Fold B uniform block splices into the capacity buffer in one trace."""
    def body(carry, block):
        cbuf, n = carry
        return (policy.splice_packed(cbuf, n, block), n + block_records), None

    carry, _ = jax.lax.scan(body, (buf, num_records0), blocks)
    return carry


@functools.lru_cache(maxsize=8)
def _vmapped_create(backend_name: str):
    """One jitted vmapped create_index per backend: a whole batch of record
    blocks indexes in a single dispatch."""
    be = backends.get_backend(backend_name)
    return jax.jit(jax.vmap(be.create_index, in_axes=(0, None)))


def splice_cache_size() -> int:
    """Number of compiled splice traces (exposed for tests/benchmarks: a
    steady-state append stream must NOT grow this per block)."""
    return _splice._cache_size()


def append_packed(packed: jax.Array, num_records: int,
                  block: jax.Array, block_records: int) -> jax.Array:
    """Bit-splice a freshly indexed ``block`` (M, ceil(n'/32)) onto a packed
    index (M, ceil(n/32)) holding ``num_records`` records.

    Pad bits past each logical record count must be zero (every engine
    backend guarantees this).  O(words) jitted shift/carry merge — no
    unpack; the trace is cached by word-count shape only (the record count
    enters traced).
    """
    total_words = policy.num_words(num_records + block_records)
    slack = block.shape[1] + 1           # splice window past the tail word
    buf = jnp.pad(packed, ((0, 0), (0, slack)))
    return _splice(buf, jnp.int32(num_records), block)[:, :total_words]


class StreamingIndexer:
    """Grow one key-major index record-block by record-block.

    ``append`` indexes only the incoming block and splices it in; the live
    index is always available via ``.index`` (bit-identical to a
    from-scratch rebuild over all records seen so far).  The packed words
    live in a geometrically doubled capacity buffer so the jitted splice
    keeps one trace per block size instead of re-tracing as the index
    grows; size ``capacity_words`` for the expected stream to avoid growth
    retraces entirely.

    With a :class:`repro.store.SegmentStore` attached the index outlives
    the process: every incoming block is WAL-logged *before* the in-memory
    splice, the tail past the store's durable prefix flushes as an
    immutable segment once ``flush_records`` accumulate (or on an explicit
    :meth:`spill`), and :meth:`restore` rebuilds a bit-identical live
    indexer from manifest + WAL after a crash.
    """

    def __init__(self, keys: jax.Array, *, backend: str = "auto",
                 capacity_words: int = 16):
        self.keys = jnp.asarray(keys, jnp.int32)
        self.backend = backends.resolve_backend(backend)
        self._cap = max(int(capacity_words), 2)
        self._buf = jnp.zeros((self.keys.shape[0], self._cap), jnp.uint32)
        self._num_records = 0
        self._store = None
        self._flush_records: int | None = None
        self._last_tick = -1
        self._last_tick_blocks = 0
        # guards the (WAL log, buf, num_records, tick) commit point of an
        # append against concurrent snapshot readers (background spill /
        # serving view).  Held only for the splice DISPATCH and field
        # assignments — never for device work, segment writes, or merges,
        # so appends don't wait on maintenance and vice versa.
        self._mu = threading.RLock()
        # background-maintenance tap: when set, a reached flush threshold
        # calls the hook (enqueue work) instead of spilling synchronously
        # on the append path
        self._spill_hook: Callable[[], None] | None = None

    @property
    def num_records(self) -> int:
        return self._num_records

    @property
    def store(self) -> "SegmentStore":
        return self._store

    @property
    def last_tick(self) -> int:
        """Highest ``tick`` stamp this index has absorbed (-1 when ticks
        are untracked).  Survives spill/crash/restore."""
        return self._last_tick

    def absorbed_blocks(self, tick: int) -> int:
        """How many blocks of (monotone) workload tick ``tick`` this index
        has already absorbed — the replay-idempotence watermark a driver
        uses to skip the already-applied prefix of an in-flight tick.
        Returns -1 when ``tick`` is below the watermark entirely (every
        block of it was absorbed before a later tick started)."""
        if tick == self._last_tick:
            return self._last_tick_blocks
        return 0 if tick > self._last_tick else -1

    def _stamp_tick(self, tick: int | None) -> None:
        if tick is None:
            return
        if tick > self._last_tick:
            self._last_tick, self._last_tick_blocks = tick, 1
        elif tick == self._last_tick:
            self._last_tick_blocks += 1

    def _grow(self, need_words: int) -> None:
        if need_words > self._cap:
            new = self._cap
            while new < need_words:
                new *= 2
            self._buf = jnp.pad(self._buf, ((0, 0), (0, new - self._cap)))
            self._cap = new

    # ----------------------------------------------------------- durability
    def attach_store(self, store: "SegmentStore", *,
                     flush_records: int | None = 4096) -> None:
        """Make this index durable: WAL-log every future append into
        ``store`` and auto-:meth:`spill` a segment whenever the in-memory
        tail reaches ``flush_records`` records (None = manual spills only).

        The store must not be ahead of the indexer — to resume from a
        non-empty store, use :meth:`restore` instead."""
        store.ensure_keys(np.asarray(jax.device_get(self.keys)))
        wal_tail = store.replay_wal()
        if store.durable_records > self._num_records or wal_tail:
            # ahead in segments OR carrying an unflushed WAL tail: a fresh
            # attach would log conflicting blocks at already-claimed
            # offsets and make the store unrecoverable
            raise ValueError(
                f"store already holds {store.durable_records} durable "
                f"records and {len(wal_tail)} WAL tail blocks; "
                "use StreamingIndexer.restore to resume from a store")
        self._store = store
        self._flush_records = flush_records
        if self._num_records > store.durable_records:
            # records indexed before the attach were never WAL-logged —
            # flush them now so recovery has no gap below the WAL floor
            self.spill()

    def _flush_snapshot(self):
        """Consistent (tail, count, start, tick watermark) snapshot of the
        flushable suffix — the indexer mutex pins (buf, count, watermark)
        together so a snapshot taken mid-append can never pair a new
        buffer with an old count (or a watermark that over/under-claims
        the flushed blocks)."""
        with self._mu:
            start = self._store.durable_records
            count = self._num_records - start
            if count <= 0:
                return None
            buf = self._buf
            wm = (self._last_tick, self._last_tick_blocks)
        # extraction runs OUTSIDE the mutex: the captured buffer is a
        # functional jax array, and extract_packed can pay a first-sight
        # jit compile — holding the lock here would stall every
        # concurrent append behind the background spill
        return policy.extract_packed(buf, start, count), count, start, wm

    def spill(self) -> None:
        """Flush the in-memory tail past the store's durable prefix as one
        immutable segment (atomic manifest commit + WAL rotation).  A
        no-op when nothing new has arrived since the last spill."""
        if self._store is None:
            raise RuntimeError("no store attached (see attach_store)")
        snap = self._flush_snapshot()
        if snap is None:
            return
        tail, count, start, wm = snap
        with _obs_trace.maybe_span("spill", records=count):
            self._store.write_segment(
                np.asarray(jax.device_get(tail)), count, start,
                tick_watermark=wm)

    # ------------------------------------------------- background spill
    def set_spill_hook(self, hook: Callable[[], None] | None) -> None:
        """Route threshold-triggered flushes through ``hook()`` (e.g. a
        maintenance executor's enqueue) instead of spilling synchronously
        on the append path; ``None`` restores synchronous spills.  The
        hook runs on the appending thread and must only enqueue."""
        self._spill_hook = hook

    def pending_flush_records(self) -> int:
        """Records in memory past the store's durable prefix (0 when no
        store is attached) — what a background flush would spill."""
        with self._mu:
            if self._store is None:
                return 0
            return self._num_records - self._store.durable_records

    def prepare_spill(self):
        """Background-flush phase one: snapshot the flushable tail and
        write its segment FILE (the slow part — runs on a maintenance
        thread; concurrent appends keep streaming into the WAL).  Returns
        an opaque token for :meth:`commit_spill`, or None when nothing
        needs flushing.  Crash before the commit: the file is an orphan,
        the WAL still holds every block — recovery is unaffected."""
        if self._store is None:
            raise RuntimeError("no store attached (see attach_store)")
        snap = self._flush_snapshot()
        if snap is None:
            return None
        tail, count, start, wm = snap
        with _obs_trace.maybe_span("spill.prepare", records=count):
            meta = self._store.prepare_segment(
                np.asarray(jax.device_get(tail)), count, start)
        return meta, wm

    def commit_spill(self, token) -> None:
        """Background-flush phase two: atomic manifest swap making the
        prepared segment live.  Blocks appended during phase one are
        carried into the fresh WAL generation by the store before the
        swap (see ``SegmentStore._commit``)."""
        meta, wm = token
        with _obs_trace.maybe_span("spill.commit", file=meta.file):
            self._store.commit_segment(meta, tick_watermark=wm)

    def abort_spill(self, token) -> None:
        """Abandon a prepared spill (its orphan file becomes gc fodder)."""
        self._store.abort_segment(token[0])

    def _maybe_spill(self) -> None:
        if (self._store is not None and self._flush_records is not None
                and (self._num_records - self._store.durable_records
                     >= self._flush_records)):
            if self._spill_hook is not None:
                self._spill_hook()
            else:
                self.spill()

    def _log_block(self, records: jax.Array, start: int,
                   tick: int | None = None) -> None:
        if self._store is not None:
            self._store.log_block(np.asarray(jax.device_get(records)),
                                  start, tick)

    @classmethod
    def restore(cls, store, keys, *, backend: str = "auto",
                capacity_words: int = 16,
                flush_records: int | None = 4096) -> "StreamingIndexer":
        """Crash recovery: load the committed segments, re-index the
        surviving WAL blocks (backends are pure functions of their
        inputs), and splice them on — the result is bit-identical to the
        pre-crash in-memory index, with the store re-attached for further
        appends."""
        si = cls(keys, backend=backend, capacity_words=capacity_words)
        store.ensure_keys(np.asarray(jax.device_get(si.keys)))
        m = store.manifest
        si._last_tick = m.last_tick
        si._last_tick_blocks = m.last_tick_blocks
        packed, n = store.load_packed()
        if n:
            si._grow(packed.shape[1] + 1)
            si._buf = si._buf.at[:, :packed.shape[1]].set(jnp.asarray(packed))
            si._num_records = n
        be = backends.get_backend(si.backend)
        for start, rec, tick in store.replay_wal():
            if start != si._num_records:
                raise ValueError(
                    f"WAL block starts at record {start} but the recovered "
                    f"stream position is {si._num_records}")
            block = be.create_index(jnp.asarray(rec), si.keys)
            si._grow(start // policy.PACK + block.shape[1] + 1)
            si._buf = _splice(si._buf, jnp.int32(start), block)
            si._num_records += rec.shape[0]
            si._stamp_tick(tick)
        # attach AFTER replay: replayed blocks are already in the WAL
        si._store = store
        si._flush_records = flush_records
        return si

    # --------------------------------------------------------------- append
    def append(self, records: jax.Array) -> policy.BitmapIndex:
        """Index a (N', W) record block and splice it in; returns the
        updated live index.  An empty block is a no-op (no dispatch)."""
        n_new = int(records.shape[0])
        if n_new == 0:
            return self.index
        block = backends.get_backend(self.backend).create_index(
            records, self.keys)
        return self.append_indexed(records, block)

    def append_indexed(self, records: jax.Array, block: jax.Array, *,
                       tick: int | None = None) -> policy.BitmapIndex:
        """Splice in a block whose (M, ceil(N'/32)) index ``block`` was
        already built elsewhere (e.g. by a sharded tick dispatch) — the raw
        ``records`` are still WAL-logged so recovery can re-index them.
        ``tick`` stamps the block for replay idempotence (see
        :attr:`last_tick`)."""
        n_new = int(records.shape[0])
        if n_new == 0:
            return self.index
        with self._mu:     # log + splice + count + tick commit atomically
            self._log_block(records, self._num_records, tick)
            self._grow(self._num_records // policy.PACK
                       + block.shape[1] + 1)
            self._buf = _splice(self._buf, jnp.int32(self._num_records),
                                block)
            self._num_records += n_new
            self._stamp_tick(tick)
        _RECORDS_INDEXED.add(n_new)
        self._maybe_spill()
        return self.index

    def append_many(self, records: jax.Array, *, mesh: Mesh | None = None,
                    axis: str = "data") -> policy.BitmapIndex:
        """Append a batch of uniform blocks (B, N', W) in two dispatches:
        one vmapped index build (sharded over ``mesh`` when given) and one
        ``lax.scan`` that folds all B splices."""
        b, n_blk = int(records.shape[0]), int(records.shape[1])
        if b == 0 or n_blk == 0:
            return self.index
        if mesh is not None:
            blocks = multicore_create_index(records, self.keys, mesh, axis,
                                            backend=self.backend)
        else:
            blocks = _vmapped_create(self.backend)(records, self.keys)
        # the device readback depends on nothing the mutex guards — keep
        # snapshot readers (serving views) unblocked during the transfer
        host = (np.asarray(jax.device_get(records))
                if self._store is not None else None)
        with self._mu:     # log + fold + count commit atomically
            if host is not None:
                for i in range(b):
                    self._store.log_block(host[i],
                                          self._num_records + i * n_blk)
            total = self._num_records + b * n_blk
            self._grow(total // policy.PACK + blocks.shape[2] + 1)
            self._buf, _ = _fold_scan(self._buf,
                                      jnp.int32(self._num_records),
                                      blocks, n_blk)
            self._num_records = total
        _RECORDS_INDEXED.add(b * n_blk)
        self._maybe_spill()
        return self.index

    def view(self) -> tuple[jax.Array, int]:
        """A consistent (capacity buffer, record count) pair even under a
        concurrent append — the serving snapshot :mod:`repro.db` caches
        on.  The buffer is a functional jax array, so the pair stays a
        bit-exact point-in-time view of the stream forever."""
        with self._mu:
            return self._buf, self._num_records

    @property
    def index(self) -> policy.BitmapIndex:
        buf, n = self.view()
        return policy.BitmapIndex(buf[:, :policy.num_words(n)], n)


def fold_block_indexes(blocks: jax.Array,
                       block_records: int) -> policy.BitmapIndex:
    """Fold per-block indexes (B, M, BW) of uniform ``block_records``-record
    blocks into ONE packed index over the concatenated records (a single
    scanned splice dispatch) — e.g. the output of
    :func:`multicore_create_index` becoming a servable tick index."""
    b, m, bw = blocks.shape
    total = b * block_records
    buf = jnp.zeros((m, total // policy.PACK + bw + 1), jnp.uint32)
    (buf, _) = _fold_scan(buf, jnp.int32(0), blocks, block_records)
    return policy.BitmapIndex(buf[:, :policy.num_words(total)], total)


# ------------------------------------------------- fused execution + energy
@dataclasses.dataclass
class TickResult:
    indexes: jax.Array | None   # (B_t, M, ceil(N/32)); None on an idle tick
    active_cores: int
    report: EnergyReport
    query_rows: jax.Array | None = None     # (Q, ceil(B_t*N/32)) uint32
    query_counts: jax.Array | None = None   # (Q,) int32
    measured_seconds: float = 0.0           # wall-clock of the tick dispatch
    # record MB/s of THIS dispatch, in PAPER units: one 8-bit record word
    # = one byte (matching bic_create_cpu and the elastic cycle model),
    # regardless of the int32 container the words travel in
    measured_mbps: float = 0.0


class MulticoreRuntime:
    """Sharded indexing with elastic energy accounting in one place.

    Each call to :meth:`run_tick` dispatches one tick's record batches over
    the mesh (reusing :func:`multicore_create_index`) and charges the
    elastic scheduler's calibrated power model for the cores the *policy*
    would activate (``cores_needed``); idle cores accrue standby energy
    (CG / CG+RBB).

    Every dispatch is wall-clock measured and folded into a throughput
    EWMA (``measured_mbps``).  By default joules still follow the
    paper-clock model; with ``calibrate_energy=True`` the measured busy
    time replaces the model's busy time for active energy AND the
    scheduler's per-core batch time is re-derived from the measured MB/s
    (``ElasticScheduler.calibrate``), so both joules and the activation
    policy track the device actually running the dispatch.

    With ``store_dir=...`` the runtime keeps one durable index per core:
    tick batches are assigned round-robin to per-core
    :class:`StreamingIndexer`\\ s (splicing the already-built per-batch
    block indexes — no re-indexing), each backed by its own
    ``repro.store.SegmentStore`` under ``<store_dir>/core-<z>`` with
    WAL-before-splice durability and ``flush_records`` segment spills.  A
    restarted runtime pointed at the same directory recovers every
    per-core index bit-identically (manifest + WAL replay).
    """

    def __init__(self, mesh: Mesh, axis: str = "data",
                 cfg: BICConfig = PaperConfig,
                 state: PowerState = PowerState(), *,
                 backend: str = "auto", calibrate_energy: bool = False,
                 store_dir: str | None = None, flush_records: int = 4096,
                 throughput_ewma: float = 0.5):
        self.mesh = mesh
        self.axis = axis
        self.backend = backends.resolve_backend(backend)
        self.num_cores = dict(mesh.shape)[axis]
        self.scheduler = ElasticScheduler(self.num_cores, cfg, state)
        self.report = EnergyReport()
        # joule ledger on the same operating points: tick reports feed
        # it so ingest energy rolls up to pJ-per-indexed-bit
        self.ledger = EnergyLedger(self.scheduler)
        self.calibrate_energy = calibrate_energy
        self.store_dir = store_dir
        self.flush_records = flush_records
        self.throughput_ewma = throughput_ewma
        self.measured_mbps = 0.0            # EWMA over non-idle ticks
        self._core_si: list[StreamingIndexer] | None = None

    def bind_ledger(self, ledger: EnergyLedger) -> None:
        """Rebind tick charging to a shared ledger (a serving stack's —
        see :meth:`repro.serve.service.BitmapService.attach_runtime`):
        indexing and serving then roll up into ONE energy report, and
        the shared ledger's attributed+unattributed invariant still
        holds because every tick's joules enter through its
        ``charge_report``."""
        self.ledger = ledger

    # ---------------------------------------------------- per-core indexes
    def core_indexers(self, keys: jax.Array) -> list[StreamingIndexer]:
        """The per-core durable indexers (created, or recovered from the
        store, on first use).  Requires ``store_dir``; every call must use
        the SAME keys the indexers were created with."""
        if self.store_dir is None:
            raise RuntimeError("MulticoreRuntime has no store_dir")
        if self._core_si is not None:
            cached = self._core_si[0].keys
            keys32 = jnp.asarray(keys, jnp.int32)
            if (cached.shape != keys32.shape
                    or not bool(jnp.all(cached == keys32))):
                raise ValueError(
                    "per-core indexers were created with a different key "
                    "set; a runtime persists ONE key set per store_dir")
        if self._core_si is None:
            from repro.store import SegmentStore
            sis = []
            for z in range(self.num_cores):
                st = SegmentStore(os.path.join(self.store_dir, f"core-{z}"))
                if st.durable_records or st.replay_wal():
                    si = StreamingIndexer.restore(
                        st, keys, backend=self.backend,
                        flush_records=self.flush_records)
                else:
                    si = StreamingIndexer(keys, backend=self.backend)
                    si.attach_store(st, flush_records=self.flush_records)
                sis.append(si)
            self._core_si = sis
        return self._core_si

    def core_indexes(self, keys: jax.Array) -> list[policy.BitmapIndex]:
        """The live per-core cumulative indexes (recovering from the store
        first if this runtime has not ticked yet)."""
        return [si.index for si in self.core_indexers(keys)]

    def checkpoint(self) -> None:
        """Force-spill every per-core in-memory tail to its segment store
        (e.g. before a planned shutdown)."""
        for si in self._core_si or ():
            si.spill()

    def run_tick(self, records: jax.Array | None, keys: jax.Array,
                 tick_seconds: float, *,
                 queries: Sequence | None = None,
                 tick_id: int | None = None) -> TickResult:
        """records (B_t, N, W) for this tick (None = idle tick).

        ``queries`` — an optional batch of predicate trees (or pre-built
        plans) served against the index of THIS tick's records: the
        per-core block indexes fold into one packed tick index (scanned
        splice) and the whole batch executes through
        :func:`repro.engine.batch.execute_many` in a few bucketed
        dispatches.  Results land in ``TickResult.query_rows/query_counts``
        in query order.

        ``tick_id`` (monotone) makes the durable per-core appends
        **idempotent under replay**: the id is WAL-stamped with every
        block and survives spill/crash/restore, so re-feeding the tick
        that was in flight at crash time appends only to the cores that
        had not absorbed it yet.  Without ``tick_id`` the driver owns
        exactly-once tick delivery."""
        wl = 0 if records is None else records.shape[0]
        if wl == 0:
            tick = self.scheduler.account(0, tick_seconds)
            self.report.merge(tick)
            self.ledger.charge_report(tick)
            return TickResult(None, 0, tick)
        t0 = time.perf_counter()
        out = multicore_create_index(records, keys, self.mesh, self.axis,
                                     backend=self.backend)
        jax.block_until_ready(out)
        elapsed = max(time.perf_counter() - t0, 1e-9)
        # paper units: one 8-bit record word = one byte (see TickResult)
        mbps = wl * records.shape[1] * records.shape[2] / 1e6 / elapsed
        a = self.throughput_ewma
        self.measured_mbps = (mbps if self.measured_mbps == 0.0
                              else a * mbps + (1 - a) * self.measured_mbps)
        if self.calibrate_energy:
            self.scheduler.calibrate(self.measured_mbps / self.num_cores)
            tick = self.scheduler.account(
                wl, tick_seconds, busy_seconds=min(elapsed, tick_seconds))
        else:
            tick = self.scheduler.account(wl, tick_seconds)
        self.report.merge(tick)
        self.ledger.charge_report(tick)
        # one indexed bit per (record, key) pair this tick produced
        self.ledger.attribute_bits(wl * records.shape[1] * keys.shape[0])
        z = self.scheduler.cores_needed(wl, tick_seconds)
        if self.store_dir is not None:
            sis = self.core_indexers(keys)
            # crash-replayed tick: each core skips the blocks it already
            # absorbed (a core can hold several batches per tick, so the
            # watermark is (tick, blocks), not just the tick id)
            todo: list[tuple[StreamingIndexer, list[int]]] = []
            for core in range(self.num_cores):
                done = (sis[core].absorbed_blocks(tick_id)
                        if tick_id is not None else 0)
                if done < 0:
                    continue
                blocks = list(range(core, wl, self.num_cores))[done:]
                if blocks:
                    todo.append((sis[core], blocks))
            if todo:                     # one D2H transfer, skipped when
                host = np.asarray(jax.device_get(records))   # fully replayed
                for si, blocks in todo:
                    for b in blocks:
                        si.append_indexed(host[b], out[b], tick=tick_id)
        qrows = qcounts = None
        if queries is not None and len(queries):
            idx = fold_block_indexes(out, records.shape[1])
            qrows, qcounts = engine_batch.execute_many(
                idx.packed, queries, num_records=idx.num_records,
                backend=self.backend)
        return TickResult(out, z, tick, qrows, qcounts,
                          measured_seconds=elapsed, measured_mbps=mbps)

    def index_stream(self, ticks: Iterable[jax.Array | None],
                     keys: jax.Array, tick_seconds: float
                     ) -> tuple[list[jax.Array], EnergyReport]:
        """Run a whole workload stream; returns per-tick index arrays and
        the energy report for THIS stream (the runtime-lifetime total stays
        available as ``self.report``)."""
        outputs = []
        stream_report = EnergyReport()
        for records in ticks:
            res = self.run_tick(records, keys, tick_seconds)
            stream_report.merge(res.report)
            if res.indexes is not None:
                outputs.append(res.indexes)
        return outputs, stream_report
