"""Streaming multi-core runtime: sharded index builds fused with the elastic
energy model, and incremental append into existing packed indexes.

The paper's Fig. 4 deployment feeds Z independent BIC cores from external
memory and powers idle cores down.  The seed simulated the energy side
(``ElasticScheduler``) separately from the execution side
(``multicore_create_index``); this module fuses them:

  * :func:`multicore_create_index` — shard_map dispatch of the full BIC
    pipeline, one engine backend per device, no cross-core communication
    during indexing (moved here from ``core/elastic.py``; that module keeps
    a thin compatibility wrapper).
  * :class:`StreamingIndexer` — incremental append of record blocks into an
    existing packed index with NO full rebuild: each block is indexed alone
    and bit-spliced onto the packed tail (a shift/carry merge when the
    current record count is not 32-aligned).
  * :class:`MulticoreRuntime` — drives ticks of a workload stream through
    the sharded build AND integrates active/standby energy with the
    calibrated silicon model.  The energy side is the paper-clock model
    driven by per-tick workload counts (cores_needed), not a measurement of
    the device execution — shard_map always dispatches over every mesh
    device; calibrating joules against measured wall-clock is a ROADMAP
    follow-up.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from repro import compat  # noqa: F401  (jax.shard_map / mesh shims on 0.4.x)

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.engine import backends, policy
from repro.core.bic import BICConfig, PaperConfig
from repro.core.elastic import ElasticScheduler, EnergyReport, PowerState


# ------------------------------------------------------------- sharded build
def multicore_create_index(records: jax.Array, keys: jax.Array,
                           mesh: Mesh, axis: str = "data",
                           *, backend: str = "auto") -> jax.Array:
    """records (Z*B, N, W) sharded over ``axis``; keys replicated.

    Each device runs the full BIC pipeline on its local batches — the
    paper's Fig. 4 dataflow (no cross-core communication during indexing;
    results are resharded only on readout).  Batch counts that do not
    divide the mesh axis are zero-padded for dispatch and sliced off the
    result.  Returns (Z*B, M, ceil(N/32)).
    """
    be = backends.get_backend(backend)
    zb = records.shape[0]
    z = dict(mesh.shape)[axis]
    pad = -zb % z
    if pad:
        records = jnp.pad(records, ((0, pad), (0, 0), (0, 0)))

    def per_core(rec_block, keys_rep):
        return jax.vmap(lambda rec: be.create_index(rec, keys_rep))(rec_block)

    fn = jax.shard_map(
        per_core, mesh=mesh,
        in_specs=(P(axis, None, None), P()),
        out_specs=P(axis, None, None),
        check_vma=False)   # pallas_call has no replication rule on jax 0.4.x
    out = fn(records, keys)
    return out[:zb] if pad else out


# -------------------------------------------------------- incremental append
def append_packed(packed: jax.Array, num_records: int,
                  block: jax.Array, block_records: int) -> jax.Array:
    """Bit-splice a freshly indexed ``block`` (M, ceil(n'/32)) onto a packed
    index (M, ceil(n/32)) holding ``num_records`` records.

    Pad bits past each logical record count must be zero (every engine
    backend guarantees this).  O(words) shift/carry merge — no unpack.
    """
    m, _ = packed.shape
    off = num_records % policy.PACK
    total_words = policy.num_words(num_records + block_records)
    if off == 0:
        return jnp.concatenate([packed, block], axis=1)[:, :total_words]
    full = num_records // policy.PACK
    base, tail = packed[:, :full], packed[:, full]
    hi = block << jnp.uint32(off)
    carry = block >> jnp.uint32(policy.PACK - off)
    ext = jnp.concatenate([hi, jnp.zeros((m, 1), jnp.uint32)], axis=1)
    ext = ext.at[:, 1:].set(ext[:, 1:] | carry)
    ext = ext.at[:, 0].set(ext[:, 0] | tail)
    return jnp.concatenate([base, ext], axis=1)[:, :total_words]


class StreamingIndexer:
    """Grow one key-major index record-block by record-block.

    ``append`` indexes only the incoming block and splices it in; the live
    index is always available via ``.index`` (bit-identical to a
    from-scratch rebuild over all records seen so far).
    """

    def __init__(self, keys: jax.Array, *, backend: str = "auto"):
        self.keys = jnp.asarray(keys, jnp.int32)
        self.backend = backends.resolve_backend(backend)
        self._packed = jnp.zeros((self.keys.shape[0], 0), jnp.uint32)
        self._num_records = 0

    @property
    def num_records(self) -> int:
        return self._num_records

    def append(self, records: jax.Array) -> policy.BitmapIndex:
        """Index a (N', W) record block and splice it in; returns the
        updated live index."""
        n_new = records.shape[0]
        block = backends.get_backend(self.backend).create_index(
            records, self.keys)
        self._packed = append_packed(self._packed, self._num_records,
                                     block, n_new)
        self._num_records += n_new
        return self.index

    @property
    def index(self) -> policy.BitmapIndex:
        return policy.BitmapIndex(self._packed, self._num_records)


# ------------------------------------------------- fused execution + energy
@dataclasses.dataclass
class TickResult:
    indexes: jax.Array | None   # (B_t, M, ceil(N/32)); None on an idle tick
    active_cores: int
    report: EnergyReport


class MulticoreRuntime:
    """Sharded indexing with elastic energy accounting in one place.

    Each call to :meth:`run_tick` dispatches one tick's record batches over
    the mesh (reusing :func:`multicore_create_index`) and charges the
    elastic scheduler's calibrated power model for the cores the *policy*
    would activate (``cores_needed``); idle cores accrue standby energy
    (CG / CG+RBB).  Joules follow the paper-clock model, not the actual
    device dispatch (which always spans the mesh).
    """

    def __init__(self, mesh: Mesh, axis: str = "data",
                 cfg: BICConfig = PaperConfig,
                 state: PowerState = PowerState(), *,
                 backend: str = "auto"):
        self.mesh = mesh
        self.axis = axis
        self.backend = backends.resolve_backend(backend)
        num_cores = dict(mesh.shape)[axis]
        self.scheduler = ElasticScheduler(num_cores, cfg, state)
        self.report = EnergyReport()

    def run_tick(self, records: jax.Array | None, keys: jax.Array,
                 tick_seconds: float) -> TickResult:
        """records (B_t, N, W) for this tick (None = idle tick)."""
        wl = 0 if records is None else records.shape[0]
        tick = self.scheduler.run([wl], tick_seconds)
        self.report.merge(tick)
        if wl == 0:
            return TickResult(None, 0, tick)
        out = multicore_create_index(records, keys, self.mesh, self.axis,
                                     backend=self.backend)
        z = self.scheduler.cores_needed(wl, tick_seconds)
        return TickResult(out, z, tick)

    def index_stream(self, ticks: Iterable[jax.Array | None],
                     keys: jax.Array, tick_seconds: float
                     ) -> tuple[list[jax.Array], EnergyReport]:
        """Run a whole workload stream; returns per-tick index arrays and
        the energy report for THIS stream (the runtime-lifetime total stays
        available as ``self.report``)."""
        outputs = []
        stream_report = EnergyReport()
        for records in ticks:
            res = self.run_tick(records, keys, tick_seconds)
            stream_report.merge(res.report)
            if res.indexes is not None:
                outputs.append(res.indexes)
        return outputs, stream_report
