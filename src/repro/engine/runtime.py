"""Streaming multi-core runtime: sharded index builds fused with the elastic
energy model, and incremental append into existing packed indexes.

The paper's Fig. 4 deployment feeds Z independent BIC cores from external
memory and powers idle cores down.  The seed simulated the energy side
(``ElasticScheduler``) separately from the execution side
(``multicore_create_index``); this module fuses them:

  * :func:`multicore_create_index` — shard_map dispatch of the full BIC
    pipeline, one engine backend per device, no cross-core communication
    during indexing (moved here from ``core/elastic.py``; that module keeps
    a thin compatibility wrapper).
  * :class:`StreamingIndexer` — incremental append of record blocks into an
    existing packed index with NO full rebuild: each block is indexed alone
    and bit-spliced onto the packed tail (a shift/carry merge when the
    current record count is not 32-aligned).  The splice runs **jitted
    against a geometrically grown capacity buffer** with the record count
    traced, so steady-state appends of a given block size reuse ONE trace
    instead of re-dispatching an unjitted splice per block;
    :meth:`StreamingIndexer.append_many` goes further and indexes a whole
    batch of blocks in one backend dispatch, folding all the splices in a
    single jitted ``lax.scan``.
  * :class:`MulticoreRuntime` — drives ticks of a workload stream through
    the sharded build AND integrates active/standby energy with the
    calibrated silicon model.  ``run_tick(queries=...)`` additionally serves
    a batch of predicate trees against the freshly built tick index through
    :mod:`repro.engine.batch`.  The energy side is the paper-clock model
    driven by per-tick workload counts (cores_needed), not a measurement of
    the device execution — shard_map always dispatches over every mesh
    device; calibrating joules against measured wall-clock is a ROADMAP
    follow-up.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Sequence

from repro import compat  # noqa: F401  (jax.shard_map / mesh shims on 0.4.x)

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.engine import backends, batch as engine_batch, policy
from repro.core.bic import BICConfig, PaperConfig
from repro.core.elastic import ElasticScheduler, EnergyReport, PowerState


# ------------------------------------------------------------- sharded build
def multicore_create_index(records: jax.Array, keys: jax.Array,
                           mesh: Mesh, axis: str = "data",
                           *, backend: str = "auto") -> jax.Array:
    """records (Z*B, N, W) sharded over ``axis``; keys replicated.

    Each device runs the full BIC pipeline on its local batches — the
    paper's Fig. 4 dataflow (no cross-core communication during indexing;
    results are resharded only on readout).  Batch counts that do not
    divide the mesh axis are zero-padded for dispatch and sliced off the
    result.  Returns (Z*B, M, ceil(N/32)).
    """
    be = backends.get_backend(backend)
    zb = records.shape[0]
    z = dict(mesh.shape)[axis]
    pad = -zb % z
    if pad:
        records = jnp.pad(records, ((0, pad), (0, 0), (0, 0)))

    def per_core(rec_block, keys_rep):
        return jax.vmap(lambda rec: be.create_index(rec, keys_rep))(rec_block)

    fn = jax.shard_map(
        per_core, mesh=mesh,
        in_specs=(P(axis, None, None), P()),
        out_specs=P(axis, None, None),
        check_vma=False)   # pallas_call has no replication rule on jax 0.4.x
    out = fn(records, keys)
    return out[:zb] if pad else out


# -------------------------------------------------------- incremental append
_U32 = jnp.uint32


def _splice_impl(buf: jax.Array, num_records: jax.Array,
                 block: jax.Array) -> jax.Array:
    """OR a freshly indexed block (M, BW) into a packed capacity buffer at
    bit offset ``num_records`` (traced — the offset never forces a retrace).

    Caller guarantees ``num_records // 32 + BW + 1 <= buffer words`` and
    that bits past each logical tail are zero (backend pad guarantee)."""
    m, bw = block.shape
    off = (num_records % policy.PACK).astype(_U32)
    full = num_records // policy.PACK
    hi = block << off
    # shift amount 32 is undefined for uint32; the off == 0 carry is zero
    # anyway, so feed the shifter a safe dummy amount there
    safe = jnp.where(off == 0, _U32(1), _U32(policy.PACK) - off)
    carry = jnp.where(off == 0, _U32(0), block >> safe)
    ext = jnp.concatenate([hi, jnp.zeros((m, 1), _U32)], axis=1)
    ext = ext.at[:, 1:].set(ext[:, 1:] | carry)
    region = jax.lax.dynamic_slice(buf, (0, full), (m, bw + 1)) | ext
    return jax.lax.dynamic_update_slice(buf, region, (0, full))


_splice = jax.jit(_splice_impl)


@functools.partial(jax.jit, static_argnames="block_records")
def _fold_scan(buf, num_records0, blocks, block_records):
    """Fold B uniform block splices into the capacity buffer in one trace."""
    def body(carry, block):
        cbuf, n = carry
        return (_splice_impl(cbuf, n, block), n + block_records), None

    carry, _ = jax.lax.scan(body, (buf, num_records0), blocks)
    return carry


@functools.lru_cache(maxsize=8)
def _vmapped_create(backend_name: str):
    """One jitted vmapped create_index per backend: a whole batch of record
    blocks indexes in a single dispatch."""
    be = backends.get_backend(backend_name)
    return jax.jit(jax.vmap(be.create_index, in_axes=(0, None)))


def splice_cache_size() -> int:
    """Number of compiled splice traces (exposed for tests/benchmarks: a
    steady-state append stream must NOT grow this per block)."""
    return _splice._cache_size()


def append_packed(packed: jax.Array, num_records: int,
                  block: jax.Array, block_records: int) -> jax.Array:
    """Bit-splice a freshly indexed ``block`` (M, ceil(n'/32)) onto a packed
    index (M, ceil(n/32)) holding ``num_records`` records.

    Pad bits past each logical record count must be zero (every engine
    backend guarantees this).  O(words) jitted shift/carry merge — no
    unpack; the trace is cached by word-count shape only (the record count
    enters traced).
    """
    total_words = policy.num_words(num_records + block_records)
    slack = block.shape[1] + 1           # splice window past the tail word
    buf = jnp.pad(packed, ((0, 0), (0, slack)))
    return _splice(buf, jnp.int32(num_records), block)[:, :total_words]


class StreamingIndexer:
    """Grow one key-major index record-block by record-block.

    ``append`` indexes only the incoming block and splices it in; the live
    index is always available via ``.index`` (bit-identical to a
    from-scratch rebuild over all records seen so far).  The packed words
    live in a geometrically doubled capacity buffer so the jitted splice
    keeps one trace per block size instead of re-tracing as the index
    grows; size ``capacity_words`` for the expected stream to avoid growth
    retraces entirely.
    """

    def __init__(self, keys: jax.Array, *, backend: str = "auto",
                 capacity_words: int = 16):
        self.keys = jnp.asarray(keys, jnp.int32)
        self.backend = backends.resolve_backend(backend)
        self._cap = max(int(capacity_words), 2)
        self._buf = jnp.zeros((self.keys.shape[0], self._cap), jnp.uint32)
        self._num_records = 0

    @property
    def num_records(self) -> int:
        return self._num_records

    def _grow(self, need_words: int) -> None:
        if need_words > self._cap:
            new = self._cap
            while new < need_words:
                new *= 2
            self._buf = jnp.pad(self._buf, ((0, 0), (0, new - self._cap)))
            self._cap = new

    def append(self, records: jax.Array) -> policy.BitmapIndex:
        """Index a (N', W) record block and splice it in; returns the
        updated live index.  An empty block is a no-op (no dispatch)."""
        n_new = int(records.shape[0])
        if n_new == 0:
            return self.index
        block = backends.get_backend(self.backend).create_index(
            records, self.keys)
        self._grow(self._num_records // policy.PACK + block.shape[1] + 1)
        self._buf = _splice(self._buf, jnp.int32(self._num_records), block)
        self._num_records += n_new
        return self.index

    def append_many(self, records: jax.Array, *, mesh: Mesh | None = None,
                    axis: str = "data") -> policy.BitmapIndex:
        """Append a batch of uniform blocks (B, N', W) in two dispatches:
        one vmapped index build (sharded over ``mesh`` when given) and one
        ``lax.scan`` that folds all B splices."""
        b, n_blk = int(records.shape[0]), int(records.shape[1])
        if b == 0 or n_blk == 0:
            return self.index
        if mesh is not None:
            blocks = multicore_create_index(records, self.keys, mesh, axis,
                                            backend=self.backend)
        else:
            blocks = _vmapped_create(self.backend)(records, self.keys)
        total = self._num_records + b * n_blk
        self._grow(total // policy.PACK + blocks.shape[2] + 1)
        self._buf, _ = _fold_scan(self._buf, jnp.int32(self._num_records),
                                  blocks, n_blk)
        self._num_records = total
        return self.index

    @property
    def index(self) -> policy.BitmapIndex:
        packed = self._buf[:, :policy.num_words(self._num_records)]
        return policy.BitmapIndex(packed, self._num_records)


def fold_block_indexes(blocks: jax.Array,
                       block_records: int) -> policy.BitmapIndex:
    """Fold per-block indexes (B, M, BW) of uniform ``block_records``-record
    blocks into ONE packed index over the concatenated records (a single
    scanned splice dispatch) — e.g. the output of
    :func:`multicore_create_index` becoming a servable tick index."""
    b, m, bw = blocks.shape
    total = b * block_records
    buf = jnp.zeros((m, total // policy.PACK + bw + 1), jnp.uint32)
    (buf, _) = _fold_scan(buf, jnp.int32(0), blocks, block_records)
    return policy.BitmapIndex(buf[:, :policy.num_words(total)], total)


# ------------------------------------------------- fused execution + energy
@dataclasses.dataclass
class TickResult:
    indexes: jax.Array | None   # (B_t, M, ceil(N/32)); None on an idle tick
    active_cores: int
    report: EnergyReport
    query_rows: jax.Array | None = None     # (Q, ceil(B_t*N/32)) uint32
    query_counts: jax.Array | None = None   # (Q,) int32


class MulticoreRuntime:
    """Sharded indexing with elastic energy accounting in one place.

    Each call to :meth:`run_tick` dispatches one tick's record batches over
    the mesh (reusing :func:`multicore_create_index`) and charges the
    elastic scheduler's calibrated power model for the cores the *policy*
    would activate (``cores_needed``); idle cores accrue standby energy
    (CG / CG+RBB).  Joules follow the paper-clock model, not the actual
    device dispatch (which always spans the mesh).
    """

    def __init__(self, mesh: Mesh, axis: str = "data",
                 cfg: BICConfig = PaperConfig,
                 state: PowerState = PowerState(), *,
                 backend: str = "auto"):
        self.mesh = mesh
        self.axis = axis
        self.backend = backends.resolve_backend(backend)
        num_cores = dict(mesh.shape)[axis]
        self.scheduler = ElasticScheduler(num_cores, cfg, state)
        self.report = EnergyReport()

    def run_tick(self, records: jax.Array | None, keys: jax.Array,
                 tick_seconds: float, *,
                 queries: Sequence | None = None) -> TickResult:
        """records (B_t, N, W) for this tick (None = idle tick).

        ``queries`` — an optional batch of predicate trees (or pre-built
        plans) served against the index of THIS tick's records: the
        per-core block indexes fold into one packed tick index (scanned
        splice) and the whole batch executes through
        :func:`repro.engine.batch.execute_many` in a few bucketed
        dispatches.  Results land in ``TickResult.query_rows/query_counts``
        in query order."""
        wl = 0 if records is None else records.shape[0]
        tick = self.scheduler.run([wl], tick_seconds)
        self.report.merge(tick)
        if wl == 0:
            return TickResult(None, 0, tick)
        out = multicore_create_index(records, keys, self.mesh, self.axis,
                                     backend=self.backend)
        z = self.scheduler.cores_needed(wl, tick_seconds)
        qrows = qcounts = None
        if queries is not None and len(queries):
            idx = fold_block_indexes(out, records.shape[1])
            qrows, qcounts = engine_batch.execute_many(
                idx.packed, queries, num_records=idx.num_records,
                backend=self.backend)
        return TickResult(out, z, tick, qrows, qcounts)

    def index_stream(self, ticks: Iterable[jax.Array | None],
                     keys: jax.Array, tick_seconds: float
                     ) -> tuple[list[jax.Array], EnergyReport]:
        """Run a whole workload stream; returns per-tick index arrays and
        the energy report for THIS stream (the runtime-lifetime total stays
        available as ``self.report``)."""
        outputs = []
        stream_report = EnergyReport()
        for records in ticks:
            res = self.run_tick(records, keys, tick_seconds)
            stream_report.merge(res.report)
            if res.indexes is not None:
                outputs.append(res.indexes)
        return outputs, stream_report
