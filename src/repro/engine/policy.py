"""Canonical padding / sentinel policy for every bitmap-index execution path.

This is the engine's single policy surface, replacing the rules that used
to be duplicated across ``core/bic.py``, ``kernels/ops.py`` and
``core/elastic.py``:

  * records pad with :data:`RECORD_SENTINEL` (-1) — a padded record matches
    no key, so its index column is all-zero;
  * keys pad with :data:`KEY_SENTINEL` (-2) — a padded key matches no record
    (and, crucially, differs from the record sentinel so sentinel-vs-sentinel
    never matches);
  * packed query results carry garbage bits past ``num_records`` whenever an
    operand row enters inverted; :func:`mask_tail` zeroes them and recounts.

The bit-packing/sentinel primitives themselves live with the packing
conventions in :mod:`repro.kernels.ref` (so kernel wrappers never import
upward from the engine); this module re-exports them and adds the
engine-level pieces: :func:`mask_tail` and :class:`BitmapIndex`, the packed
key-major index container all layers exchange.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ref import (KEY_SENTINEL, PACK,  # noqa: F401  (re-export)
                               RECORD_SENTINEL, num_words, pad_keys,
                               pad_records, round_up)


def mask_tail(result: jax.Array, num_records: int | jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """Zero bits >= num_records (they exist only due to 32-bit packing) and
    return (masked row, popcount).  ``num_records`` may be traced."""
    nw = result.shape[0]
    valid = (jnp.arange(nw * PACK, dtype=jnp.uint32) < num_records)
    masked = result & ref.pack_bits(valid)
    count = jax.lax.population_count(masked).astype(jnp.int32).sum()
    return masked, count


def splice_packed(buf: jax.Array, bit_offset: jax.Array,
                  block: jax.Array) -> jax.Array:
    """OR packed ``block`` rows (M, BW) into a packed capacity buffer
    (M, W) at ``bit_offset`` (traced — the offset never forces a retrace).

    The shift/carry merge every splicing path shares: the streaming
    indexer's append, the scanned block fold, and the segment-parallel
    OR-fold of per-segment query result rows.  Caller guarantees
    ``bit_offset // 32 + BW + 1 <= W`` and that bits past each logical
    tail are zero (backend pad / tail-mask guarantee)."""
    m, bw = block.shape
    off = (bit_offset % PACK).astype(jnp.uint32)
    full = bit_offset // PACK
    hi = block << off
    # shift amount 32 is undefined for uint32; the off == 0 carry is zero
    # anyway, so feed the shifter a safe dummy amount there
    safe = jnp.where(off == 0, jnp.uint32(1), jnp.uint32(PACK) - off)
    carry = jnp.where(off == 0, jnp.uint32(0), block >> safe)
    ext = jnp.concatenate([hi, jnp.zeros((m, 1), jnp.uint32)], axis=1)
    ext = ext.at[:, 1:].set(ext[:, 1:] | carry)
    region = jax.lax.dynamic_slice(buf, (0, full), (m, bw + 1)) | ext
    return jax.lax.dynamic_update_slice(buf, region, (0, full))


def extract_packed(packed: jax.Array, start: int, count: int) -> jax.Array:
    """Copy packed bit columns ``[start, start + count)`` out of (M, W)
    packed rows into a fresh ``(M, ceil(count/32))`` packed array with
    zeroed tail bits — the inverse of :func:`splice_packed`, used to slice
    a flushable tail out of a live index at an arbitrary (unaligned)
    offset.  ``start``/``count`` are host ints (spill is an I/O path)."""
    m, w = packed.shape
    nw = num_words(count)
    off = start % PACK
    w0 = start // PACK
    need = w0 + nw + (1 if off else 0)
    if need > w:
        packed = jnp.pad(packed, ((0, 0), (0, need - w)))
    if off:
        lo = packed[:, w0:w0 + nw] >> jnp.uint32(off)
        hi = packed[:, w0 + 1:w0 + 1 + nw] << jnp.uint32(PACK - off)
        out = lo | hi
    else:
        out = packed[:, w0:w0 + nw]
    valid = (jnp.arange(nw * PACK, dtype=jnp.uint32) < count)
    return out & ref.pack_bits(valid)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitmapIndex:
    """Key-major packed bitmap index: rows = keys, columns = records."""
    packed: jax.Array          # (M, ceil(N/32)) uint32
    num_records: int

    def tree_flatten(self):
        return (self.packed,), self.num_records

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    @property
    def num_keys(self) -> int:
        return self.packed.shape[0]

    def row(self, key_idx: int) -> jax.Array:
        return self.packed[key_idx]

    def to_dense(self) -> jax.Array:
        """(M, N) {0,1} — for tests and small examples only."""
        return ref.unpack_bits(self.packed, self.num_records)
