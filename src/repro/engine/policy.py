"""Canonical padding / sentinel policy for every bitmap-index execution path.

This is the engine's single policy surface, replacing the rules that used
to be duplicated across ``core/bic.py``, ``kernels/ops.py`` and
``core/elastic.py``:

  * records pad with :data:`RECORD_SENTINEL` (-1) — a padded record matches
    no key, so its index column is all-zero;
  * keys pad with :data:`KEY_SENTINEL` (-2) — a padded key matches no record
    (and, crucially, differs from the record sentinel so sentinel-vs-sentinel
    never matches);
  * packed query results carry garbage bits past ``num_records`` whenever an
    operand row enters inverted; :func:`mask_tail` zeroes them and recounts.

The bit-packing/sentinel primitives themselves live with the packing
conventions in :mod:`repro.kernels.ref` (so kernel wrappers never import
upward from the engine); this module re-exports them and adds the
engine-level pieces: :func:`mask_tail` and :class:`BitmapIndex`, the packed
key-major index container all layers exchange.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ref import (KEY_SENTINEL, PACK,  # noqa: F401  (re-export)
                               RECORD_SENTINEL, num_words, pad_keys,
                               pad_records, round_up)


def mask_tail(result: jax.Array, num_records: int | jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """Zero bits >= num_records (they exist only due to 32-bit packing) and
    return (masked row, popcount).  ``num_records`` may be traced."""
    nw = result.shape[0]
    valid = (jnp.arange(nw * PACK, dtype=jnp.uint32) < num_records)
    masked = result & ref.pack_bits(valid)
    count = jax.lax.population_count(masked).astype(jnp.int32).sum()
    return masked, count


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitmapIndex:
    """Key-major packed bitmap index: rows = keys, columns = records."""
    packed: jax.Array          # (M, ceil(N/32)) uint32
    num_records: int

    def tree_flatten(self):
        return (self.packed,), self.num_records

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    @property
    def num_keys(self) -> int:
        return self.packed.shape[0]

    def row(self, key_idx: int) -> jax.Array:
        return self.packed[key_idx]

    def to_dense(self) -> jax.Array:
        """(M, N) {0,1} — for tests and small examples only."""
        return ref.unpack_bits(self.packed, self.num_records)
