"""Batched query serving: many predicate trees per dispatch.

``planner.execute`` serves one predicate tree per call — fine for ad-hoc
queries, but a serving workload is a *mix* of thousands of small trees, and
at that scale per-call dispatch dominates the actual bitwise work (the same
observation that drives bulk bitwise engines: amortize dispatch over large
batches of passes).  This module restructures the serving path:

  1. **Lower** every plan to a uniform *pass program*: a tuple of groups,
     each group a tuple of fused AND-passes ``(literals, post_invert)``.
     A plain DNF clause is a one-pass group; a factored group is a common
     AND pass plus a De-Morgan OR pass (``post_invert`` folds the final
     negation into an xor mask).  Query result = OR over groups of the
     AND over each group's passes.
  2. **Bucket** programs by canonical padded shape ``(G groups, P passes,
     L literals)`` — G and L round up to powers of two so a heterogeneous
     1000-query mix lands in a handful of buckets instead of one trace per
     exact shape.
  3. **Pad with identity rows**: the packed index is augmented with one
     virtual all-ones row at index M.  Padded literal slots select it
     non-inverted (AND-identity); padded group slots xor-mask their pass to
     all-zeros (OR-identity).  Padding never changes a result bit.
  4. **Execute each bucket as ONE vmapped, jit-cached call** over
     ``(Q, G, P, L)`` literal-selector arrays.  Executors cache on
     ``(backend, G, P, L)`` only — key ids, inversion flags, and the record
     count all enter traced — and the query axis Q itself pads up to a
     power of two with provable all-zero pad queries (sliced off), so the
     varying coalesced batch sizes a micro-batching scheduler emits reuse
     one compiled trace instead of retracing per batch size.

Composite plans (the DNF size-guard fallback) and contradictions are served
out-of-band — composites through ``planner.execute``, contradictions as
constant zeros — and spliced back into input order.

:func:`execute_many_segments` extends the same machinery to indexes that
live as a chain of packed **segments** over disjoint record ranges (the
durable layout of :mod:`repro.store`): plans lower and bucket ONCE, the
bucketed dispatch runs per segment (identical word counts reuse one
compiled executor), and the per-segment result rows OR-splice together at
their record offsets — so an index larger than any single resident buffer
is servable without materializing it.
"""
from __future__ import annotations

import functools
from typing import Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import backends, costmodel, planner, policy
from repro.fault import seam as _fault_seam
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

# compile observability: waves / bucket dispatches / executor builds in
# the process-wide registry (the jit caches below are process-global).
# builds == lru_cache misses == retraces; hits are dispatches - builds.
_WAVES = _obs_metrics.GLOBAL.counter(
    "engine_waves_total", "batched _serve invocations")
_QUERIES = _obs_metrics.GLOBAL.counter(
    "engine_queries_total", "queries served through batched waves")
_DISPATCHES = _obs_metrics.GLOBAL.counter(
    "engine_bucket_dispatches_total", "bucket executor calls")
_BUILDS = _obs_metrics.GLOBAL.counter(
    "engine_executor_builds_total",
    "bucket executors jit-built (cache misses = retraces)")

#: One pass: (literals tuple[(key, inverted)], post_invert).  Program:
#: tuple of groups, each a tuple of passes.
PassProgram = tuple


def lower(pl: Union[planner.QueryPlan, planner.FactoredPlan]) -> PassProgram:
    """Lower a plan to the uniform group/pass form the batched executor
    runs.  ``OR(lits) == ~AND(~lits)``: factored OR sides enter with
    flipped literal inversions and ``post_invert=True``."""
    if isinstance(pl, planner.QueryPlan):
        return tuple(((c, False),) for c in pl.clauses)
    groups = []
    for common, ored in pl.groups:
        passes = []
        if common:
            passes.append((common, False))
        if ored:
            passes.append((tuple((i, not v) for i, v in ored), True))
        groups.append(tuple(passes))
    return tuple(groups)


def _pow2_ceil(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def canonical_shape(prog: PassProgram) -> tuple[int, int, int]:
    """(G, P, L) bucket key: groups and literals round up to powers of two
    (padding is identity-exact), pass depth stays exact (1 or 2)."""
    g = _pow2_ceil(len(prog))
    p = max(len(passes) for passes in prog)
    l = _pow2_ceil(max(len(lits) for passes in prog for lits, _ in passes))
    return g, p, l


def _bucket_body(backend, p: int, g: int):
    """The shared bucket-executor body: vmap over queries of [OR over
    groups of [AND over passes of [fused kernel pass]]], then one
    tail-mask + popcount per query.  ``aug`` is (M+1, Nw) with the all-ones
    row at M; sels/invs (Q, g, p, l); post (Q, g, p) uint32 xor masks
    (0 or 0xFFFFFFFF)."""

    def run(aug, num_records, sels, invs, post):
        def one_pass(sel, inv, po):
            row, _ = backend.query(aug[sel], inv)   # count is dead code
            return row ^ po

        def one_query(sel, inv, po):
            rows = jax.vmap(jax.vmap(one_pass))(sel, inv, po)  # (g, p, Nw)
            grp = rows[:, 0]
            for pi in range(1, p):
                grp = grp & rows[:, pi]
            acc = grp[0]
            for gi in range(1, g):
                acc = acc | grp[gi]
            return policy.mask_tail(acc, num_records)

        return jax.vmap(one_query)(sels, invs, post)

    return run


def _body_for(backend, g: int, p: int):
    """A backend's bucket executor body: its whole-bucket ``run_program``
    hook when it has one (the bulk backend's fused multi-word sweep),
    else the per-pass body composed around ``query``.  Both honor the
    same call contract, so the jitted/stacked wrappers don't care."""
    if backend.run_program is not None:
        return backend.run_program
    return _bucket_body(backend, p, g)


@functools.lru_cache(maxsize=64)
def _executor(backend_name: str, g: int, p: int, l: int):
    """One jitted batched executor per (backend, canonical shape).
    Keyed by backend NAME: executors for different backends coexist in
    the cache, so a cost-model backend switch mid-traffic lands on an
    already-compiled executor instead of stalling a wave."""
    _BUILDS.inc()                      # body runs only on a cache miss
    return jax.jit(_body_for(backends.get_backend(backend_name), g, p))


@functools.lru_cache(maxsize=64)
def _stacked_executor(backend_name: str, g: int, p: int, l: int):
    """Segment-stacked twin of :func:`_executor`: the SAME bucket body
    vmapped over a leading segment axis of ``aug`` (S, M+1, Nw) and
    ``num_records`` (S,), with the selector arrays broadcast — every live
    segment of a uniform-word-count chain serves the whole bucket in ONE
    dispatch instead of one dispatch per segment."""
    _BUILDS.inc()
    body = _body_for(backends.get_backend(backend_name), g, p)
    return jax.jit(jax.vmap(body, in_axes=(0, 0, None, None, None)))


def batched_executor_cache_info():
    """Exposed for tests/benchmarks: the bucket-executor cache statistics."""
    return _executor.cache_info()


@functools.lru_cache(maxsize=4096)
def _lowered(pl) -> tuple[PassProgram, tuple[int, int, int] | None, int, int]:
    """Per-plan lowering cache: (program, canonical shape, min/max key id).
    Plans hash by value, so a re-submitted (or structurally equal) plan
    skips lowering, shape derivation, and range-scan work entirely."""
    prog = lower(pl)
    if not prog:
        return prog, None, 0, -1
    ids = [i for grp in prog for lits, _ in grp for i, _ in lits]
    return prog, canonical_shape(prog), min(ids), max(ids)


def _bucket_arrays(progs: Sequence[PassProgram], shape: tuple[int, int, int],
                   ones_idx: int):
    """Pack a bucket's programs into dense (Q, G, P, L) selector arrays.

    Defaults are the identities: literal slots select the virtual all-ones
    row non-inverted; pad groups xor-mask pass 0 to all-zeros.

    The query axis also rounds up to a power of two (pad queries are
    all-pad-groups — provable all-zero rows, sliced off by the caller):
    executors jit-cache on the full selector shape, so without Q-padding
    every distinct coalesced batch size a serving scheduler produces
    would compile a fresh trace — the micro-batching win would drown in
    retraces."""
    g, p, l = shape
    q = len(progs)
    qp = _pow2_ceil(max(q, 1))
    sels = np.full((qp, g, p, l), ones_idx, np.int32)
    invs = np.zeros((qp, g, p, l), np.int32)
    post = np.zeros((qp, g, p), np.uint32)
    post[q:, :, 0] = 0xFFFFFFFF           # pad queries -> all-zero rows
    for qi, prog in enumerate(progs):
        for gi in range(g):
            if gi >= len(prog):
                post[qi, gi, 0] = 0xFFFFFFFF      # pad group -> all-zeros
                continue
            for pi, (lits, pinv) in enumerate(prog[gi]):
                for li, (kidx, linv) in enumerate(lits):
                    sels[qi, gi, pi, li] = kidx
                    invs[qi, gi, pi, li] = int(linv)
                if pinv:
                    post[qi, gi, pi] = 0xFFFFFFFF
    return sels, invs, post


def _to_plans(predicates: Sequence, m: int,
              max_clauses: int | None, factor: bool) -> list:
    """Plan every predicate (validating raw trees against ``m`` key rows)
    and optionally factor the DNF plans."""
    plans = []
    for pred in predicates:
        if isinstance(pred, (planner.QueryPlan, planner.FactoredPlan,
                             planner.CompositePlan)):
            pl = pred
        else:
            # validate on the raw tree, BEFORE simplification, so a typo'd
            # id inside a contradictory/absorbed branch still raises
            planner.check_key_range(planner.key_indices(pred), m)
            pl = planner.plan(pred, max_clauses=max_clauses)
        if factor and isinstance(pl, planner.QueryPlan) and pl.clauses:
            pl = planner.factor(pl)
        plans.append(pl)
    return plans


def _partition(plans: Sequence, m: int):
    """Bucket lowered plans by canonical shape and pack the per-bucket
    selector arrays ONCE — reusable across every packed buffer the batch
    is served against (the whole index, or each segment of a chain).

    Returns (bucket list [(shape, idxs, sels, invs, post)], zero-result
    query indexes, composite-fallback query indexes)."""
    buckets: dict[tuple[int, int, int], tuple[list, list]] = {}
    composite: list[int] = []
    zeros: list[int] = []
    for qi, pl in enumerate(plans):
        if isinstance(pl, planner.CompositePlan):
            composite.append(qi)       # planner.execute validates key range
            continue
        prog, shape, lo, hi = _lowered(pl)
        if not prog:
            zeros.append(qi)           # contradiction: constant all-zero
            continue
        if lo < 0 or hi >= m:   # cached min/max make the common case free
            planner.check_key_range(planner.plan_key_indices(pl), m)
        idxs, progs = buckets.setdefault(shape, ([], []))
        idxs.append(qi)
        progs.append(prog)
    packed_buckets = []
    for shape, (idxs, progs) in buckets.items():
        sels, invs, post = _bucket_arrays(progs, shape, ones_idx=m)
        packed_buckets.append((shape, idxs, jnp.asarray(sels),
                               jnp.asarray(invs), jnp.asarray(post)))
    return packed_buckets, zeros, composite


#: id(packed) -> (packed, augmented) — a steady-state serving loop
#: re-dispatches against the SAME immutable packed buffer every wave, and
#: re-materializing the augmented copy (one identity row appended) costs a
#: full index copy per dispatch at bandwidth-bound sizes.  Entries hold a
#: strong reference to the source buffer, so a cached id can never belong
#: to a recycled object; bounded by wholesale drop.
_AUG_CACHE: dict = {}
_AUG_CACHE_LIMIT = 16


def _augmented(packed: jax.Array) -> jax.Array:
    ent = _AUG_CACHE.get(id(packed))
    if ent is not None and ent[0] is packed:
        return ent[1]
    m, nw = packed.shape
    aug = jnp.concatenate(
        [packed, jnp.full((1, nw), 0xFFFFFFFF, dtype=jnp.uint32)], axis=0)
    if len(_AUG_CACHE) >= _AUG_CACHE_LIMIT:
        _AUG_CACHE.clear()
    _AUG_CACHE[id(packed)] = (packed, aug)
    return aug


def _serve(packed: jax.Array, num_records: int, plans: Sequence,
           part, name: str, pad_output: bool = False
           ) -> tuple[jax.Array, jax.Array]:
    """Run a pre-partitioned batch against ONE packed buffer; results come
    back in input order.

    ``pad_output=True`` keeps every piece at its padded power-of-two size
    and pads the OUTPUT query axis to ``pow2_ceil(Q)`` too (rows past the
    real Q are unspecified padding): every array shape in the path is
    then drawn from a small closed set, so a micro-batching scheduler's
    varying batch compositions never pay a first-sight jit compile on
    the re-assembly ops — callers index the real prefix."""
    # fault seam: an injected dispatch error aborts the whole wave here,
    # exercising the service's retry -> backend-fallback -> isolation path
    _fault_seam.fire("engine.dispatch", backend=name, queries=len(plans))
    _WAVES.inc()
    _QUERIES.add(len(plans))
    tracer = _obs_trace.TRACER
    m, nw = packed.shape
    buckets, zeros, composite = part
    q = len(plans)
    q_out = _pow2_ceil(max(q, 1)) if pad_output else q
    # One result piece per bucket (plus zeros / composite fallbacks), then a
    # single permutation gather back into input order — no per-bucket
    # scatter over the (Q, Nw) output.
    pieces_r: list[jax.Array] = []
    pieces_c: list[jax.Array] = []
    order: list[int] = []       # original query index per real row
    pos: list[int] = []         # its row in the concatenated pieces
    off = 0
    if buckets:
        aug = _augmented(packed)
        nrec = jnp.int32(num_records)
        for shape, idxs, sels, invs, post in buckets:
            _DISPATCHES.inc()
            if tracer is None:
                rws, cts = _executor(name, *shape)(aug, nrec, sels, invs,
                                                   post)
            else:
                with tracer.span("bucket.dispatch", backend=name,
                                 shape=shape, q=len(idxs)):
                    rws, cts = _executor(name, *shape)(aug, nrec, sels,
                                                       invs, post)
            if not pad_output and rws.shape[0] != len(idxs):
                rws, cts = rws[:len(idxs)], cts[:len(idxs)]  # drop Q-pads
            pieces_r.append(rws)
            pieces_c.append(cts)
            order.extend(idxs)
            pos.extend(range(off, off + len(idxs)))
            off += rws.shape[0]
    if zeros:
        zn = _pow2_ceil(len(zeros)) if pad_output else len(zeros)
        pieces_r.append(jnp.zeros((zn, nw), jnp.uint32))
        pieces_c.append(jnp.zeros((zn,), jnp.int32))
        order.extend(zeros)
        pos.extend(range(off, off + len(zeros)))
        off += zn
    for qi in composite:                # size-guard fallback: out-of-band
        r, c = planner.execute(packed, plans[qi], num_records=num_records,
                               backend=name)
        pieces_r.append(r[None])
        pieces_c.append(c[None])
        order.append(qi)
        pos.append(off)
        off += 1

    rows_all = pieces_r[0] if len(pieces_r) == 1 else jnp.concatenate(pieces_r)
    counts_all = (pieces_c[0] if len(pieces_c) == 1
                  else jnp.concatenate(pieces_c))
    if order == list(range(q)) and rows_all.shape[0] == q_out:
        return rows_all, counts_all     # single in-order exact bucket
    inv = np.zeros(q_out, np.int32)     # pad slots gather row 0 (ignored)
    inv[np.asarray(order, np.int32)] = np.asarray(pos, np.int32)
    inv = jnp.asarray(inv)
    return rows_all[inv], counts_all[inv]


def execute_many(packed: jax.Array,
                 predicates: Sequence[Union[planner.Pred, planner.QueryPlan,
                                            planner.FactoredPlan,
                                            planner.CompositePlan]], *,
                 num_records: int, backend: str = "auto",
                 max_clauses: int | None = planner.DEFAULT_MAX_CLAUSES,
                 factor: bool = False, pad_output: bool = False,
                 stats: planner.KeyStats | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Serve a batch of predicate trees (or pre-built plans) over one packed
    (M, Nw) index in a handful of vmapped dispatches.

    Returns (rows (Q, Nw) uint32, counts (Q,) int32) in input order, each
    row tail-masked past ``num_records`` — bit-identical to a sequential
    loop of :func:`planner.execute`.  ``factor=True`` additionally runs
    common-clause factoring on each DNF plan before lowering.
    ``pad_output=True`` pads the query axis of BOTH outputs to
    ``pow2_ceil(Q)`` (rows past Q are unspecified) so varying serving
    batch sizes reuse compiled re-assembly shapes — see :func:`_serve`.

    ``backend="auto"`` is a *measured* per-wave choice: the lowered plans'
    padded bucket shapes feed :func:`repro.engine.costmodel.decide`, which
    picks the cheapest calibrated backend (and whether common-clause
    factoring shrinks the streamed words).  ``stats`` (optional KeyStats)
    only refines the cost terms — never the result bits.
    """
    m, nw = packed.shape
    plans = _to_plans(predicates, m, max_clauses, factor)
    if not plans:
        return (jnp.zeros((0, nw), jnp.uint32), jnp.zeros((0,), jnp.int32))
    if backend == "auto":
        decision = costmodel.decide(plans, num_words=nw, num_keys=m,
                                    stats=stats, allow_factor=not factor)
        name = decision.backend
        if decision.factor:
            plans = [planner.factor(pl)
                     if isinstance(pl, planner.QueryPlan) and pl.clauses
                     else pl for pl in plans]
    else:
        name = backends.resolve_backend(backend)
    return _serve(packed, num_records, plans, _partition(plans, m), name,
                  pad_output)


def _serve_stacked(stack: jax.Array, nrecs: Sequence[int], plans: Sequence,
                   part, name: str) -> tuple[jax.Array, jax.Array]:
    """Run a pre-partitioned batch against a STACK of uniform-word-count
    packed buffers (S, M, Nw) holding ``nrecs[s]`` records each — one
    vmapped dispatch per bucket covers every segment.  Returns
    (rows (S, Q, Nw), counts (S, Q)) in input query order."""
    _fault_seam.fire("engine.dispatch", backend=name, queries=len(plans))
    _WAVES.inc()
    _QUERIES.add(len(plans))
    tracer = _obs_trace.TRACER
    s, m, nw = stack.shape
    buckets, zeros, composite = part
    q = len(plans)
    pieces_r: list[jax.Array] = []
    pieces_c: list[jax.Array] = []
    order: list[int] = []
    if buckets:
        aug = jnp.concatenate(
            [stack, jnp.full((s, 1, nw), 0xFFFFFFFF, dtype=jnp.uint32)],
            axis=1)
        nrec = jnp.asarray(list(nrecs), jnp.int32)
        for shape, idxs, sels, invs, post in buckets:
            _DISPATCHES.inc()
            if tracer is None:
                rws, cts = _stacked_executor(name, *shape)(aug, nrec, sels,
                                                           invs, post)
            else:
                with tracer.span("bucket.dispatch", backend=name,
                                 shape=shape, q=len(idxs), segments=s):
                    rws, cts = _stacked_executor(name, *shape)(
                        aug, nrec, sels, invs, post)
            if rws.shape[1] != len(idxs):         # drop Q-pad rows
                rws, cts = rws[:, :len(idxs)], cts[:, :len(idxs)]
            pieces_r.append(rws)
            pieces_c.append(cts)
            order.extend(idxs)
    if zeros:
        pieces_r.append(jnp.zeros((s, len(zeros), nw), jnp.uint32))
        pieces_c.append(jnp.zeros((s, len(zeros)), jnp.int32))
        order.extend(zeros)
    for qi in composite:                # size-guard fallback: out-of-band
        rs, cs = [], []
        for si in range(s):
            r, c = planner.execute(stack[si], plans[qi],
                                   num_records=int(nrecs[si]), backend=name)
            rs.append(r)
            cs.append(c)
        pieces_r.append(jnp.stack(rs)[:, None])
        pieces_c.append(jnp.stack(cs)[:, None])
        order.append(qi)

    rows_all = (pieces_r[0] if len(pieces_r) == 1
                else jnp.concatenate(pieces_r, axis=1))
    counts_all = (pieces_c[0] if len(pieces_c) == 1
                  else jnp.concatenate(pieces_c, axis=1))
    if order == list(range(q)):
        return rows_all, counts_all
    inv = np.empty(q, np.int32)
    inv[np.asarray(order, np.int32)] = np.arange(q, dtype=np.int32)
    inv = jnp.asarray(inv)
    return rows_all[:, inv], counts_all[:, inv]


_seg_splice = jax.jit(policy.splice_packed)


def execute_many_segments(parts: Sequence[tuple[jax.Array, int]],
                          predicates: Sequence, *, backend: str = "auto",
                          max_clauses: int | None =
                          planner.DEFAULT_MAX_CLAUSES,
                          factor: bool = False,
                          stack_uniform: bool | None = None,
                          stats: planner.KeyStats | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """Serve a query batch over an index stored as a chain of packed
    segments covering contiguous record ranges — the durable layout of
    :mod:`repro.store` — without materializing one contiguous buffer.

    ``parts``: ordered ``(packed (M, ceil(n_i/32)) uint32, n_i)`` pairs;
    record ``sum(n_(<i))`` is the absolute offset of segment i.  Plans
    lower, validate, and bucket ONCE; each segment then runs the bucketed
    dispatch (segments with equal word counts share the same compiled
    executors) and its result rows — tail-masked within the segment — are
    OR-spliced into the global (Q, ceil(N/32)) rows at the segment's bit
    offset.  Counts sum per segment.  Bit-identical to
    :func:`execute_many` over the spliced-together index.

    ``stack_uniform``: when every live segment shares ONE word count —
    the steady state of a tier-compacted store — the segments stack into
    an (S, M, Nw) array and each bucket serves ALL segments in a single
    vmapped dispatch (:func:`_stacked_executor`) instead of one bucketed
    dispatch per segment; results stay bit-identical to the per-segment
    path.  ``None`` (the default) means: stack for explicit backends,
    and for ``backend="auto"`` let the cost model weigh the stack-copy
    bytes against the saved per-segment dispatch overheads.
    """
    parts = [(p, int(n)) for p, n in parts]
    if not parts:
        # an empty index has no key count to validate against; every
        # query matches nothing by definition
        q = len(predicates)
        return (jnp.zeros((q, 0), jnp.uint32), jnp.zeros((q,), jnp.int32))
    total = sum(n for _, n in parts)
    tw = policy.num_words(total)
    m = parts[0][0].shape[0]
    if any(p.shape[0] != m for p, _ in parts):
        raise ValueError("segments disagree on key count: "
                         f"{[p.shape[0] for p, _ in parts]}")
    plans = _to_plans(predicates, m, max_clauses, factor)
    q = len(plans)
    if q == 0:
        return (jnp.zeros((q, tw), jnp.uint32), jnp.zeros((q,), jnp.int32))
    max_bw = max(p.shape[1] for p, _ in parts)
    if backend == "auto":
        decision = costmodel.decide(plans, num_words=max_bw,
                                    num_segments=len(parts), num_keys=m,
                                    stats=stats, allow_factor=not factor)
        name = decision.backend
        if decision.factor:
            plans = [planner.factor(pl)
                     if isinstance(pl, planner.QueryPlan) and pl.clauses
                     else pl for pl in plans]
        if stack_uniform is None:
            stack_uniform = decision.stack_uniform
    else:
        name = backends.resolve_backend(backend)
        if stack_uniform is None:
            stack_uniform = True
    part = _partition(plans, m)
    rows = jnp.zeros((q, tw + max_bw + 1), jnp.uint32)
    counts = jnp.zeros((q,), jnp.int32)
    uniform = len({p.shape[1] for p, _ in parts}) == 1
    if stack_uniform and uniform and len(parts) > 1:
        stack = jnp.stack([jnp.asarray(p) for p, _ in parts])
        nrecs = [n for _, n in parts]
        rows_s, counts_s = _serve_stacked(stack, nrecs, plans, part, name)
        start = 0
        for si, n in enumerate(nrecs):
            rows = _seg_splice(rows, jnp.int32(start), rows_s[si])
            start += n
        return rows[:, :tw], counts_s.sum(axis=0).astype(jnp.int32)
    start = 0
    for packed, n in parts:
        r_i, c_i = _serve(jnp.asarray(packed), n, plans, part, name)
        rows = _seg_splice(rows, jnp.int32(start), r_i)
        counts = counts + c_i
        start += n
    return rows[:, :tw], counts
