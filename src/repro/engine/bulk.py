"""Bulk-bitwise execution: whole pass programs as tiled multi-word sweeps.

The paper's BIC (and the in-memory bulk-bitwise engines it anticipates —
Buddy-RAM, SiM) wins by treating boolean filtering as a *bulk memory
operation*: AND/OR/NOT over huge bitvectors runs at whatever bandwidth the
memory system sustains, not at dispatch rate.  The ``ref``/``pallas``
backends execute one fused AND pass per call; the batched executor vmaps
those per-pass calls, which streams every operand row end to end — at
serving sizes the augmented index is re-read from far memory once per
literal of every query in the bucket.

This module is the third backend's execution core: it runs the WHOLE
lowered pass program (the ``(Q, G, P, L)`` selector arrays of one bucket,
see :mod:`repro.engine.batch`) as a sweep over *word tiles*:

  * every literal of every query gathers its operand row ONCE; the AND
    over literals, the De-Morgan xor, the AND over passes and the OR over
    groups all fold before the result rows are written — one fused
    multi-word sweep instead of one dispatch per pass;
  * tail masking + popcount run fused over the swept rows;
  * the sweep is memory-bounded, not memory-proportional: on TPU the
    Pallas kernel walks word tiles sized to VMEM (:func:`tile_words`);
    the pure-``jnp`` realization instead chunks the QUERY axis when the
    ``(Q, G, P, Nw)`` accumulator would outgrow :data:`SWEEP_BUDGET_BYTES`
    (word-tiling via ``lax.map`` serializes into per-tile dispatch
    overhead on CPU — query chunks keep whole rows streaming).

Two realizations share that schedule: :func:`run_program` (pure ``jnp`` —
the portable fallback, and the CPU fast path) and the word-tiled Pallas
kernel :func:`repro.kernels.bitmap_ops.bulk_program` (used on TPU).  Both
are bit-identical to the per-pass bucket body; the differential sweep in
``tests/test_backend_sweep.py`` gates that.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine import policy
from repro.kernels import bitmap_ops, ref

_U32 = jnp.uint32

#: Fast-memory budget (bytes) one tile of work should fit in: the
#: augmented index tile plus the (Q, G, P, T) accumulator.  Sized for a
#: CPU L2/L3 slice; comfortably under a TPU core's ~16 MB VMEM too.
TILE_BUDGET_BYTES = 4 << 20

#: Floor on the tile width (words).  Below this the per-tile bookkeeping
#: dominates and the sweep degenerates into dispatch overhead.
MIN_TILE_WORDS = 64


def tile_words(m1: int, qgp: int, nw: int,
               budget: int = TILE_BUDGET_BYTES) -> int:
    """Largest power-of-two word-tile width such that one augmented index
    tile (``m1`` rows) plus the accumulator (``qgp`` rows) fits the fast-
    memory budget; never below :data:`MIN_TILE_WORDS`, never wider than
    the (pow2-rounded) row itself."""
    t = 1
    while t < nw:
        t *= 2
    while t > MIN_TILE_WORDS and (m1 + qgp) * t * 4 > budget:
        t //= 2
    return t


def query(rows: jax.Array, invert: jax.Array) -> tuple[jax.Array, jax.Array]:
    """``Backend.query`` for the bulk backend: one fused AND-with-inversion
    pass as a single bulk reduction (no per-literal unrolled chain — the
    reduce tree is XLA's to schedule at memory speed).  Same contract as
    :func:`repro.kernels.ref.bitmap_query`: tail bits are NOT masked."""
    flips = invert.astype(_U32)[:, None] * _U32(0xFFFFFFFF)
    result = jax.lax.reduce(rows ^ flips, _U32(0xFFFFFFFF),
                            jax.lax.bitwise_and, (0,))
    count = jax.lax.population_count(result).astype(jnp.int32).sum()
    return result, count


def create_index(records: jax.Array, keys: jax.Array) -> jax.Array:
    """Index creation is already one bulk pass (vectorized match +
    transpose); the bulk backend shares the oracle pipeline — its win is
    the query side."""
    n = records.shape[0]
    m = keys.shape[0]
    packed = ref.create_index(policy.pad_records(records),
                              policy.pad_keys(keys))
    return packed[:m, : policy.num_words(n)]


#: Cap on the pure-jnp sweep's largest intermediate — the (Qc, G, P, Nw)
#: accumulator of one query chunk.  Above it the query axis chunks via
#: ``lax.map``; whole rows keep streaming either way.
SWEEP_BUDGET_BYTES = 64 << 20


def _sweep_block(aug, sels, invs, post, flip):
    """One fused sweep over full rows: sels/invs/post carry a leading
    query-chunk axis; returns (Qc, Nw) result rows, tails unmasked."""
    q, g, p, l = sels.shape
    acc = None
    for li in range(l):                       # static unroll: bucket L
        opnd = jnp.take(aug, sels[..., li], axis=0)       # (q, g, p, Nw)
        x = opnd ^ flip[..., li, None]
        acc = x if acc is None else acc & x
    acc = acc ^ post[..., None]               # De-Morgan OR-pass mask
    grp = acc[:, :, 0]
    for pi in range(1, p):
        grp = grp & acc[:, :, pi]
    out = grp[:, 0]
    for gi in range(1, g):
        out = out | grp[:, gi]
    return out                                # (q, Nw)


def _sweep_jnp(aug: jax.Array, sels: jax.Array, invs: jax.Array,
               post: jax.Array) -> jax.Array:
    """The fused sweep, pure jnp: aug (M+1, Nw) augmented packed index,
    sels/invs (Q, G, P, L), post (Q, G, P) xor masks -> rows (Q, Nw),
    tail bits NOT yet masked.  Query-chunked past the accumulator
    budget; bit-identical either way."""
    m1, nw = aug.shape
    q, g, p, l = sels.shape
    flip = invs.astype(_U32) * _U32(0xFFFFFFFF)
    per_query = g * p * max(nw, 1) * 4
    qc = max(1, SWEEP_BUDGET_BYTES // max(per_query, 1))
    if qc >= q:
        return _sweep_block(aug, sels, invs, post, flip)
    while q % qc:                             # q is a power of two
        qc -= 1
    chunk = lambda a: a.reshape((q // qc, qc) + a.shape[1:])  # noqa: E731
    swept = jax.lax.map(
        lambda args: _sweep_block(aug, *args),
        (chunk(sels), chunk(invs), chunk(post), chunk(flip)))
    return swept.reshape(q, nw)


def run_program(aug: jax.Array, num_records, sels: jax.Array,
                invs: jax.Array, post: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """Whole-bucket executor (the ``Backend.run_program`` hook): identical
    call contract to the per-pass bucket body in :mod:`repro.engine.batch`
    — aug (M+1, Nw) with the all-ones identity row at M, selector arrays
    (Q, G, P, L), post xor masks (Q, G, P) — returning (rows (Q, Nw),
    counts (Q,)) with tails masked past ``num_records``.

    On TPU the sweep runs as the word-tiled Pallas kernel; elsewhere as
    the pure-jnp tiled sweep.  Uncompiled — the batch layer jits (and
    vmaps, for segment stacks) exactly like the per-pass body.
    """
    if jax.default_backend() == "tpu":
        m1 = aug.shape[0]
        q, g, p, _ = sels.shape
        bn = tile_words(m1, q * g * p, aug.shape[1])
        rows = bitmap_ops.bulk_program(aug, sels, invs, post, block_n=bn,
                                       interpret=False)
    else:
        rows = _sweep_jnp(aug, sels, invs, post)
    return jax.vmap(policy.mask_tail, in_axes=(0, None))(rows, num_records)


def run_program_pallas(aug: jax.Array, num_records, sels: jax.Array,
                       invs: jax.Array, post: jax.Array, *,
                       block_n: int | None = None,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """The Pallas realization, callable explicitly (tests exercise it in
    interpret mode off-TPU; :func:`run_program` routes to it on TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m1 = aug.shape[0]
    q, g, p, _ = sels.shape
    if block_n is None:
        block_n = tile_words(m1, q * g * p, aug.shape[1])
    rows = bitmap_ops.bulk_program(aug, sels, invs, post, block_n=block_n,
                                   interpret=interpret)
    return jax.vmap(policy.mask_tail, in_axes=(0, None))(rows, num_records)
