"""Measured cost model: ``auto`` backend dispatch as a calibrated decision.

Bulk-bitwise filtering is bandwidth-bound, so the right backend for a
query wave is a *measured* property of the host, not a static preference.
This module owns that measurement and the per-wave decision:

  * :class:`Calibration` — per-backend roofline coefficients (sustained
    streamed words/sec on the fused-pass path + fixed per-dispatch
    overhead) plus the host's STREAM-class copy bandwidth.  Measured by
    :func:`measure_calibration` (what ``benchmarks/roofline.py bitmap``
    and the ``engine_backend_sweep`` bench run), persisted as JSON by
    :func:`save_calibration`, and loaded lazily by :func:`get_calibration`
    (path: ``$REPRO_BITMAP_CALIBRATION`` or
    ``results/bitmap_calibration.json``; conservative per-platform
    defaults apply until a measurement exists).
  * :func:`decide` — given the wave's lowered plans, the packed word
    count, the segment count, and optional :class:`~repro.engine.planner.
    KeyStats`, estimate each candidate backend's wall time

        t(b) = dispatches x overhead(b) + streamed_words / words_per_sec(b)

    over the canonically *padded* bucket shapes (what actually executes),
    and pick the cheapest — together with whether common-clause factoring
    shrinks the streamed words (pass-fusion depth) and whether a uniform
    segment chain should stack into one vmapped dispatch per bucket
    (stacking buys ``(S - 1) x dispatches`` overheads for one extra
    stack-copy of the chain at copy bandwidth).  Selectivity estimates
    enter as the expected result-materialization term and are surfaced in
    the decision's ``terms`` (and through ``BitmapDB.explain``).

Decisions never change a result bit — every candidate is bit-identical
(the differential sweep gates that); the model only chooses which
executor cache key a wave lands on, so a mid-traffic switch costs nothing
once :meth:`repro.serve.service.BitmapService.warmup` has pre-compiled
the candidates.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Iterable, Mapping, Sequence

import jax

from repro.engine import backends, planner
from repro.obs import metrics as _obs_metrics

# cost-model observability: calls vs computed = memo hit rate (the
# decision memo is process-global, so its meters are too)
_DECIDE_CALLS = _obs_metrics.GLOBAL.counter(
    "costmodel_decide_calls_total", "auto-dispatch decisions requested")
_DECIDE_COMPUTED = _obs_metrics.GLOBAL.counter(
    "costmodel_decisions_computed_total",
    "decisions actually derived (memo misses + uncacheable)")

ENV_PATH = "REPRO_BITMAP_CALIBRATION"
DEFAULT_PATH = os.path.join("results", "bitmap_calibration.json")
CALIBRATION_VERSION = 1

#: Candidates are backends within this factor of the fastest calibrated
#: words/sec — a backend three orders of magnitude off (the interpreted
#: Pallas path on CPU) is never worth warming or considering.
CANDIDATE_CUTOFF = 32.0


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    """Roofline coefficients of one backend on this host."""
    words_per_sec: float          # sustained streamed uint32 words/sec
    dispatch_overhead_s: float    # fixed cost per compiled-executor call


@dataclasses.dataclass(frozen=True)
class Calibration:
    """One host's measured (or default) bitmap-path roofline."""
    profiles: tuple[tuple[str, BackendProfile], ...]
    copy_bytes_per_sec: float     # STREAM-class copy bandwidth (r+w bytes)
    platform: str                 # jax.default_backend() at measurement
    source: str = "default"       # "default" | "measured"

    def profile(self, name: str) -> BackendProfile | None:
        for n, p in self.profiles:
            if n == name:
                return p
        return None

    def to_json(self) -> str:
        return json.dumps({
            "version": CALIBRATION_VERSION,
            "platform": self.platform,
            "source": self.source,
            "copy_bytes_per_sec": self.copy_bytes_per_sec,
            "backends": {n: {"words_per_sec": p.words_per_sec,
                             "dispatch_overhead_s": p.dispatch_overhead_s}
                         for n, p in self.profiles},
        }, indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "Calibration":
        d = json.loads(text)
        if d.get("version") != CALIBRATION_VERSION:
            raise ValueError(f"calibration version {d.get('version')!r} "
                             f"!= {CALIBRATION_VERSION}")
        profs = tuple(sorted(
            (n, BackendProfile(float(p["words_per_sec"]),
                               float(p["dispatch_overhead_s"])))
            for n, p in d["backends"].items()))
        return cls(profs, float(d["copy_bytes_per_sec"]),
                   str(d.get("platform", "cpu")),
                   str(d.get("source", "measured")))


# Uninformed priors, used only until a measurement exists.  The shapes of
# these numbers matter more than their values: on CPU the interpreted
# Pallas path is orders of magnitude off (never a candidate), the bulk
# sweep beats the per-pass path on big rows but pays slightly more fixed
# setup; on TPU the compiled kernels lead.
_DEFAULTS = {
    "cpu": (
        ("bulk", BackendProfile(3.0e9, 6e-5)),
        ("pallas", BackendProfile(5.0e5, 2e-3)),
        ("ref", BackendProfile(2.0e9, 4e-5)),
    ),
    "tpu": (
        ("bulk", BackendProfile(1.8e11, 4e-5)),
        ("pallas", BackendProfile(1.5e11, 3e-5)),
        ("ref", BackendProfile(1.0e11, 3e-5)),
    ),
}
_DEFAULT_COPY = {"cpu": 1.0e10, "tpu": 8.19e11}


def _platform_default() -> Calibration:
    plat = jax.default_backend()
    key = plat if plat in _DEFAULTS else "cpu"
    return Calibration(_DEFAULTS[key], _DEFAULT_COPY[key], plat, "default")


def calibration_path() -> str:
    return os.environ.get(ENV_PATH, DEFAULT_PATH)


_active: Calibration | None = None


def get_calibration() -> Calibration:
    """The process-wide calibration: an explicit :func:`set_calibration`
    override, else the persisted measurement at :func:`calibration_path`,
    else the per-platform defaults."""
    global _active
    if _active is None:
        path = calibration_path()
        if os.path.exists(path):
            try:
                with open(path) as f:
                    _active = Calibration.from_json(f.read())
            except (ValueError, KeyError, OSError):
                _active = _platform_default()
        else:
            _active = _platform_default()
    return _active


def set_calibration(cal: Calibration | None) -> None:
    """Install (or with ``None`` reset) the active calibration."""
    global _active
    _active = cal


def load_calibration(path: str) -> Calibration:
    with open(path) as f:
        return Calibration.from_json(f.read())


def save_calibration(cal: Calibration, path: str | None = None) -> str:
    """Persist a calibration as JSON (atomic tmp+replace); returns the
    path written."""
    path = path or calibration_path()
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(cal.to_json())
    os.replace(tmp, path)
    return path


def candidates(cal: Calibration | None = None) -> tuple[str, ...]:
    """Backends worth considering (and pre-warming) on this host:
    registered, calibrated, and within :data:`CANDIDATE_CUTOFF` of the
    fastest calibrated words/sec."""
    cal = cal or get_calibration()
    regs = set(backends.available_backends()) - {"auto"}
    profs = [(n, p) for n, p in cal.profiles if n in regs]
    if not profs:
        return (backends.resolve_backend("auto"),)
    best = max(p.words_per_sec for _, p in profs)
    out = tuple(sorted(n for n, p in profs
                       if p.words_per_sec * CANDIDATE_CUTOFF >= best))
    return out or (backends.resolve_backend("auto"),)


# ------------------------------------------------------------------ decision
@dataclasses.dataclass(frozen=True)
class Decision:
    """One wave's cost-model choice (never affects result bits)."""
    backend: str
    factor: bool                  # apply common-clause factoring first
    stack_uniform: bool           # stack a uniform segment chain
    estimates: tuple[tuple[str, float], ...]   # per-candidate seconds
    terms: Mapping[str, float]    # the model's inputs, for explain()

    @property
    def est_seconds(self) -> float:
        return dict(self.estimates)[self.backend]


def _bucket_shapes(plans: Sequence) -> tuple[dict, int, int]:
    """Canonical padded bucket histogram of a wave: {(g, p, l): count},
    plus composite-fallback and contradiction counts.  Uses the batch
    layer's lowering cache, so a steady-state wave costs dict probes."""
    from repro.engine import batch  # deferred: batch imports this module
    shapes: dict[tuple[int, int, int], int] = {}
    composite = zeros = 0
    for pl in plans:
        if isinstance(pl, planner.CompositePlan):
            composite += 1
            continue
        _, shape, _, _ = batch._lowered(pl)
        if shape is None:
            zeros += 1
        else:
            shapes[shape] = shapes.get(shape, 0) + 1
    return shapes, composite, zeros


def _pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _streamed_words(shapes: dict, nw: int) -> float:
    """Words the padded bucket dispatches move: every literal slot reads
    ``nw`` operand words per query of the (pow2-padded) bucket, plus one
    result-row write per query."""
    return float(sum(_pow2(q) * (g * p * l + 1) * nw
                     for (g, p, l), q in shapes.items()))


def _maybe_factored(plans: Sequence) -> list | None:
    """Factored twins of a wave's plans, or None when no plan has more
    than one clause (factoring can't help)."""
    if not any(isinstance(pl, planner.QueryPlan) and len(pl.clauses) > 1
               for pl in plans):
        return None
    return [planner.factor(pl)
            if isinstance(pl, planner.QueryPlan) and pl.clauses else pl
            for pl in plans]


def estimate_matches(plans: Sequence, stats: planner.KeyStats | None
                     ) -> float | None:
    """Expected matching records across a wave (union bound per plan):
    the result-materialization term, and what ``explain`` reports."""
    if stats is None:
        return None
    total = 0.0
    for pl in plans:
        if isinstance(pl, planner.QueryPlan):
            est = sum(stats.clause_estimate(c) for c in pl.clauses)
        elif isinstance(pl, planner.FactoredPlan):
            est = sum(stats.clause_estimate(c) if c else stats.num_records
                      for c, _ in pl.groups)
        else:                     # composite: no cheap bound
            est = stats.num_records
        total += min(float(est), float(stats.num_records))
    return total


def decide(plans: Sequence, *, num_words: int, num_segments: int = 1,
           num_keys: int | None = None,
           stats: planner.KeyStats | None = None,
           cal: Calibration | None = None,
           allow_factor: bool = True) -> Decision:
    """Choose (backend, factoring, segment stacking) for one wave of
    lowered plans over an index of ``num_words`` packed words per segment
    (``num_segments`` uniform segments).  Pure host arithmetic — no
    device work; the heavy inputs come from the batch layer's caches, and
    the whole decision memoizes on the wave's plan tuple: a steady-state
    serving loop re-submitting the same plans pays one cache probe, not
    a re-derivation (a re-registered backend set or new calibration is
    part of the key, so neither ever serves a stale choice)."""
    _DECIDE_CALLS.inc()
    cal = cal or get_calibration()
    try:
        return _decide_cached(tuple(plans), num_words, num_segments,
                              num_keys, stats, cal, allow_factor,
                              backends.available_backends())
    except TypeError:            # unhashable plan object: decide uncached
        return _decide_impl(plans, num_words, num_segments, num_keys,
                            stats, cal, allow_factor)


@functools.lru_cache(maxsize=512)
def _decide_cached(plans, num_words, num_segments, num_keys, stats, cal,
                   allow_factor, _registered):
    return _decide_impl(plans, num_words, num_segments, num_keys, stats,
                        cal, allow_factor)


def _decide_impl(plans, num_words, num_segments, num_keys, stats, cal,
                 allow_factor) -> Decision:
    _DECIDE_COMPUTED.inc()
    cands = candidates(cal)
    shapes, composite, zeros = _bucket_shapes(plans)
    words_plain = _streamed_words(shapes, num_words)

    factored = _maybe_factored(plans) if allow_factor else None
    use_factor = False
    shapes_used = shapes
    words = words_plain
    if factored is not None:
        shapes_f, _, _ = _bucket_shapes(factored)
        words_f = _streamed_words(shapes_f, num_words)
        # factoring trades fewer streamed words for (usually) deeper
        # 2-pass buckets; adopt it only on a real word reduction
        if words_f < words_plain * 0.95:
            use_factor = True
            shapes_used = shapes_f
            words = words_f

    n_buckets = max(len(shapes_used), 1) if shapes_used else 0
    n_buckets += composite            # composites dispatch out-of-band
    s = max(int(num_segments), 1)
    total_words = words * s
    # stacking a uniform chain: one stack-copy of the whole chain
    # (S x M x Nw words read + written) buys (S-1) x buckets dispatches
    stack_bytes = 0.0
    if s > 1 and num_keys is not None:
        stack_bytes = 2.0 * s * num_keys * num_words * 4.0

    est: list[tuple[str, float]] = []
    est_stacked: dict[str, float] = {}
    for name in cands:
        prof = cal.profile(name)
        if prof is None:
            continue
        t_work = total_words / max(prof.words_per_sec, 1.0)
        t_flat = n_buckets * s * prof.dispatch_overhead_s + t_work
        if s > 1:
            t_stk = (n_buckets * prof.dispatch_overhead_s + t_work
                     + stack_bytes / max(cal.copy_bytes_per_sec, 1.0))
            est_stacked[name] = t_stk
            est.append((name, min(t_flat, t_stk)))
        else:
            est.append((name, t_flat))
    if not est:                       # calibration names nothing usable
        name = backends.resolve_backend("auto")
        return Decision(name, False, True, ((name, 0.0),),
                        {"streamed_words": total_words})
    best, t_best = min(est, key=lambda kv: (kv[1], kv[0]))
    stack = s > 1 and est_stacked.get(best, float("inf")) <= t_best + 1e-12

    terms: dict[str, float] = {
        "streamed_words": total_words,
        "streamed_bytes": total_words * 4.0,
        "buckets": float(n_buckets),
        "segments": float(s),
        "queries": float(len(plans)),
        "contradictions": float(zeros),
        "composites": float(composite),
        "words_plain": words_plain * s,
        "copy_bytes_per_sec": cal.copy_bytes_per_sec,
    }
    em = estimate_matches(plans, stats)
    if em is not None:
        terms["est_matches"] = em
        terms["est_selectivity"] = (em / (len(plans) * stats.num_records)
                                    if plans and stats.num_records else 0.0)
    return Decision(best, use_factor, stack, tuple(est), terms)


# -------------------------------------------------------------- measurement
def measure_calibration(*, num_records: int = 1 << 20, num_keys: int = 256,
                        num_queries: int = 64, reps: int = 3,
                        backend_names: Iterable[str] | None = None,
                        probe_seconds: float = 0.5,
                        seed: int = 0) -> Calibration:
    """Measure this host's bitmap-path roofline: STREAM-class copy
    bandwidth plus, per backend, sustained streamed words/sec on a
    representative fused-pass bucket and the fixed per-dispatch overhead.

    Backends whose small probe already exceeds ``probe_seconds`` (the
    interpreted Pallas path on CPU) keep the probe-sized estimate instead
    of paying a full-size run.  Import-time free; runs device work.
    """
    import time

    import jax.numpy as jnp
    import numpy as np

    from repro.engine import batch
    from repro.engine.planner import QueryPlan

    rng = np.random.default_rng(seed)
    nw = max(num_records // 32, 1)
    packed = jnp.asarray(
        rng.integers(0, 2 ** 32, (num_keys, nw), dtype=np.uint32))

    def timed(fn, r=reps):
        jax.block_until_ready(fn())
        best = float("inf")
        for _ in range(r):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    # STREAM-class copy: one read + one write of the whole index
    copy = jax.jit(lambda a: a | jnp.uint32(0))
    t_copy = timed(lambda: copy(packed))
    copy_bps = 2.0 * packed.nbytes / t_copy

    def two_lit_plans(m):
        return [QueryPlan((((int(rng.integers(0, m)), False),
                            (int(rng.integers(0, m)), True)),))
                for _ in range(num_queries)]

    names = tuple(backend_names) if backend_names is not None else tuple(
        sorted(set(backends.available_backends()) - {"auto"}))
    small_nw = 2048
    small = packed[:, :small_nw]
    tiny = packed[:, :16]
    profiles = []
    for name in names:
        plans = two_lit_plans(num_keys)
        words_small = _streamed_words({(1, 1, 2): num_queries}, small_nw)
        t_small = timed(lambda: batch.execute_many(
            small, plans, num_records=small_nw * 32, backend=name), r=1)
        if t_small > probe_seconds:
            wps = words_small / t_small
            t_tiny = t_small * 16 / small_nw  # don't re-run a slow path
        else:
            words = _streamed_words({(1, 1, 2): num_queries}, nw)
            t_full = timed(lambda: batch.execute_many(
                packed, plans, num_records=num_records, backend=name))
            wps = words / t_full
            t_tiny = timed(lambda: batch.execute_many(
                tiny, plans[:1], num_records=16 * 32, backend=name))
        profiles.append((name, BackendProfile(wps, max(t_tiny, 1e-7))))
    return Calibration(tuple(sorted(profiles)), copy_bps,
                       jax.default_backend(), "measured")
