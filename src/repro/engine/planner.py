"""Boolean query planner: predicate trees -> fused bitmap-kernel passes.

The bitmap kernels execute one shape of work natively: a fused
AND-with-per-row-inversion over packed index rows (``Backend.query``).  The
planner maps arbitrary AND/OR/NOT predicate trees onto a *minimal sequence*
of those passes:

  1. normalize to negation normal form (De Morgan pushes NOT to leaves);
  2. distribute to disjunctive normal form — each conjunctive clause is
     exactly one fused kernel pass;
  3. simplify: drop contradictory clauses (``x & ~x``), dedup literals,
     absorb clauses subsumed by a subset clause (``a | (a & b)`` -> ``a``);
  4. OR the per-clause result rows, then apply the canonical tail mask and
     popcount once.

Three serving-path refinements sit on top of the plain DNF pipeline:

  * **Plan-size guard** — DNF distribution is exponential on adversarial
    trees (an AND of k ORs is 2^k clauses).  :func:`plan` estimates the
    clause count *before* distributing and, past ``max_clauses``, falls
    back to a :class:`CompositePlan` that evaluates the offending AND/OR
    node as separate sub-plans whose packed rows combine with ``&``/``|``.
  * **Common-clause factoring** — :func:`factor` groups clauses that differ
    in exactly one literal: ``(a&b&c) | (a&b&d)`` becomes ``a&b & (c|d)``,
    one shared fused pass plus one De-Morgan OR pass instead of one pass
    per clause (pure single-literal clauses ``a|b|c`` collapse to a single
    pass the same way).
  * **Plan-constant cache** — the gather/inversion literal arrays for a
    plan are built once and kept device-resident, keyed on the plan, so a
    hot serving loop never re-uploads ``jnp.asarray`` literals per call.

Compiled executors are jit-cached keyed on *plan shape* (backend, literals
per clause) — two plans with the same shape but different key ids or record
counts share one trace, because the gather indices, inversion flags, and
record count enter as traced arrays.

Predicates compose with Python operators::

    from repro.engine import key
    pred = (key(2) | key(7)) & key(4) & ~key(5)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Sequence, Union

import jax
import jax.numpy as jnp

from repro.engine import backends, policy

# ---------------------------------------------------------- predicate algebra
class Pred:
    """Base predicate; combine with ``&``, ``|``, ``~``."""

    def __and__(self, other: "Pred") -> "Pred":
        return And((self, other))

    def __or__(self, other: "Pred") -> "Pred":
        return Or((self, other))

    def __invert__(self) -> "Pred":
        return Not(self)


@dataclasses.dataclass(frozen=True)
class Key(Pred):
    """Leaf: "the record contains index key ``index``"."""
    index: int


@dataclasses.dataclass(frozen=True)
class And(Pred):
    children: tuple[Pred, ...]


@dataclasses.dataclass(frozen=True)
class Or(Pred):
    children: tuple[Pred, ...]


@dataclasses.dataclass(frozen=True)
class Not(Pred):
    child: Pred


def key(index: int) -> Key:
    return Key(int(index))


def from_include_exclude(include: Sequence[int] = (),
                         exclude: Sequence[int] = ()) -> Pred:
    """The legacy API surface: AND of positive/negated literals."""
    lits: list[Pred] = [key(i) for i in include]
    lits += [~key(i) for i in exclude]
    if not lits:
        raise ValueError("query needs at least one operand row")
    return lits[0] if len(lits) == 1 else And(tuple(lits))


# ------------------------------------------------------------- normalization
Literal = tuple[int, bool]           # (key index, inverted)
Clause = frozenset  # of Literal


@dataclasses.dataclass(frozen=True)
class KeyStats:
    """Per-key set-bit counts — the planner's cardinality estimates.

    ``counts[i]`` is the number of records whose index bit for key row
    ``i`` is set (exactly, or an upper-bound estimate); ``num_records`` is
    the record population the counts were taken over.  When supplied to
    :func:`plan`, DNF clauses execute cheapest-estimated-selectivity first
    instead of fewest-literals first.  Ordering NEVER changes a result bit
    (the clause rows OR together), only which fused pass a short-circuiting
    executor would try first and how plans bucket by shape.
    """
    counts: tuple[int, ...]
    num_records: int

    @classmethod
    def from_counts(cls, counts, num_records: int) -> "KeyStats":
        return cls(tuple(int(c) for c in counts), int(num_records))

    def literal_estimate(self, index: int, inverted: bool) -> int:
        """Estimated matching records for one literal (unknown keys fall
        back to the whole population — no information)."""
        if not 0 <= index < len(self.counts):
            return self.num_records
        c = min(self.counts[index], self.num_records)
        return self.num_records - c if inverted else c

    def clause_estimate(self, clause: Iterable[Literal]) -> int:
        """Upper bound on an AND clause's selectivity: its most selective
        literal bounds the intersection."""
        return min((self.literal_estimate(i, inv) for i, inv in clause),
                   default=self.num_records)


def _dnf(p: Pred, neg: bool) -> frozenset:
    """Disjunctive normal form as a set of conjunctive clauses."""
    if isinstance(p, Key):
        return frozenset({Clause({(p.index, neg)})})
    if isinstance(p, Not):
        return _dnf(p.child, not neg)
    if isinstance(p, (And, Or)):
        if not p.children:
            raise ValueError(f"{type(p).__name__} needs at least one child")
        parts = [_dnf(c, neg) for c in p.children]
        conjunctive = isinstance(p, And) != neg       # De Morgan under neg
        if not conjunctive:
            return frozenset().union(*parts)
        out = {Clause()}
        for part in parts:
            out = {a | b for a in out for b in part}
        return frozenset(out)
    raise TypeError(f"not a predicate: {p!r}")


def _simplify(clauses: Iterable[Clause],
              stats: KeyStats | None = None) -> list[tuple[Literal, ...]]:
    sat = [c for c in clauses
           if not any((i, not inv) in c for i, inv in c)]
    # absorption: a clause subsumed by a subset clause contributes nothing
    kept = [c for c in sat
            if not any(o < c for o in sat)]
    # deterministic cheapest-first ordering: estimated selectivity when
    # per-key stats are available, literal count as the uninformed
    # fallback, lexicographic tiebreak — stable plan shapes / cache keys,
    # and a short-circuit executor can try the cheapest pass first.  The
    # clause order never changes the OR-of-clauses result.
    if stats is None:
        sort_key = lambda c: (len(c), c)                  # noqa: E731
    else:
        sort_key = lambda c: (stats.clause_estimate(c),   # noqa: E731
                              len(c), c)
    return sorted((tuple(sorted(c)) for c in set(kept)), key=sort_key)


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Normalized, simplified DNF: one fused kernel pass per clause."""
    clauses: tuple[tuple[Literal, ...], ...]

    @property
    def shape(self) -> tuple[int, ...]:
        """Literals per pass — the jit-cache key component."""
        return tuple(len(c) for c in self.clauses)

    @property
    def num_passes(self) -> int:
        return len(self.clauses)


@dataclasses.dataclass(frozen=True)
class CompositePlan:
    """Size-guard fallback: AND/OR combination of independently executed
    sub-plans.  Leaf rows are tail-masked, and ``&``/``|`` preserve zeroed
    tail bits, so the combined row needs only a final popcount."""
    op: str                                  # "and" | "or"
    parts: tuple                             # of QueryPlan | CompositePlan

    @property
    def num_passes(self) -> int:
        return sum(p.num_passes for p in self.parts)


@dataclasses.dataclass(frozen=True)
class FactoredPlan:
    """Factored DNF: each group is ``AND(common) & OR(ored)`` (either side
    may be empty, not both); group rows OR together."""
    groups: tuple                # of (common: tuple[Literal], ored: tuple[Literal])

    @property
    def shape(self) -> tuple[tuple[int, int], ...]:
        return tuple((len(c), len(d)) for c, d in self.groups)

    @property
    def num_passes(self) -> int:
        return sum((1 if c else 0) + (1 if d else 0) for c, d in self.groups)


AnyPlan = Union[QueryPlan, FactoredPlan, CompositePlan]

#: Past this many DNF clauses, ``plan`` stops distributing and emits a
#: CompositePlan instead (sub-plans combined by row-wise AND/OR).
DEFAULT_MAX_CLAUSES = 128


def _dnf_size(p: Pred, neg: bool, cap: int) -> int:
    """Clause count full distribution would produce, saturating at cap+1
    (never materializes a clause, so adversarial trees stay cheap)."""
    if isinstance(p, Key):
        return 1
    if isinstance(p, Not):
        return _dnf_size(p.child, not neg, cap)
    sizes = [_dnf_size(c, neg, cap) for c in p.children]
    if isinstance(p, And) != neg:            # conjunctive: sizes multiply
        out = 1
        for s in sizes:
            out *= s
            if out > cap:
                return cap + 1
        return out
    return min(sum(sizes), cap + 1)


def _plan_guarded(p: Pred, neg: bool, max_clauses: int,
                  stats: KeyStats | None) -> AnyPlan:
    if _dnf_size(p, neg, max_clauses) <= max_clauses:
        return QueryPlan(tuple(_simplify(_dnf(p, neg), stats)))
    if isinstance(p, Not):
        return _plan_guarded(p.child, not neg, max_clauses, stats)
    conjunctive = isinstance(p, And) != neg
    parts = tuple(_plan_guarded(c, neg, max_clauses, stats)
                  for c in p.children)
    return CompositePlan("and" if conjunctive else "or", parts)


def plan(pred: Pred, *, max_clauses: int | None = DEFAULT_MAX_CLAUSES,
         stats: KeyStats | None = None) -> AnyPlan:
    """Normalize + simplify a predicate tree into an executable plan.

    Returns a :class:`QueryPlan` whenever the simplified DNF fits in
    ``max_clauses`` clauses; otherwise a :class:`CompositePlan` that keeps
    the offending AND/OR nodes as separate sub-plans instead of distributing
    them (``max_clauses=None`` disables the guard).  ``stats`` (per-key
    set-bit counts, see :class:`KeyStats`) orders the DNF clauses by
    estimated selectivity instead of literal count — result bits are
    identical either way."""
    if max_clauses is None:
        return QueryPlan(tuple(_simplify(_dnf(pred, neg=False), stats)))
    return _plan_guarded(pred, False, max_clauses, stats)


def total_clauses(pl: AnyPlan) -> int:
    """Fused-pass clause count across a plan tree — the quantity the size
    guard bounds per leaf."""
    if isinstance(pl, QueryPlan):
        return len(pl.clauses)
    if isinstance(pl, FactoredPlan):
        return len(pl.groups)
    return sum(total_clauses(p) for p in pl.parts)


def factor(qp: QueryPlan) -> FactoredPlan:
    """Common-clause factoring: clauses that differ in exactly one literal
    share their common AND pass — ``(a&b&c)|(a&b&d)`` -> ``a&b & (c|d)``.

    Greedy largest-group-first; each clause joins at most one group, and
    unfactored clauses pass through as ``(clause, ())`` groups."""
    clauses = qp.clauses
    cand: dict[tuple, list[tuple[int, Literal]]] = {}
    for ci, c in enumerate(clauses):
        cset = frozenset(c)
        for lit in c:
            base = tuple(sorted(cset - {lit}))
            cand.setdefault(base, []).append((ci, lit))
    used: set[int] = set()
    groups: list[tuple[tuple, tuple]] = []
    for base, members in sorted(cand.items(),
                                key=lambda kv: (-len(kv[1]), kv[0])):
        live = [(ci, lit) for ci, lit in members if ci not in used]
        if len(live) < 2:
            continue
        used.update(ci for ci, _ in live)
        groups.append((base, tuple(sorted(lit for _, lit in live))))
    groups += [(c, ()) for ci, c in enumerate(clauses) if ci not in used]
    return FactoredPlan(tuple(sorted(groups)))


def key_indices(pred: Pred) -> set[int]:
    """Every key index mentioned anywhere in a predicate tree (including
    branches that normalization would simplify away)."""
    if isinstance(pred, Key):
        return {pred.index}
    if isinstance(pred, Not):
        return key_indices(pred.child)
    if isinstance(pred, (And, Or)):
        out: set[int] = set()
        for c in pred.children:
            out |= key_indices(c)
        return out
    raise TypeError(f"not a predicate: {pred!r}")


# ----------------------------------------------------------------- execution
@functools.lru_cache(maxsize=256)
def _compiled(backend_name: str, shape: tuple[int, ...]):
    """One jitted executor per (backend, plan shape).  The record count
    enters traced, so record-count changes alone never retrace; jit still
    retraces when the packed *word* count (ceil(N/32)) grows, e.g. a
    streaming append that crosses a 32-record boundary."""
    backend = backends.get_backend(backend_name)

    def run(packed, num_records, sels, invs):
        nw = packed.shape[1]
        acc = jnp.zeros((nw,), jnp.uint32)
        for sel, inv in zip(sels, invs):
            row, _ = backend.query(packed[sel], inv)
            acc = acc | row
        return policy.mask_tail(acc, num_records)

    return jax.jit(run)


@functools.lru_cache(maxsize=256)
def _compiled_factored(backend_name: str,
                       shape: tuple[tuple[int, int], ...]):
    """Executor for factored plans: per group one shared AND pass over the
    common literals plus one De-Morgan pass for the OR'd literals
    (``OR(lits) == ~AND(~lits)``; the caller pre-flips those inversion
    flags).  Same shape-keyed jit caching as the plain executor."""
    backend = backends.get_backend(backend_name)

    def run(packed, num_records, consts):
        nw = packed.shape[1]
        acc = jnp.zeros((nw,), jnp.uint32)
        for c_sel, c_inv, d_sel, d_inv in consts:
            if c_sel is not None:
                row, _ = backend.query(packed[c_sel], c_inv)
            else:
                row = jnp.full((nw,), 0xFFFFFFFF, dtype=jnp.uint32)
            if d_sel is not None:
                r, _ = backend.query(packed[d_sel], d_inv)
                row = row & ~r
            acc = acc | row
        return policy.mask_tail(acc, num_records)

    return jax.jit(run)


@functools.lru_cache(maxsize=4096)
def _plan_constants(clauses: tuple):
    """Device-resident gather/inversion literal arrays, keyed on the plan's
    clauses — a hot serving loop re-executing a plan never re-uploads them."""
    sels = tuple(jnp.asarray([i for i, _ in c], jnp.int32) for c in clauses)
    invs = tuple(jnp.asarray([int(inv) for _, inv in c], jnp.int32)
                 for c in clauses)
    return sels, invs


@functools.lru_cache(maxsize=4096)
def _factored_constants(groups: tuple):
    """Device-resident constants for a factored plan; OR-side inversion
    flags enter pre-flipped for the De-Morgan pass."""
    out = []
    for common, ored in groups:
        c_sel = jnp.asarray([i for i, _ in common], jnp.int32) if common else None
        c_inv = (jnp.asarray([int(v) for _, v in common], jnp.int32)
                 if common else None)
        d_sel = jnp.asarray([i for i, _ in ored], jnp.int32) if ored else None
        d_inv = (jnp.asarray([int(not v) for _, v in ored], jnp.int32)
                 if ored else None)
        out.append((c_sel, c_inv, d_sel, d_inv))
    return tuple(out)


def compiled_plan_cache_info():
    """Exposed for tests/benchmarks: the executor cache statistics."""
    return _compiled.cache_info()


def plan_constant_cache_info():
    """Exposed for tests/benchmarks: the plan-constant cache statistics."""
    return _plan_constants.cache_info()


def check_key_range(mentioned: Iterable[int], num_keys: int) -> None:
    """Raise on any key id outside [0, num_keys) — a silent jnp gather
    clamp would mis-select, and the batch layer's virtual identity row
    lives at index ``num_keys``."""
    bad = sorted(i for i in mentioned if not 0 <= i < num_keys)
    if bad:
        raise ValueError(f"key indices {bad} out of range for an index "
                         f"with {num_keys} keys")


def plan_key_indices(pl: AnyPlan) -> set[int]:
    """Every key index a compiled plan gathers."""
    if isinstance(pl, QueryPlan):
        return {i for c in pl.clauses for i, _ in c}
    if isinstance(pl, FactoredPlan):
        return {i for c, d in pl.groups for i, _ in (*c, *d)}
    out: set[int] = set()
    for p in pl.parts:
        out |= plan_key_indices(p)
    return out


def _run(packed: jax.Array, pl: AnyPlan, num_records: int, name: str
         ) -> tuple[jax.Array, jax.Array]:
    nw = packed.shape[1]
    if isinstance(pl, QueryPlan):
        if not pl.clauses:   # contradiction: provably empty, no kernel pass
            return (jnp.zeros((nw,), jnp.uint32), jnp.zeros((), jnp.int32))
        sels, invs = _plan_constants(pl.clauses)
        return _compiled(name, pl.shape)(packed, jnp.int32(num_records),
                                         sels, invs)
    if isinstance(pl, FactoredPlan):
        if not pl.groups:
            return (jnp.zeros((nw,), jnp.uint32), jnp.zeros((), jnp.int32))
        consts = _factored_constants(pl.groups)
        return _compiled_factored(name, pl.shape)(
            packed, jnp.int32(num_records), consts)
    row = _composite_row(packed, pl, num_records, name)
    count = jax.lax.population_count(row).astype(jnp.int32).sum()
    return row, count


def _composite_row(packed, node, num_records, name):
    """Leaf rows come back tail-masked, and AND/OR preserve zeroed tails, so
    the composite needs no second mask pass."""
    if not isinstance(node, CompositePlan):
        return _run(packed, node, num_records, name)[0]
    rows = [_composite_row(packed, p, num_records, name) for p in node.parts]
    out = rows[0]
    for r in rows[1:]:
        out = (out & r) if node.op == "and" else (out | r)
    return out


def execute(packed: jax.Array, predicate: Union[Pred, AnyPlan], *,
            num_records: int, backend: str = "auto"
            ) -> tuple[jax.Array, jax.Array]:
    """Run a predicate (or pre-built plan) over a packed (M, Nw) index.

    Returns (packed result row (Nw,) uint32, matching-record count), with
    tail bits past ``num_records`` masked to zero.

    ``backend="auto"`` routes through the measured cost model
    (:mod:`repro.engine.costmodel`) — a per-call choice of the cheapest
    calibrated backend for this plan shape and word count.
    """
    if isinstance(predicate, (QueryPlan, FactoredPlan, CompositePlan)):
        pl = predicate
        mentioned = plan_key_indices(pl)
    else:
        # validate on the raw tree, BEFORE simplification, so a typo'd id
        # inside a contradictory/absorbed branch still raises
        mentioned = key_indices(predicate)
        pl = plan(predicate)
    if backend == "auto":
        from repro.engine import costmodel  # deferred: costmodel imports us
        name = costmodel.decide([pl], num_words=packed.shape[1],
                                num_keys=packed.shape[0],
                                allow_factor=False).backend
    else:
        name = backends.resolve_backend(backend)
    check_key_range(mentioned, packed.shape[0])
    return _run(packed, pl, num_records, name)


def evaluate_dense(pred: Pred, dense: "jnp.ndarray") -> "jnp.ndarray":
    """Reference semantics on a dense (M, N) {0,1} matrix — test oracle."""
    import numpy as np
    d = np.asarray(dense).astype(bool)

    def ev(p) -> np.ndarray:
        if isinstance(p, Key):
            return d[p.index]
        if isinstance(p, Not):
            return ~ev(p.child)
        if isinstance(p, And):
            return functools.reduce(lambda a, b: a & b,
                                    (ev(c) for c in p.children))
        if isinstance(p, Or):
            return functools.reduce(lambda a, b: a | b,
                                    (ev(c) for c in p.children))
        raise TypeError(f"not a predicate: {p!r}")

    return ev(pred)
