"""Boolean query planner: predicate trees -> fused bitmap-kernel passes.

The bitmap kernels execute one shape of work natively: a fused
AND-with-per-row-inversion over packed index rows (``Backend.query``).  The
planner maps arbitrary AND/OR/NOT predicate trees onto a *minimal sequence*
of those passes:

  1. normalize to negation normal form (De Morgan pushes NOT to leaves);
  2. distribute to disjunctive normal form — each conjunctive clause is
     exactly one fused kernel pass;
  3. simplify: drop contradictory clauses (``x & ~x``), dedup literals,
     absorb clauses subsumed by a subset clause (``a | (a & b)`` -> ``a``);
  4. OR the per-clause result rows, then apply the canonical tail mask and
     popcount once.

Compiled executors are jit-cached keyed on *plan shape* (backend, literals
per clause) — two plans with the same shape but different key ids or record
counts share one trace, because the gather indices, inversion flags, and
record count enter as traced arrays.

Predicates compose with Python operators::

    from repro.engine import key
    pred = (key(2) | key(7)) & key(4) & ~key(5)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Sequence, Union

import jax
import jax.numpy as jnp

from repro.engine import backends, policy

# ---------------------------------------------------------- predicate algebra
class Pred:
    """Base predicate; combine with ``&``, ``|``, ``~``."""

    def __and__(self, other: "Pred") -> "Pred":
        return And((self, other))

    def __or__(self, other: "Pred") -> "Pred":
        return Or((self, other))

    def __invert__(self) -> "Pred":
        return Not(self)


@dataclasses.dataclass(frozen=True)
class Key(Pred):
    """Leaf: "the record contains index key ``index``"."""
    index: int


@dataclasses.dataclass(frozen=True)
class And(Pred):
    children: tuple[Pred, ...]


@dataclasses.dataclass(frozen=True)
class Or(Pred):
    children: tuple[Pred, ...]


@dataclasses.dataclass(frozen=True)
class Not(Pred):
    child: Pred


def key(index: int) -> Key:
    return Key(int(index))


def from_include_exclude(include: Sequence[int] = (),
                         exclude: Sequence[int] = ()) -> Pred:
    """The legacy API surface: AND of positive/negated literals."""
    lits: list[Pred] = [key(i) for i in include]
    lits += [~key(i) for i in exclude]
    if not lits:
        raise ValueError("query needs at least one operand row")
    return lits[0] if len(lits) == 1 else And(tuple(lits))


# ------------------------------------------------------------- normalization
Literal = tuple[int, bool]           # (key index, inverted)
Clause = frozenset  # of Literal


def _dnf(p: Pred, neg: bool) -> frozenset:
    """Disjunctive normal form as a set of conjunctive clauses."""
    if isinstance(p, Key):
        return frozenset({Clause({(p.index, neg)})})
    if isinstance(p, Not):
        return _dnf(p.child, not neg)
    if isinstance(p, (And, Or)):
        if not p.children:
            raise ValueError(f"{type(p).__name__} needs at least one child")
        parts = [_dnf(c, neg) for c in p.children]
        conjunctive = isinstance(p, And) != neg       # De Morgan under neg
        if not conjunctive:
            return frozenset().union(*parts)
        out = {Clause()}
        for part in parts:
            out = {a | b for a in out for b in part}
        return frozenset(out)
    raise TypeError(f"not a predicate: {p!r}")


def _simplify(clauses: Iterable[Clause]) -> list[tuple[Literal, ...]]:
    sat = [c for c in clauses
           if not any((i, not inv) in c for i, inv in c)]
    # absorption: a clause subsumed by a subset clause contributes nothing
    kept = [c for c in sat
            if not any(o < c for o in sat)]
    # deterministic ordering for stable plan shapes / cache keys
    return sorted(tuple(sorted(c)) for c in set(kept))


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Normalized, simplified DNF: one fused kernel pass per clause."""
    clauses: tuple[tuple[Literal, ...], ...]

    @property
    def shape(self) -> tuple[int, ...]:
        """Literals per pass — the jit-cache key component."""
        return tuple(len(c) for c in self.clauses)

    @property
    def num_passes(self) -> int:
        return len(self.clauses)


def plan(pred: Pred) -> QueryPlan:
    """Normalize + simplify a predicate tree into an executable plan."""
    return QueryPlan(tuple(_simplify(_dnf(pred, neg=False))))


def key_indices(pred: Pred) -> set[int]:
    """Every key index mentioned anywhere in a predicate tree (including
    branches that normalization would simplify away)."""
    if isinstance(pred, Key):
        return {pred.index}
    if isinstance(pred, Not):
        return key_indices(pred.child)
    if isinstance(pred, (And, Or)):
        out: set[int] = set()
        for c in pred.children:
            out |= key_indices(c)
        return out
    raise TypeError(f"not a predicate: {pred!r}")


# ----------------------------------------------------------------- execution
@functools.lru_cache(maxsize=256)
def _compiled(backend_name: str, shape: tuple[int, ...]):
    """One jitted executor per (backend, plan shape).  The record count
    enters traced, so record-count changes alone never retrace; jit still
    retraces when the packed *word* count (ceil(N/32)) grows, e.g. a
    streaming append that crosses a 32-record boundary."""
    backend = backends.get_backend(backend_name)

    def run(packed, num_records, sels, invs):
        nw = packed.shape[1]
        acc = jnp.zeros((nw,), jnp.uint32)
        for sel, inv in zip(sels, invs):
            row, _ = backend.query(packed[sel], inv)
            acc = acc | row
        return policy.mask_tail(acc, num_records)

    return jax.jit(run)


def compiled_plan_cache_info():
    """Exposed for tests/benchmarks: the executor cache statistics."""
    return _compiled.cache_info()


def execute(packed: jax.Array, predicate: Union[Pred, QueryPlan], *,
            num_records: int, backend: str = "auto"
            ) -> tuple[jax.Array, jax.Array]:
    """Run a predicate (or pre-built plan) over a packed (M, Nw) index.

    Returns (packed result row (Nw,) uint32, matching-record count), with
    tail bits past ``num_records`` masked to zero.
    """
    if isinstance(predicate, QueryPlan):
        pl = predicate
        mentioned = {i for c in pl.clauses for i, _ in c}
    else:
        # validate on the raw tree, BEFORE simplification, so a typo'd id
        # inside a contradictory/absorbed branch still raises
        mentioned = key_indices(predicate)
        pl = plan(predicate)
    name = backends.resolve_backend(backend)
    num_keys = packed.shape[0]
    bad = sorted(i for i in mentioned if not 0 <= i < num_keys)
    if bad:                  # a silent jnp gather clamp would mis-select
        raise ValueError(f"key indices {bad} out of range for an index "
                         f"with {num_keys} keys")
    nw = packed.shape[1]
    if not pl.clauses:       # contradiction: provably empty, no kernel pass
        return (jnp.zeros((nw,), jnp.uint32), jnp.zeros((), jnp.int32))
    sels = tuple(jnp.asarray([i for i, _ in c], jnp.int32)
                 for c in pl.clauses)
    invs = tuple(jnp.asarray([int(inv) for _, inv in c], jnp.int32)
                 for c in pl.clauses)
    return _compiled(name, pl.shape)(packed, jnp.int32(num_records),
                                     sels, invs)


def evaluate_dense(pred: Pred, dense: "jnp.ndarray") -> "jnp.ndarray":
    """Reference semantics on a dense (M, N) {0,1} matrix — test oracle."""
    import numpy as np
    d = np.asarray(dense).astype(bool)

    def ev(p) -> np.ndarray:
        if isinstance(p, Key):
            return d[p.index]
        if isinstance(p, Not):
            return ~ev(p.child)
        if isinstance(p, And):
            return functools.reduce(lambda a, b: a & b,
                                    (ev(c) for c in p.children))
        if isinstance(p, Or):
            return functools.reduce(lambda a, b: a | b,
                                    (ev(c) for c in p.children))
        raise TypeError(f"not a predicate: {p!r}")

    return ev(pred)
