"""``repro.engine`` — the single execution layer for bitmap indexing.

Three tiers (see ARCHITECTURE.md):

  * :mod:`repro.engine.policy`   — canonical padding/sentinel policy and the
    packed :class:`BitmapIndex` container.
  * :mod:`repro.engine.backends` — backend registry (``pallas`` / ``ref`` /
    ``auto``) behind one ``create_index`` / ``query`` interface.
  * :mod:`repro.engine.planner`  — boolean query planner: AND/OR/NOT
    predicate trees normalized to DNF and compiled to a minimal sequence of
    fused bitmap-kernel passes, with jit caching keyed on plan shape, a
    DNF size guard (composite sub-plans for adversarial trees),
    common-clause factoring, and a device-resident plan-constant cache.
  * :mod:`repro.engine.batch`    — batched query serving: many predicate
    trees per dispatch via plan-shape bucketing, identity-row padding, and
    vmapped jit-cached bucket executors.
  * :mod:`repro.engine.bulk`     — the ``bulk`` backend's execution core:
    whole pass programs as fused multi-word sweeps (pure-jnp fallback on
    CPU, word-tiled Pallas kernel on TPU).
  * :mod:`repro.engine.costmodel` — measured roofline cost model behind
    ``backend="auto"``: persisted per-backend calibration plus a per-wave
    decision (backend, factoring, segment stacking).
  * :mod:`repro.engine.runtime`  — streaming multi-core runtime: incremental
    index append (jitted shift/carry splice, scanned batch appends) and
    shard_map dispatch fused with elastic energy accounting.

Symbols are resolved lazily so that lower layers (``repro.kernels.ops``
imports the policy; ``repro.core`` imports backends/planner; the runtime
imports ``repro.core.elastic``) never form an import cycle through this
package ``__init__``.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # policy
    "PACK": "policy", "RECORD_SENTINEL": "policy", "KEY_SENTINEL": "policy",
    "BitmapIndex": "policy", "mask_tail": "policy",
    # backends
    "Backend": "backends", "register_backend": "backends",
    "get_backend": "backends", "resolve_backend": "backends",
    "available_backends": "backends",
    # planner
    "Pred": "planner", "Key": "planner", "And": "planner", "Or": "planner",
    "Not": "planner", "key": "planner", "plan": "planner",
    "QueryPlan": "planner", "CompositePlan": "planner",
    "FactoredPlan": "planner", "factor": "planner",
    "total_clauses": "planner", "execute": "planner",
    "from_include_exclude": "planner", "KeyStats": "planner",
    # batch
    "execute_many": "batch", "execute_many_segments": "batch",
    # costmodel
    "decide": "costmodel", "Decision": "costmodel",
    "Calibration": "costmodel", "BackendProfile": "costmodel",
    "get_calibration": "costmodel", "set_calibration": "costmodel",
    "measure_calibration": "costmodel",
    # runtime
    "StreamingIndexer": "runtime", "MulticoreRuntime": "runtime",
    "multicore_create_index": "runtime", "append_packed": "runtime",
    "fold_block_indexes": "runtime",
}

__all__ = sorted(_EXPORTS) + ["policy", "backends", "planner", "batch",
                              "bulk", "costmodel", "runtime"]


def __getattr__(name):
    if name in ("policy", "backends", "planner", "batch", "bulk",
                "costmodel", "runtime"):
        return importlib.import_module(f"{__name__}.{name}")
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(f"{__name__}.{mod}"), name)


def __dir__():
    return __all__
