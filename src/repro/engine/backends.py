"""Backend registry — every index build and query pass dispatches here.

A :class:`Backend` pairs the two primitive operations the engine needs:

  * ``create_index(records (N, W) int, keys (M,) int)``
      -> key-major packed bitmap (M, ceil(N/32)) uint32, with all pad bits
      past N guaranteed zero (the canonical sentinel policy ensures padded
      records match nothing);
  * ``query(rows (K, Nw) uint32, invert (K,) int)``
      -> (result row (Nw,) uint32, popcount) for AND_k (invert_k ? ~r : r).
      Tail bits past the logical record count are NOT masked here — the
      planner applies :func:`repro.engine.policy.mask_tail` exactly once per
      compiled plan.

A backend may additionally provide ``run_program`` — a whole-bucket
executor with the batched layer's call contract (augmented index, record
count, ``(Q, G, P, L)`` selector arrays, post xor masks -> rows + counts).
When present, :mod:`repro.engine.batch` jits IT as the bucket executor
instead of composing per-pass ``query`` calls — the hook a bulk-bitwise
path needs to fuse a whole pass program into one multi-word sweep.

Built-ins: ``pallas`` (the TPU kernels; interpret mode off-TPU), ``ref``
(the pure-jnp oracle) and ``bulk`` (the tiled bulk-bitwise sweep of
:mod:`repro.engine.bulk` — Pallas word-tiled kernel on TPU, pure-jnp tile
sweep elsewhere).  ``auto`` without workload information resolves to
``pallas`` on TPU and ``ref`` elsewhere — vmapping interpreted Pallas
kernels on CPU is strictly slower than the oracle; the workload-aware
call sites (``planner.execute``, ``engine.batch``, ``repro.db``) instead
route ``auto`` through the measured cost model
(:mod:`repro.engine.costmodel`).  New backends (e.g. a future GPU or
bit-sliced CPU path) register with :func:`register_backend`.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Protocol

import jax

from repro.engine import bulk, policy
from repro.kernels import ops, ref


class _CreateFn(Protocol):
    def __call__(self, records: jax.Array, keys: jax.Array) -> jax.Array: ...


class _QueryFn(Protocol):
    def __call__(self, rows: jax.Array, invert: jax.Array
                 ) -> tuple[jax.Array, jax.Array]: ...


class _ProgramFn(Protocol):
    def __call__(self, aug: jax.Array, num_records, sels: jax.Array,
                 invs: jax.Array, post: jax.Array
                 ) -> tuple[jax.Array, jax.Array]: ...


@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    create_index: _CreateFn
    query: _QueryFn
    #: optional whole-bucket executor (see module docstring); backends
    #: without one get the per-pass bucket body composed around ``query``
    run_program: _ProgramFn | None = None


_REGISTRY: dict[str, Backend] = {}


# Compiled executors (sequential, factored, batched, stacked, vmapped-
# create) close over Backend objects; re-registering a name must drop them
# so stale backends never keep serving.  getattr-guarded: a module may be
# mid-import.
_COMPILED_CACHES = (
    ("repro.engine.planner", ("_compiled", "_compiled_factored")),
    ("repro.engine.batch", ("_executor", "_stacked_executor")),
    ("repro.engine.runtime", ("_vmapped_create",)),
)


def register_backend(backend: Backend) -> Backend:
    _REGISTRY[backend.name] = backend
    for modname, attrs in _COMPILED_CACHES:
        mod = sys.modules.get(modname)
        for attr in attrs if mod is not None else ():
            cache = getattr(mod, attr, None)
            if cache is not None:
                cache.cache_clear()
    return backend


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY)) + ("auto",)


def resolve_backend(name: str) -> str:
    """Map ``auto`` to a concrete backend for the current jax platform."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if name not in _REGISTRY:
        raise ValueError(f"unknown backend {name!r}; "
                         f"registered: {available_backends()}")
    return name


def get_backend(name: str = "auto") -> Backend:
    return _REGISTRY[resolve_backend(name)]


# ------------------------------------------------------------ built-ins
def _ref_create_index(records: jax.Array, keys: jax.Array) -> jax.Array:
    """Oracle path: pad to PACK multiples with the canonical sentinels, run
    the pure-jnp pipeline, slice back to logical shape."""
    n = records.shape[0]
    m = keys.shape[0]
    packed = ref.create_index(policy.pad_records(records),
                              policy.pad_keys(keys))
    return packed[:m, : policy.num_words(n)]


register_backend(Backend("ref", _ref_create_index, ref.bitmap_query))
register_backend(Backend("pallas", ops.create_index, ops.query))
register_backend(Backend("bulk", bulk.create_index, bulk.query,
                         run_program=bulk.run_program))
