"""Seam-coverage checker: durable I/O in ``store/``/``fabric/`` must be
fault-injectable.

The fault fabric only stays honest if every byte that reaches a disk or
a socket passes a ``repro.fault.seam.fire`` site — otherwise new write
paths silently escape the chaos harness and its "zero acked-write loss"
gate stops meaning anything.  Rule: within any function in ``store/`` or
``fabric/`` that performs raw durable I/O —

  * ``os.fsync(...)`` / ``os.open`` with write flags,
  * builtin ``open(...)`` in a write-capable mode,
  * ``.send``/``.sendall``/``.sendto`` on a ``socket.socket``-typed
    receiver (annotation- or construction-inferred; transport futures'
    ``.send`` is not a socket and is not flagged),
  * ``.write(...)`` on a handle that same function opened or received
    as a ``BinaryIO``/``IO`` parameter (``io.BytesIO`` buffers are not
    I/O and are not flagged),

— the function must also contain a ``seam.fire(...)`` call (or a
``_Gate.admit`` gate, the transport idiom that fires the rpc seams).
Legitimately unseamed paths (e.g. ``fsync_dir`` metadata syncs) live in
the committed baseline with one-line reasons, not in blind spots.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, Tree, checker

__all__ = ["check_seam_coverage"]

_SCOPES = ("src/repro/store/", "src/repro/fabric/")
_SEND = ("send", "sendall", "sendto")
_WRITE_FLAGS = ("O_WRONLY", "O_RDWR", "O_CREAT", "O_APPEND", "O_TRUNC")


def _mode_is_write(call: ast.Call) -> bool:
    mode = None
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
        mode = call.args[1].value
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = kw.value.value
    if not isinstance(mode, str):
        return False
    return any(c in mode for c in "wa+x")


def _os_open_is_write(call: ast.Call) -> bool:
    for node in ast.walk(call):
        if isinstance(node, ast.Attribute) and node.attr in _WRITE_FLAGS:
            return True
    return False


class _Scanner(ast.NodeVisitor):
    """Per-function scan: raw-I/O sites, seam fires, and local handle /
    socket typing."""

    def __init__(self):
        self.io_sites: list[tuple[int, str]] = []   # (line, what)
        self.fires = False
        self.file_handles: set[str] = set()
        self.buffers: set[str] = set()
        self.sockets: set[str] = set()

    def scan(self, fn) -> None:
        for a in fn.args.args + fn.args.kwonlyargs:
            t = a.annotation
            names = [n.id if isinstance(n, ast.Name) else n.attr
                     for n in ast.walk(t)
                     if isinstance(n, (ast.Name, ast.Attribute))] if t \
                else []
            if any(n in ("BinaryIO", "IO", "TextIO") for n in names):
                self.file_handles.add(a.arg)
            if "socket" in names:
                self.sockets.add(a.arg)
        for stmt in fn.body:
            self.visit(stmt)

    # ---- typing from assignments / with-items
    def _bind(self, name: str, value) -> None:
        if not isinstance(value, ast.Call):
            return
        f = value.func
        if isinstance(f, ast.Name) and f.id == "open":
            self.file_handles.add(name)
        elif isinstance(f, ast.Attribute) and f.attr == "BytesIO":
            self.buffers.add(name)
        elif isinstance(f, ast.Attribute) and f.attr == "StringIO":
            self.buffers.add(name)
        elif isinstance(f, ast.Attribute) and f.attr == "socket" and \
                isinstance(f.value, ast.Name) and f.value.id == "socket":
            self.sockets.add(name)

    def visit_Assign(self, node: ast.Assign) -> None:
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            self._bind(node.targets[0].id, node.value)
        self.generic_visit(node)

    def visit_With(self, node: ast.With) -> None:
        for item in node.items:
            if isinstance(item.optional_vars, ast.Name):
                self._bind(item.optional_vars.id, item.context_expr)
        self.generic_visit(node)

    # ---- the interesting calls
    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute):
            recv = f.value
            if f.attr == "fire" and isinstance(recv, ast.Name) and \
                    recv.id in ("seam", "fault_seam"):
                self.fires = True
            elif f.attr == "admit":
                self.fires = True          # transport gate fires rpc seams
            elif f.attr == "fsync" and isinstance(recv, ast.Name) and \
                    recv.id == "os":
                self.io_sites.append((node.lineno, "os.fsync"))
            elif f.attr == "open" and isinstance(recv, ast.Name) and \
                    recv.id == "os" and _os_open_is_write(node):
                self.io_sites.append((node.lineno, "os.open(write)"))
            elif f.attr in _SEND and isinstance(recv, ast.Name) and \
                    recv.id in self.sockets:
                self.io_sites.append((node.lineno, f"socket.{f.attr}"))
            elif f.attr == "write" and isinstance(recv, ast.Name):
                if recv.id in self.file_handles and \
                        recv.id not in self.buffers:
                    self.io_sites.append((node.lineno, "file.write"))
        elif isinstance(f, ast.Name):
            if f.id == "fire":
                self.fires = True
            elif f.id == "open" and _mode_is_write(node):
                self.io_sites.append((node.lineno, "open(write)"))
        self.generic_visit(node)

    def visit_FunctionDef(self, node) -> None:
        pass                                     # nested defs scanned separately

    visit_AsyncFunctionDef = visit_FunctionDef


def _iter_fns(module: ast.Module):
    def rec(node, prefix):
        for child in getattr(node, "body", []):
            if isinstance(child, ast.ClassDef):
                yield from rec(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                yield child, qual
                yield from rec(child, qual)
    yield from rec(module, "")


@checker("seams")
def check_seam_coverage(tree: Tree) -> list[Finding]:
    findings: list[Finding] = []
    for mod in tree.iter():
        if not any(mod.relpath.startswith(s) for s in _SCOPES):
            continue
        for fn, qual in _iter_fns(mod.tree):
            sc = _Scanner()
            sc.scan(fn)
            if sc.fires or not sc.io_sites:
                continue
            for line, what in sc.io_sites:
                findings.append(Finding(
                    "seams", "unseamed-io", mod.relpath, line,
                    f"{qual}:{what}",
                    f"{qual} performs raw {what} without a fault-seam "
                    f"fire in scope — this write path is invisible to "
                    f"the chaos harness"))
    return findings
