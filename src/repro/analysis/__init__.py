"""Domain-aware static analysis for the repro tree.

``python -m repro.analysis`` runs five checkers — lock-order against the
documented hierarchy, fault-seam coverage of durable I/O, JAX hygiene in
jit bodies, span/metric taxonomy, and wire-kind exhaustiveness — plus a
runtime lock-order witness (``repro.analysis.witness``) that
cross-validates the static hierarchy during the test suite.  See
ARCHITECTURE.md "Static analysis" for the baseline workflow.
"""
from repro.analysis.core import (Baseline, Finding, Tree, checker,  # noqa: F401
                                 find_repo_root, run)

__all__ = ["Baseline", "Finding", "Tree", "checker", "find_repo_root",
           "run"]
