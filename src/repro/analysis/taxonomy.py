"""Taxonomy lint: span names and metric names stay on contract.

Spans: every name emitted through ``maybe_span``/``tracer.span``/
``tr.make``/``tracer.event`` must match a row of the ARCHITECTURE.md
span-taxonomy table (parsed, not duplicated here — the docs are the
config).  Table entries may carry ``<kind>`` placeholders and ``.*``
suffixes; f-string span names lint their literal skeleton against them.

Metrics: the naming scheme is ``<layer>_<noun>_total`` for counters and
bare nouns for everything else; one name means one thing — the same
name registered with two different instrument kinds anywhere in the
tree, or registered on the process-global registry from two different
modules, is a collision.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.core import Finding, Tree, checker

__all__ = ["check_taxonomy", "parse_span_taxonomy"]

_TRACERISH = ("tr", "tracer")
_SPAN_METHODS = ("span", "make", "event", "record", "start")
_METRIC_METHODS = ("counter", "gauge", "histogram", "reservoir")


def parse_span_taxonomy(arch_text: str) -> list[str]:
    """Backticked entries of the first column of the span-taxonomy
    table (the markdown table whose header row is ``| span | scope |``)."""
    rows = []
    in_table = False
    for line in arch_text.splitlines():
        s = line.strip()
        if s.startswith("|") and "span" in s and "scope" in s:
            in_table = True
            continue
        if in_table:
            if not s.startswith("|"):
                break
            first = s.split("|")[1]
            rows.extend(re.findall(r"`([^`]+)`", first))
    if not rows:
        raise ValueError("ARCHITECTURE.md span-taxonomy table not found")
    return rows


def _pattern_to_regex(entry: str) -> re.Pattern:
    """Doc entry -> regex: ``<kind>`` matches one+ chars, a trailing
    ``.*`` matches the bare name or any dotted suffix."""
    out = []
    i = 0
    while i < len(entry):
        c = entry[i]
        if c == "<":
            j = entry.index(">", i)
            out.append(r".+")
            i = j + 1
        elif entry[i:i + 2] == ".*":
            out.append(r"(\..+)?")
            i += 2
        else:
            out.append(re.escape(c))
            i += 1
    return re.compile("".join(out) + "$")


def _span_name_of(call: ast.Call) -> tuple[str, bool] | None:
    """First positional arg -> (skeleton, is_pattern); f-string holes
    become a placeholder segment."""
    if not call.args:
        return None
    a = call.args[0]
    if isinstance(a, ast.Constant) and isinstance(a.value, str):
        return a.value, False
    if isinstance(a, ast.JoinedStr):
        parts, holes = [], False
        for v in a.values:
            if isinstance(v, ast.Constant):
                parts.append(str(v.value))
            else:
                parts.append("\0")
                holes = True
        return "".join(parts), holes
    return None


@checker("taxonomy")
def check_taxonomy(tree: Tree) -> list[Finding]:
    arch = tree.doc("ARCHITECTURE.md")
    allowed = [_pattern_to_regex(e) for e in parse_span_taxonomy(arch)]
    findings: list[Finding] = []
    metric_sites: dict[str, list[tuple[str, str, int, bool]]] = {}

    for mod in tree.iter():
        if mod.relpath.endswith("obs/trace.py") or \
                mod.relpath.endswith("obs/metrics.py") or \
                mod.relpath.startswith("src/repro/analysis/"):
            continue                   # the substrate itself, not emitters
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            # ---- spans
            is_span = False
            if isinstance(f, ast.Name) and f.id == "maybe_span":
                is_span = True
            elif isinstance(f, ast.Attribute) and f.attr == "maybe_span":
                is_span = True
            elif isinstance(f, ast.Attribute) and \
                    f.attr in _SPAN_METHODS and \
                    isinstance(f.value, ast.Name) and \
                    f.value.id in _TRACERISH:
                is_span = True
            if is_span:
                got = _span_name_of(node)
                if got is not None:
                    name, is_pat = got
                    probe = name.replace("\0", "X")
                    if not any(rx.match(probe) for rx in allowed):
                        shown = name.replace("\0", "<...>")
                        findings.append(Finding(
                            "taxonomy", "unknown-span", mod.relpath,
                            node.lineno, shown,
                            f"span name {shown!r} is not in the "
                            f"ARCHITECTURE.md span taxonomy"))
                continue
            # ---- metrics
            if isinstance(f, ast.Attribute) and \
                    f.attr in _METRIC_METHODS and node.args:
                a = node.args[0]
                name = None
                if isinstance(a, ast.Constant) and isinstance(a.value, str):
                    name = a.value
                elif isinstance(a, ast.JoinedStr):
                    # dynamic families: lint only the literal suffix
                    tail = a.values[-1]
                    if isinstance(tail, ast.Constant):
                        name = "\0" + str(tail.value)
                if name is None:
                    continue
                is_global = any(
                    isinstance(n, (ast.Name, ast.Attribute)) and
                    (getattr(n, "id", None) == "GLOBAL"
                     or getattr(n, "attr", None) == "GLOBAL")
                    for n in ast.walk(f.value))
                metric_sites.setdefault(name.lstrip("\0"), []).append(
                    (f.attr, mod.relpath, node.lineno, is_global))
                bare = name.lstrip("\0")
                if f.attr == "counter" and not bare.endswith("_total"):
                    findings.append(Finding(
                        "taxonomy", "counter-name", mod.relpath,
                        node.lineno, bare,
                        f"counter {bare!r} must end in '_total' "
                        f"(naming scheme: <layer>_<noun>_total)"))
                elif f.attr != "counter" and bare.endswith("_total"):
                    findings.append(Finding(
                        "taxonomy", "metric-name", mod.relpath,
                        node.lineno, bare,
                        f"{f.attr} {bare!r} must not end in '_total' "
                        f"(reserved for counters)"))

    for name, sites in sorted(metric_sites.items()):
        kinds = {k for k, *_ in sites}
        if len(kinds) > 1:
            k, rel, line, _ = sites[0]
            findings.append(Finding(
                "taxonomy", "metric-collision", rel, line, name,
                f"metric {name!r} registered as multiple kinds "
                f"({', '.join(sorted(kinds))}) — one name, one meaning"))
            continue
        gmods = {rel for _, rel, _, g in sites if g}
        if len(gmods) > 1:
            _, rel, line, _ = sites[0]
            findings.append(Finding(
                "taxonomy", "metric-collision", rel, line, name,
                f"metric {name!r} registered on the GLOBAL registry "
                f"from multiple modules ({', '.join(sorted(gmods))})"))
    return findings
