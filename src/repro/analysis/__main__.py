"""``python -m repro.analysis`` — run the checkers against the tree.

Exit status 0 iff zero unbaselined findings (stale baseline entries are
reported but do not fail — they mean the tree got *better*).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis import core


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="domain-aware static analysis for the repro tree")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detect)")
    ap.add_argument("--checker", action="append", default=None,
                    help="run only this checker (repeatable); default all")
    ap.add_argument("--json", action="store_true",
                    help="emit the findings JSON document to stdout")
    ap.add_argument("--output", default=None,
                    help="also write the JSON document to this path")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: the committed one)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "(keeps existing reasons; new entries get TODO)")
    args = ap.parse_args(argv)

    root = args.root or core.find_repo_root()
    findings = core.run(root, args.checker)

    bpath = args.baseline or core.default_baseline_path()
    baseline = core.Baseline([]) if args.no_baseline \
        else core.Baseline.load(bpath)
    unbase, supp, stale = baseline.split(findings)

    if args.update_baseline:
        entries = [e for e in baseline.entries if e not in stale]
        have = {(e["checker"], e["path"], e["rule"], e["symbol"])
                for e in entries}
        for f in findings:
            if f.fingerprint not in have:
                entries.append({"checker": f.checker, "path": f.path,
                                "rule": f.rule, "symbol": f.symbol,
                                "reason": "TODO: justify or fix"})
        entries.sort(key=lambda e: (e["checker"], e["path"], e["rule"],
                                    e["symbol"]))
        with open(bpath, "w", encoding="utf-8") as f:
            json.dump(entries, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline rewritten: {bpath} ({len(entries)} entries)")
        return 0

    doc = core.render_json(unbase, supp, stale)
    if args.output:
        os.makedirs(os.path.dirname(args.output) or ".", exist_ok=True)
        with open(args.output, "w", encoding="utf-8") as f:
            f.write(doc + "\n")
    if args.json:
        print(doc)
    else:
        print(core.render_text(unbase, supp, stale))
    return 1 if unbase else 0


if __name__ == "__main__":
    sys.exit(main())
