"""AST-based static-analysis framework for the repro tree.

The serving stack's correctness now rests on cross-cutting invariants no
single module can see: a global lock hierarchy, fault-seam coverage of
every durable write, jit-body hygiene, one span/metric taxonomy, and a
wire codec whose kinds must stay exhaustive.  This package checks them
mechanically — ``python -m repro.analysis`` — instead of rediscovering
violations one chaos seed at a time.

Pieces:

  * :class:`Tree` — every ``src/repro`` module parsed once, shared by
    all checkers (plus the repo root, so checkers can read
    ``ARCHITECTURE.md`` — docs-as-config, enforcement can't drift).
  * :class:`Finding` — one defect: checker, rule, site, stable
    ``symbol`` anchor.  The baseline matches on
    ``(checker, path, rule, symbol)`` — deliberately NOT the line
    number, so suppressions survive unrelated edits.
  * :func:`checker` registry + :func:`run` driver.
  * :class:`Baseline` — committed JSON of explicitly-suppressed
    findings, each with a one-line ``reason``.  Stale entries (matching
    nothing) are reported so the file can't rot.

Stdlib-only, import-light: the analyzer never imports the modules it
checks (pure AST), so it runs in CI before any jax wheel is warm.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
from typing import Callable, Iterable, Iterator

__all__ = ["Finding", "Module", "Tree", "Baseline", "checker", "run",
           "render_text", "render_json", "find_repo_root"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One defect.  ``symbol`` is the stable anchor (a qualname, lock
    id, metric name, or edge) that identifies the finding across line
    drift; ``line`` is display-only."""
    checker: str
    rule: str
    path: str                  # repo-relative, forward slashes
    line: int
    symbol: str
    message: str
    severity: str = "error"    # "error" | "warning"

    @property
    def fingerprint(self) -> tuple[str, str, str, str]:
        return (self.checker, self.path, self.rule, self.symbol)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.checker}/{self.rule}] "
                f"{self.severity}: {self.message}  ({self.symbol})")


class Module:
    """One parsed source module."""

    __slots__ = ("path", "relpath", "tree", "source")

    def __init__(self, path: str, relpath: str, tree: ast.Module,
                 source: str):
        self.path = path
        self.relpath = relpath
        self.tree = tree
        self.source = source


class Tree:
    """All of ``src/repro`` parsed once, keyed by repo-relative path."""

    def __init__(self, root: str):
        self.root = root
        self.modules: dict[str, Module] = {}

    @classmethod
    def load(cls, root: str, subdir: str = os.path.join("src", "repro")
             ) -> "Tree":
        t = cls(root)
        base = os.path.join(root, subdir)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__",)]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, root).replace(os.sep, "/")
                with open(path, encoding="utf-8") as f:
                    src = f.read()
                t.modules[rel] = Module(path, rel,
                                        ast.parse(src, filename=rel), src)
        return t

    def iter(self, prefix: str | None = None) -> Iterator[Module]:
        for rel in sorted(self.modules):
            if prefix is None or rel.startswith(prefix):
                yield self.modules[rel]

    def doc(self, name: str) -> str:
        """A repo-root document's text (e.g. ARCHITECTURE.md)."""
        with open(os.path.join(self.root, name), encoding="utf-8") as f:
            return f.read()


# ------------------------------------------------------------------ registry
CHECKERS: dict[str, Callable[[Tree], list[Finding]]] = {}


def checker(name: str):
    """Register ``fn(tree) -> list[Finding]`` under ``name``."""
    def deco(fn):
        CHECKERS[name] = fn
        return fn
    return deco


def run(root: str, names: Iterable[str] | None = None) -> list[Finding]:
    """Load the tree and run the named checkers (default: all),
    returning findings sorted by site."""
    # import for side effect: registers the checkers
    from repro.analysis import (jaxlint, locks, seams,  # noqa: F401
                                taxonomy, wire)
    tree = Tree.load(root)
    selected = list(names) if names else sorted(CHECKERS)
    out: list[Finding] = []
    for name in selected:
        if name not in CHECKERS:
            raise KeyError(f"unknown checker {name!r}; have "
                           f"{sorted(CHECKERS)}")
        out.extend(CHECKERS[name](tree))
    out.sort(key=lambda f: (f.path, f.line, f.checker, f.rule, f.symbol))
    return out


# ------------------------------------------------------------------ baseline
class Baseline:
    """Committed suppressions: a JSON list of
    ``{checker, path, rule, symbol, reason}`` entries.  Matching is by
    fingerprint; every entry must carry a non-empty reason."""

    def __init__(self, entries: list[dict]):
        for e in entries:
            if not str(e.get("reason", "")).strip():
                raise ValueError(f"baseline entry without a reason: {e}")
        self.entries = entries
        self._index = {(e["checker"], e["path"], e["rule"], e["symbol"])
                       : e for e in entries}

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls([])
        with open(path, encoding="utf-8") as f:
            return cls(json.load(f))

    def matches(self, f: Finding) -> bool:
        return f.fingerprint in self._index

    def split(self, findings: list[Finding]
              ) -> tuple[list[Finding], list[Finding], list[dict]]:
        """-> (unbaselined, suppressed, stale_entries)."""
        unbase = [f for f in findings if not self.matches(f)]
        supp = [f for f in findings if self.matches(f)]
        hit = {f.fingerprint for f in supp}
        stale = [e for k, e in self._index.items() if k not in hit]
        return unbase, supp, stale


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(__file__), "baseline.json")


# ----------------------------------------------------------------- reporters
def render_text(unbaselined: list[Finding], suppressed: list[Finding],
                stale: list[dict]) -> str:
    lines = [f.render() for f in unbaselined]
    lines.append(f"{len(unbaselined)} finding(s), "
                 f"{len(suppressed)} baselined, "
                 f"{len(stale)} stale baseline entr(y/ies)")
    for e in stale:
        lines.append(f"  stale baseline: {e['checker']}/{e['rule']} "
                     f"{e['path']} {e['symbol']} — {e['reason']}")
    return "\n".join(lines)


def render_json(unbaselined: list[Finding], suppressed: list[Finding],
                stale: list[dict]) -> str:
    return json.dumps({
        "findings": [f.to_dict() for f in unbaselined],
        "suppressed": [f.to_dict() for f in suppressed],
        "stale_baseline": stale,
        "counts": {"unbaselined": len(unbaselined),
                   "suppressed": len(suppressed),
                   "stale": len(stale)},
    }, indent=2, sort_keys=True)


def find_repo_root(start: str | None = None) -> str:
    """Walk up from ``start`` (default cwd) to the directory holding
    both ``src/repro`` and ``ARCHITECTURE.md``; falls back to the
    package's own grandparent (src/repro/analysis -> repo)."""
    cur = os.path.abspath(start or os.getcwd())
    while True:
        if (os.path.isdir(os.path.join(cur, "src", "repro"))
                and os.path.exists(os.path.join(cur, "ARCHITECTURE.md"))):
            return cur
        nxt = os.path.dirname(cur)
        if nxt == cur:
            break
        cur = nxt
    pkg = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(pkg)))
