"""Runtime lock-order witness: the dynamic half of the lock checker.

The static analyzer can only see acquisitions it can resolve; the
runtime can only see orderings a particular run happened to execute.
Each side validates the other: this module patches the
``threading.Lock/RLock/Condition`` factories so every lock *created from
repro source* is wrapped, records every observed ``(held, acquired)``
nesting keyed by the static inventory's lock ids (creation-site
mapping), and at teardown checks the observed pairs against the
ARCHITECTURE.md rank table.  Run under the whole tier-1 suite
(``REPRO_LOCK_WITNESS=1 pytest``) it turns every test into a lock-order
probe.

Locks created outside ``src/repro`` (jax internals, stdlib plumbing —
including the RLock each wrapped ``Condition`` allocates internally)
pass through unwrapped and unrecorded.
"""
from __future__ import annotations

import os
import sys
import threading

from repro.analysis import locks as locks_mod
from repro.analysis.core import Tree, find_repo_root

__all__ = ["LockWitness", "install", "current"]

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_CONDITION = threading.Condition


class _Wrapped:
    """Order-observing proxy around one lock/rlock/condition."""

    __slots__ = ("_real", "_wit", "lock_id")

    def __init__(self, real, wit: "LockWitness", lock_id: str):
        self._real = real
        self._wit = wit
        self.lock_id = lock_id

    # --- acquisition surface
    def acquire(self, *a, **kw):
        got = self._real.acquire(*a, **kw)
        if got:
            self._wit.note_acquire(self)
        return got

    def release(self):
        self._wit.note_release(self)
        self._real.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    # --- condition surface (only present on real conditions)
    def wait(self, timeout=None):
        # wait releases and reacquires the underlying lock; the witness
        # stack keeps the cv entry (orderings observed after the wakeup
        # still happen under the reacquired cv)
        return self._real.wait(timeout)

    def wait_for(self, predicate, timeout=None):
        return self._real.wait_for(predicate, timeout)

    def notify(self, n=1):
        self._real.notify(n)

    def notify_all(self):
        self._real.notify_all()

    def locked(self):
        return self._real.locked()

    def __getattr__(self, name):
        return getattr(self._real, name)


class LockWitness:
    """Observed-nesting recorder + factory patcher."""

    def __init__(self, root: str | None = None):
        self.root = root or find_repo_root()
        tree = Tree.load(self.root)
        inv = locks_mod.collect_inventory(tree)
        self.ranks = locks_mod.parse_hierarchy(tree.doc("ARCHITECTURE.md"))
        # (abspath, line) -> lock id
        self._sites: dict[tuple[str, int], str] = {}
        for d in inv.values():
            key = (os.path.normpath(os.path.join(self.root, d.relpath)),
                   d.line)
            self._sites[key] = d.id
        # (outer, inner) -> (file, line, full held stack at first sighting)
        self.pairs: dict[tuple[str, str], tuple[str, int, tuple]] = {}
        self._pairs_lock = _REAL_LOCK()
        self._tls = threading.local()
        self._installed = False

    # ------------------------------------------------------------- recording
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = []
            self._tls.stack = st
        return st

    def note_acquire(self, w: _Wrapped) -> None:
        st = self._stack()
        if st and not any(x.lock_id == w.lock_id for x in st):
            top = st[-1]
            key = (top.lock_id, w.lock_id)
            if key not in self.pairs:
                fr = sys._getframe(1)
                while fr is not None and \
                        fr.f_code.co_filename == __file__:
                    fr = fr.f_back          # skip the proxy's own frames
                where = (fr.f_code.co_filename, fr.f_lineno) \
                    if fr is not None else ("<unknown>", 0)
                held = tuple(x.lock_id for x in st)
                with self._pairs_lock:
                    self.pairs.setdefault(key, (*where, held))
        st.append(w)

    def note_release(self, w: _Wrapped) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] is w:
                del st[i]
                break

    def reset_thread(self) -> None:
        """Drop the calling thread's held-lock context.  Test isolation
        hook: crash-simulation tests abandon a deliberately-open
        two-phase flush (``prepare_spill`` with no commit — the "process
        died here" idiom), leaving that discarded store's flush lock
        held forever.  The dead instance is not a hazard, but its stale
        stack entry would poison every nesting this thread observes for
        the rest of the session; the per-test fixture clears it."""
        self._tls.stack = []

    # ------------------------------------------------------------- patching
    def _site_id(self) -> str | None:
        """Map the creating frame (first repro-source frame up-stack) to
        a static lock id; None -> leave the lock unwrapped."""
        src_root = os.path.join(self.root, "src", "repro")
        f = sys._getframe(2)
        while f is not None:
            fn = os.path.normpath(f.f_code.co_filename)
            if fn.startswith(src_root):
                lid = self._sites.get((fn, f.f_lineno))
                if lid is None:          # tolerate small formatting drift
                    for dl in (1, 2, -1, -2):
                        lid = self._sites.get((fn, f.f_lineno + dl))
                        if lid is not None:
                            break
                return lid
            f = f.f_back
        return None

    def install(self) -> "LockWitness":
        if self._installed:
            return self
        wit = self

        def make_lock():
            real = _REAL_LOCK()
            lid = wit._site_id()
            return real if lid is None else _Wrapped(real, wit, lid)

        def make_rlock():
            real = _REAL_RLOCK()
            lid = wit._site_id()
            return real if lid is None else _Wrapped(real, wit, lid)

        def make_condition(lock=None):
            if lock is not None and isinstance(lock, _Wrapped):
                lock = lock._real
            real = _REAL_CONDITION(lock)
            lid = wit._site_id()
            return real if lid is None else _Wrapped(real, wit, lid)

        threading.Lock = make_lock
        threading.RLock = make_rlock
        threading.Condition = make_condition
        self._installed = True
        return self

    def uninstall(self) -> None:
        if self._installed:
            threading.Lock = _REAL_LOCK
            threading.RLock = _REAL_RLOCK
            threading.Condition = _REAL_CONDITION
            self._installed = False

    # ------------------------------------------------------------ validation
    def violations(self) -> list[str]:
        """Observed nestings that contradict the documented ranks."""
        out = []
        with self._pairs_lock:
            pairs = dict(self.pairs)
        for (outer, inner), (fn, line, held) in sorted(pairs.items()):
            ro, ri = self.ranks.get(outer), self.ranks.get(inner)
            if ro is None or ri is None:
                out.append(f"unranked nesting {outer} -> {inner} "
                           f"(first seen {fn}:{line})")
            elif ro >= ri:
                out.append(f"rank inversion {outer} (rank {ro}) held "
                           f"while acquiring {inner} (rank {ri}) at "
                           f"{fn}:{line} (held: {' -> '.join(held)})")
        return out


_CURRENT: LockWitness | None = None


def install(root: str | None = None) -> LockWitness:
    global _CURRENT
    if _CURRENT is None:
        _CURRENT = LockWitness(root).install()
    return _CURRENT


def current() -> LockWitness | None:
    return _CURRENT
