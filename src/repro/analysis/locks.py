"""Interprocedural lock-order analysis against the documented hierarchy.

Three layers:

  1. **Inventory** — every ``threading.Lock/RLock/Condition`` the tree
     creates, with a stable id and its creation site.  Attribute locks
     are ``Class._name``; module globals ``modstem.NAME``; function
     locals ``qual.var``.  The runtime witness
     (:mod:`repro.analysis.witness`) keys observed locks back to these
     ids by creation site, so both sides speak one vocabulary.
  2. **Acquisition graph** — per function/method, an ordered event list
     (``with lock:`` push/pop, explicit ``.acquire()``/``.release()``,
     resolved call sites).  A fixpoint over method summaries propagates
     transitive acquisitions, locks still held at return (the store's
     two-phase ``prepare_segment`` → ``commit_segment`` protocol), and
     entry releases, then a replay per method yields the global
     ``(held, acquired)`` edge set.
  3. **Hierarchy check** — ranks parsed from the ARCHITECTURE.md
     "Lock hierarchy" table (lower rank = outer).  An edge whose outer
     rank is not strictly lower is an inversion; locks that participate
     in nesting but have no table row are findings too, so the table
     stays the single complete source of truth.

Resolution is best-effort and silent on what it cannot see (dynamic
dispatch, locks passed as bare arguments): missing edges weaken the
check, they never fabricate findings.
"""
from __future__ import annotations

import ast
import dataclasses
import re

from repro.analysis.core import Finding, Tree, checker

__all__ = ["LockDef", "collect_inventory", "build_edges",
           "parse_hierarchy", "check_lock_order"]

_FACTORIES = {"Lock", "RLock", "Condition"}


@dataclasses.dataclass(frozen=True)
class LockDef:
    id: str
    relpath: str
    line: int
    kind: str                  # Lock | RLock | Condition


def _is_lock_factory(call: ast.expr) -> str | None:
    """'threading.Lock'-style constructor -> kind name, else None."""
    if (isinstance(call, ast.Call) and isinstance(call.func, ast.Attribute)
            and isinstance(call.func.value, ast.Name)
            and call.func.value.id == "threading"
            and call.func.attr in _FACTORIES):
        return call.func.attr
    return None


def _modstem(relpath: str) -> str:
    return relpath.rsplit("/", 1)[-1][:-3]


# ---------------------------------------------------------------- inventory
def collect_inventory(tree: Tree) -> dict[str, LockDef]:
    """Every lock the tree creates, keyed by id.  Duplicate ids (same
    class+attr defined twice) keep the first definition; the witness
    tolerates multiple creation sites per id."""
    defs: dict[str, LockDef] = {}

    def add(lid: str, mod, node, kind: str) -> None:
        defs.setdefault(lid, LockDef(lid, mod.relpath, node.lineno, kind))

    for mod in tree.iter():
        for node in mod.tree.body:          # module-level globals
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                kind = _is_lock_factory(node.value)
                if kind:
                    add(f"{_modstem(mod.relpath)}.{node.targets[0].id}",
                        mod, node.value, kind)
        for cls, fn, qual in _iter_functions(mod.tree):
            for stmt in ast.walk(fn):
                if not isinstance(stmt, ast.Assign) or \
                        len(stmt.targets) != 1:
                    continue
                kind = _is_lock_factory(stmt.value)
                if not kind:
                    continue
                tgt = stmt.targets[0]
                if (cls is not None and isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    add(f"{cls.name}.{tgt.attr}", mod, stmt.value, kind)
                elif isinstance(tgt, ast.Name):
                    add(f"{qual}.{tgt.id}", mod, stmt.value, kind)
    return defs


def _iter_functions(module: ast.Module):
    """Yield (classdef_or_None, functiondef, qualname) for every
    function/method, including nested defs (qualified by their parent)."""
    def rec(node, cls, prefix):
        for child in node.body if hasattr(node, "body") else []:
            if isinstance(child, ast.ClassDef):
                yield from rec(child, child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                yield cls, child, qual
                yield from rec(child, cls, qual)
    yield from rec(module, None, "")


# -------------------------------------------------------- attr-type inference
def _ann_name(t, class_names) -> str | None:
    """A Name / string-constant annotation naming a known class."""
    if isinstance(t, ast.Name) and t.id in class_names:
        return t.id
    if isinstance(t, ast.Constant) and str(t.value) in class_names:
        return str(t.value)
    return None


def _collect_attr_types(tree: Tree) -> dict[str, dict[str, str]]:
    """{ClassName: {attr: ClassName}} — from ctor calls
    (``self.x = Foo(...)``), annotated-parameter aliasing
    (``def __init__(self, svc: "BitmapService"): self.x = svc``),
    annotated ``@property`` returns, and a fixpoint over attribute
    chains (``self.store = indexer.store``)."""
    class_names = {n.name for m in tree.iter()
                   for n in ast.walk(m.tree) if isinstance(n, ast.ClassDef)}
    out: dict[str, dict[str, str]] = {}
    # (cls, attr, value_expr, ann_map) deferred until the fixpoint
    pending: list[tuple[str, str, ast.expr, dict[str, str]]] = []
    for mod in tree.iter():
        for cls, fn, _ in _iter_functions(mod.tree):
            if cls is None:
                continue
            is_prop = any(isinstance(d, ast.Name) and d.id == "property"
                          for d in fn.decorator_list)
            if is_prop:
                name = _ann_name(fn.returns, class_names)
                if name:
                    out.setdefault(cls.name, {})[fn.name] = name
            ann: dict[str, str] = {}
            for a in fn.args.args + fn.args.kwonlyargs:
                name = _ann_name(a.annotation, class_names)
                if name:
                    ann[a.arg] = name
            for stmt in ast.walk(fn):
                tgt = value = None
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    tgt, value = stmt.targets[0], stmt.value
                elif isinstance(stmt, ast.AnnAssign):
                    tgt, value = stmt.target, stmt.value
                    name = _ann_name(stmt.annotation, class_names)
                    if name and isinstance(tgt, ast.Attribute) and \
                            isinstance(tgt.value, ast.Name) and \
                            tgt.value.id == "self":
                        out.setdefault(cls.name, {})[tgt.attr] = name
                        continue
                if tgt is None or value is None:
                    continue
                if not (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    continue
                if isinstance(value, ast.Call):
                    f = value.func
                    name = None
                    if isinstance(f, ast.Name) and f.id in class_names:
                        name = f.id
                    elif isinstance(f, ast.Attribute) and \
                            f.attr in class_names:
                        name = f.attr
                    if name:
                        out.setdefault(cls.name, {})[tgt.attr] = name
                else:
                    pending.append((cls.name, tgt.attr, value, ann))

    def resolve(expr, cls_name, ann):
        if isinstance(expr, ast.Name):
            if expr.id == "self":
                return cls_name
            return ann.get(expr.id)
        if isinstance(expr, ast.Attribute):
            base = resolve(expr.value, cls_name, ann)
            if base is not None:
                return out.get(base, {}).get(expr.attr)
        return None

    for _ in range(10):
        changed = False
        for cls_name, attr, value, ann in pending:
            if attr in out.get(cls_name, {}):
                continue
            name = resolve(value, cls_name, ann)
            if name:
                out.setdefault(cls_name, {})[attr] = name
                changed = True
        if not changed:
            break
    return out


# ------------------------------------------------------------ event extraction
@dataclasses.dataclass
class _Summary:
    qual: str                  # "Class.meth" or "modstem.fn"
    relpath: str
    events: list               # ("push"/"pop"/"acquire"/"release", id, line)
                               # | ("call", calleekey, line)
    acquires: set = dataclasses.field(default_factory=set)
    held_at_return: set = dataclasses.field(default_factory=set)
    releases_entry: set = dataclasses.field(default_factory=set)


class _FnWalker(ast.NodeVisitor):
    """Linearize one function into lock events + resolved call sites."""

    def __init__(self, mod, cls, qual, lock_ids, attr_types, imports,
                 class_of_module, local_types):
        self.mod = mod
        self.cls = cls
        self.qual = qual
        self.lock_ids = lock_ids
        self.attr_types = attr_types
        self.imports = imports          # alias -> module stem
        self.class_of_module = class_of_module  # ClassName -> exists
        self.local_types = local_types  # var -> ClassName (per function)
        self.events: list = []

    # -- resolution helpers
    def _lock_of(self, expr) -> str | None:
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and \
                expr.value.id == "self" and self.cls is not None:
            lid = f"{self.cls.name}.{expr.attr}"
            return lid if lid in self.lock_ids else None
        if isinstance(expr, ast.Name):
            lid = f"{self.qual}.{expr.id}"
            if lid in self.lock_ids:
                return lid
            lid = f"{_modstem(self.mod.relpath)}.{expr.id}"
            return lid if lid in self.lock_ids else None
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and \
                expr.value.id in self.imports:
            lid = f"{self.imports[expr.value.id]}.{expr.attr}"
            return lid if lid in self.lock_ids else None
        if isinstance(expr, ast.Attribute):
            # cross-object direct acquisition: `self.store._flush_lock`,
            # `indexer._mu` — resolve the receiver chain to a class
            base = self._type_of(expr.value)
            if base is not None:
                lid = f"{base}.{expr.attr}"
                return lid if lid in self.lock_ids else None
        return None

    def _type_of(self, expr) -> str | None:
        """Best-effort class of an expression (self / self.attr chains /
        typed locals)."""
        if isinstance(expr, ast.Name):
            if expr.id == "self" and self.cls is not None:
                return self.cls.name
            return self.local_types.get(expr.id)
        if isinstance(expr, ast.Attribute):
            base = self._type_of(expr.value)
            if base is not None:
                return self.attr_types.get(base, {}).get(expr.attr)
        return None

    def _callee_of(self, call: ast.Call) -> str | None:
        f = call.func
        if isinstance(f, ast.Attribute):
            base = self._type_of(f.value)
            if base is not None:
                return f"{base}.{f.attr}"
            if isinstance(f.value, ast.Name) and \
                    f.value.id in self.imports:
                return f"{self.imports[f.value.id]}.{f.attr}"
            return None
        if isinstance(f, ast.Name):
            if f.id in self.class_of_module:       # ctor call
                return f"{f.id}.__init__"
            return f"{_modstem(self.mod.relpath)}.{f.id}"
        return None

    # -- traversal
    def visit_With(self, node: ast.With) -> None:
        pushed = []
        for item in node.items:
            lid = self._lock_of(item.context_expr)
            if lid is None and isinstance(item.context_expr, ast.Call):
                # `with cv:` only; `with maybe_span(...)` etc: still
                # visit the call for nested resolution
                self.visit(item.context_expr)
            if lid is not None:
                self.events.append(("push", lid, node.lineno))
                pushed.append(lid)
        for stmt in node.body:
            self.visit(stmt)
        for lid in reversed(pushed):
            self.events.append(("pop", lid, node.lineno))

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr in \
                ("acquire", "release"):
            lid = self._lock_of(f.value)
            if lid is not None:
                kind = "acquire" if f.attr == "acquire" else "release"
                self.events.append((kind, lid, node.lineno))
                for a in node.args:
                    self.visit(a)
                return
        callee = self._callee_of(node)
        if callee is not None:
            self.events.append(("call", callee, node.lineno))
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        # track `x = ClassName(...)` for later `x.meth()` resolution
        if (len(node.targets) == 1 and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)
                and isinstance(node.value.func, ast.Name)
                and node.value.func.id in self.class_of_module):
            self.local_types[node.targets[0].id] = node.value.func.id
        self.generic_visit(node)

    def visit_FunctionDef(self, node) -> None:
        pass                                     # nested defs walked separately

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node) -> None:
        pass


def _collect_imports(mod) -> dict[str, str]:
    """alias -> module stem, for repro-internal imports only."""
    out: dict[str, str] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom):
            for a in node.names:
                stem = a.name
                out[a.asname or a.name] = stem
        elif isinstance(node, ast.Import):
            for a in node.names:
                stem = a.name.rsplit(".", 1)[-1]
                out[a.asname or a.name.split(".")[0]] = stem
    return out


def build_summaries(tree: Tree, lock_ids: set
                    ) -> dict[str, _Summary]:
    attr_types = _collect_attr_types(tree)
    class_names = {n.name for m in tree.iter()
                   for n in ast.walk(m.tree) if isinstance(n, ast.ClassDef)}
    summaries: dict[str, _Summary] = {}
    for mod in tree.iter():
        imports = _collect_imports(mod)
        for cls, fn, qual in _iter_functions(mod.tree):
            key = (f"{cls.name}.{fn.name}" if cls is not None
                   else f"{_modstem(mod.relpath)}.{fn.name}")
            w = _FnWalker(mod, cls, qual, lock_ids, attr_types, imports,
                          class_names, {})
            for stmt in fn.body:
                w.visit(stmt)
            if key not in summaries:       # first def wins on collision
                summaries[key] = _Summary(key, mod.relpath, w.events)
    return summaries


# ------------------------------------------------------------------ fixpoint
def _replay(s: _Summary, summaries, record_edges=None):
    """One replay of a summary's events against current callee
    summaries.  Returns (acquires, held_at_return, releases_entry);
    optionally records (held, acquired, line) edge triples."""
    held: list[str] = []
    acquires: set[str] = set()
    releases_entry: set[str] = set()
    ever_acquired: set[str] = set()

    def do_acquire(lid, line):
        acquires.add(lid)
        ever_acquired.add(lid)
        if record_edges is not None:
            for h in held:
                if h != lid:
                    record_edges.add((h, lid, line))
        held.append(lid)

    def do_release(lid):
        if lid in held:
            held.reverse()
            held.remove(lid)
            held.reverse()
        elif lid not in ever_acquired:
            releases_entry.add(lid)

    for ev in s.events:
        kind, name, line = ev
        if kind in ("push", "acquire"):
            do_acquire(name, line)
        elif kind in ("pop", "release"):
            do_release(name)
        elif kind == "call":
            cs = summaries.get(name)
            if cs is None or cs is s:
                continue
            for a in sorted(cs.acquires):
                acquires.add(a)
                if record_edges is not None:
                    for h in held:
                        if h != a:
                            record_edges.add((h, a, line))
            for lid in sorted(cs.held_at_return):
                if lid not in held:
                    held.append(lid)
                    ever_acquired.add(lid)
            for lid in sorted(cs.releases_entry):
                do_release(lid)
    return acquires, set(held), releases_entry


def build_edges(tree: Tree, lock_defs: dict[str, LockDef]
                ) -> tuple[set, dict[str, _Summary]]:
    """Fixpoint over summaries, then an edge-recording replay.
    Edges are ``(outer_id, inner_id, line)`` triples."""
    summaries = build_summaries(tree, set(lock_defs))
    for _ in range(24):
        changed = False
        for s in summaries.values():
            acq, ret, rel = _replay(s, summaries)
            if (acq, ret, rel) != (s.acquires, s.held_at_return,
                                   s.releases_entry):
                s.acquires, s.held_at_return, s.releases_entry = \
                    acq, ret, rel
                changed = True
        if not changed:
            break
    edges: set = set()
    edge_sites: dict[tuple[str, str], tuple[str, int]] = {}
    for s in summaries.values():
        local: set = set()
        _replay(s, summaries, record_edges=local)
        for (a, b, line) in local:
            edges.add((a, b))
            edge_sites.setdefault((a, b), (s.relpath, line))
    return {(a, b, *edge_sites[(a, b)]) for (a, b) in edges}, summaries


# ----------------------------------------------------------------- hierarchy
_ROW = re.compile(r"^\s*\|\s*(\d+)\s*\|(.+?)\|")
_TICK = re.compile(r"`([A-Za-z_][\w.]*)`")


def parse_hierarchy(arch_text: str) -> dict[str, int]:
    """Parse the ARCHITECTURE.md "Lock hierarchy" table: rows
    ``| <rank> | `LockId`[, `LockId`...] | ... |``.  Lower rank =
    outer.  Raises if the section or table is missing — the docs ARE
    the config."""
    m = re.search(r"^##+\s+Lock hierarchy\b", arch_text, re.M)
    if not m:
        raise ValueError("ARCHITECTURE.md has no 'Lock hierarchy' section")
    section = arch_text[m.end():]
    nxt = re.search(r"^##+\s+", section, re.M)
    if nxt:
        section = section[:nxt.start()]
    ranks: dict[str, int] = {}
    for line in section.splitlines():
        row = _ROW.match(line)
        if not row:
            continue
        rank = int(row.group(1))
        for lid in _TICK.findall(row.group(2)):
            if lid in ranks:
                raise ValueError(f"lock {lid!r} ranked twice in "
                                 "ARCHITECTURE.md")
            ranks[lid] = rank
    if not ranks:
        raise ValueError("Lock hierarchy table parsed to zero rows")
    return ranks


# -------------------------------------------------------------------- checker
@checker("locks")
def check_lock_order(tree: Tree) -> list[Finding]:
    lock_defs = collect_inventory(tree)
    edges, _ = build_edges(tree, lock_defs)
    ranks = parse_hierarchy(tree.doc("ARCHITECTURE.md"))
    findings: list[Finding] = []

    participants = {a for a, b, *_ in edges} | {b for a, b, *_ in edges}
    for lid in sorted(participants - set(ranks)):
        d = lock_defs[lid]
        findings.append(Finding(
            "locks", "unranked", d.relpath, d.line, lid,
            f"lock {lid} participates in nesting but has no rank in the "
            f"ARCHITECTURE.md lock-hierarchy table"))

    for (a, b, relpath, line) in sorted(edges):
        ra, rb = ranks.get(a), ranks.get(b)
        if ra is None or rb is None or a == b:
            continue
        if ra >= rb:
            findings.append(Finding(
                "locks", "inversion", relpath, line, f"{a}->{b}",
                f"{a} (rank {ra}) held while acquiring {b} (rank {rb}); "
                f"the documented hierarchy requires strictly "
                f"outer-to-inner (lower rank first)"))

    # cycles independent of ranks (catches problems even in unranked sets)
    adj: dict[str, set[str]] = {}
    for a, b, *_ in edges:
        if a != b:
            adj.setdefault(a, set()).add(b)
    for a in sorted(adj):
        stack, seen = [(a, iter(sorted(adj.get(a, ()))))], {a}
        path = [a]
        while stack:
            node, it = stack[-1]
            nxt = next(it, None)
            if nxt is None:
                stack.pop()
                path.pop()
                continue
            if nxt == a:
                cyc = "->".join(path + [a])
                sym = "->".join(sorted(set(path)))
                if not any(f.rule == "cycle" and f.symbol == sym
                           for f in findings):
                    d = lock_defs[a]
                    findings.append(Finding(
                        "locks", "cycle", d.relpath, d.line, sym,
                        f"lock acquisition cycle: {cyc}"))
            elif nxt not in seen:
                seen.add(nxt)
                path.append(nxt)
                stack.append((nxt, iter(sorted(adj.get(nxt, ())))))
    return findings
