"""JAX-hygiene lint for ``engine/``: host syncs and retrace hazards
inside jit-compiled bodies.

A host sync inside a jit body (``.item()``, ``np.asarray``,
``jax.device_get``, ``float()`` on a traced value) either fails at trace
time or — worse — silently forces a device round-trip per call.  A
retrace hazard (non-hashable static argument, mutable closure capture)
turns the executor caches the batch layer depends on into per-call
recompiles.  Both classes killed real latency budgets before; this lint
keeps them out of the engine.

What counts as a jit body:

  * a function decorated ``@jax.jit`` or
    ``@functools.partial(jax.jit, ...)`` / ``@partial(jax.jit, ...)``;
  * a local ``def``/``lambda`` passed to a ``jax.jit(...)`` call in the
    same module (directly or through ``jax.vmap``).

``int()``/shape arithmetic on ``.shape``/``.ndim``/``len()`` is static
under tracing and is never flagged.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, Tree, checker

__all__ = ["check_jax_hygiene"]

_SCOPE = "src/repro/engine/"
_HOST_NP = ("asarray", "array", "frombuffer")


def _is_jit_expr(node) -> bool:
    """``jax.jit`` / ``jit`` attribute or name."""
    return (isinstance(node, ast.Attribute) and node.attr == "jit") or \
        (isinstance(node, ast.Name) and node.id == "jit")


def _jit_call(node) -> ast.Call | None:
    """The ``jax.jit(...)`` call in an expression, unwrapping
    ``functools.partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return None
    if _is_jit_expr(node.func):
        return node
    if isinstance(node.func, ast.Attribute) and \
            node.func.attr == "partial" or \
            isinstance(node.func, ast.Name) and node.func.id == "partial":
        if node.args and _is_jit_expr(node.args[0]):
            return node
    return None


def _shape_static(node) -> bool:
    """Expression derived from shapes/dtypes — static under tracing."""
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim",
                                                       "dtype", "size"):
            return True
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) and \
                n.func.id == "len":
            return True
    return False


class _BodyLint(ast.NodeVisitor):
    """Flag host syncs inside one jit body."""

    def __init__(self, relpath, qual, findings):
        self.relpath = relpath
        self.qual = qual
        self.findings = findings

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "item" and not node.args:
                self._flag(node, "host-sync", ".item()")
            elif f.attr in _HOST_NP and isinstance(f.value, ast.Name) \
                    and f.value.id in ("np", "numpy"):
                self._flag(node, "host-sync", f"np.{f.attr}()")
            elif f.attr == "device_get":
                self._flag(node, "host-sync", "jax.device_get()")
            elif f.attr == "block_until_ready":
                self._flag(node, "host-sync", ".block_until_ready()")
        elif isinstance(f, ast.Name) and f.id == "float" and node.args:
            if not isinstance(node.args[0], ast.Constant) and \
                    not _shape_static(node.args[0]):
                self._flag(node, "host-sync", "float() on a traced value")
        self.generic_visit(node)

    def _flag(self, node, rule, what):
        self.findings.append(Finding(
            "jax", rule, self.relpath, node.lineno,
            f"{self.qual}:{what}",
            f"{what} inside the jit-compiled body {self.qual} forces a "
            f"device->host sync per call"))


def _mutable_captures(fn, enclosing_mutables) -> list[tuple[str, int]]:
    """Free variables of ``fn`` bound to mutable literals in the
    enclosing scope."""
    local = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
    if getattr(fn.args, "vararg", None):
        local.add(fn.args.vararg.arg)
    for n in ast.walk(fn):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Name):
                    local.add(t.id)
    out = []
    for n in ast.walk(fn):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) and \
                n.id not in local and n.id in enclosing_mutables:
            out.append((n.id, n.lineno))
    return out


@checker("jax")
def check_jax_hygiene(tree: Tree) -> list[Finding]:
    findings: list[Finding] = []
    for mod in tree.iter(_SCOPE):
        # names of local defs jitted somewhere in this module, plus
        # mutable-literal bindings per enclosing function
        for scope in ast.walk(mod.tree):
            if not isinstance(scope, (ast.Module, ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            defs: dict[str, ast.AST] = {}
            mutables: set[str] = set()
            for stmt in scope.body:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    defs[stmt.name] = stmt
                elif isinstance(stmt, ast.Assign) and \
                        len(stmt.targets) == 1 and \
                        isinstance(stmt.targets[0], ast.Name):
                    if isinstance(stmt.value, (ast.List, ast.Dict,
                                               ast.Set)):
                        mutables.add(stmt.targets[0].id)
                    elif isinstance(stmt.value, ast.Lambda):
                        defs[stmt.targets[0].id] = stmt.value
            qual_prefix = getattr(scope, "name", mod.relpath)

            # decorated jit bodies
            for stmt in scope.body:
                if not isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                jitted = any(_jit_call(d) is not None or _is_jit_expr(d)
                             for d in stmt.decorator_list)
                if jitted:
                    _lint_jit_body(mod, stmt, f"{qual_prefix}.{stmt.name}",
                                   mutables, findings)
                for d in stmt.decorator_list:
                    call = _jit_call(d)
                    if call is not None:
                        _check_static_args(mod, stmt, call, findings)

            # jax.jit(f) call sites over local defs/lambdas
            for node in ast.walk(scope):
                call = _jit_call(node) if isinstance(node, ast.Call) \
                    else None
                if call is None:
                    continue
                targets = call.args[1:] if not _is_jit_expr(call.func) \
                    else call.args[:1]
                for t in targets:
                    body = None
                    name = None
                    if isinstance(t, ast.Lambda):
                        body, name = t, "<lambda>"
                    elif isinstance(t, ast.Name) and t.id in defs:
                        body, name = defs[t.id], t.id
                    elif isinstance(t, ast.Call):
                        # jax.jit(jax.vmap(f)) — unwrap one level
                        for a in t.args:
                            if isinstance(a, ast.Name) and a.id in defs:
                                body, name = defs[a.id], a.id
                            elif isinstance(a, ast.Lambda):
                                body, name = a, "<lambda>"
                    if body is not None:
                        _lint_jit_body(mod, body,
                                       f"{qual_prefix}.{name}",
                                       mutables, findings)
    return findings


def _lint_jit_body(mod, fn, qual, enclosing_mutables, findings):
    lint = _BodyLint(mod.relpath, qual, findings)
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        lint.visit(stmt)
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        for name, line in _mutable_captures(fn, enclosing_mutables):
            findings.append(Finding(
                "jax", "retrace-hazard", mod.relpath, line,
                f"{qual}:{name}",
                f"jit body {qual} closes over mutable binding {name!r}; "
                f"mutating it silently invalidates nothing — the "
                f"compiled executor keeps the captured snapshot"))


def _check_static_args(mod, fn, partial_call, findings):
    """Non-hashable static args: a static_argnames param whose default
    is a mutable literal will raise at call time (or worse, defeat the
    jit cache if converted)."""
    static: set[str] = set()
    for kw in partial_call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    static.add(n.value)
    if not static:
        return
    args = fn.args
    defaults = list(args.defaults)
    named = args.args[len(args.args) - len(defaults):]
    for a, d in zip(named, defaults):
        if a.arg in static and isinstance(d, (ast.List, ast.Dict, ast.Set)):
            findings.append(Finding(
                "jax", "retrace-hazard", mod.relpath, d.lineno,
                f"{fn.name}:{a.arg}",
                f"static arg {a.arg!r} of {fn.name} defaults to a "
                f"non-hashable literal — jit static args must be "
                f"hashable"))
