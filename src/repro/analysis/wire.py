"""Wire-exhaustiveness: request kinds, host handlers, and reply kinds
must stay closed sets.

``ServiceHost`` dispatches ``getattr(self, f"_on_{env.kind}")`` — a
request kind without a handler only fails at runtime, on the wire, as
an ``error`` reply.  This checker closes the loop statically:

  * every ``Envelope("<kind>")`` the tree constructs outside the host
    module must have a matching ``_on_<kind>`` handler;
  * every ``_on_<kind>`` handler must have at least one sender (dead
    handlers hide protocol drift);
  * every reply kind the client side compares against
    (``reply.kind == "..."``) must be a kind some handler actually
    sends via ``env.reply(...)``.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, Tree, checker

__all__ = ["check_wire"]

_SCOPE = "src/repro/fabric/"


def _const_str(node) -> str | None:
    return node.value if isinstance(node, ast.Constant) and \
        isinstance(node.value, str) else None


@checker("wire")
def check_wire(tree: Tree) -> list[Finding]:
    handlers: dict[str, tuple[str, int]] = {}
    host_rel = None
    requests: dict[str, tuple[str, int]] = {}
    replies: dict[str, tuple[str, int]] = {}
    reply_refs: dict[str, tuple[str, int]] = {}

    # the host is the class with the most _on_<kind> handlers (callback
    # classes elsewhere may have an incidental _on_ method)
    best = 0
    for mod in tree.iter(_SCOPE):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                ons = [n for n in node.body
                       if isinstance(n, ast.FunctionDef)
                       and n.name.startswith("_on_")]
                if len(ons) > best:
                    best = len(ons)
                    host_rel = mod.relpath
                    handlers = {fn.name[4:]: (mod.relpath, fn.lineno)
                                for fn in ons}
    if len(handlers) < 2:
        return []

    # kind-forwarding wrappers: `def _broadcast(self, kind, ...)` whose
    # body constructs Envelope(kind) — call-site constants count as sends
    wrappers: dict[str, int] = {}        # func name -> kind param index
    for mod in tree.iter(_SCOPE):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            params = [a.arg for a in node.args.args if a.arg != "self"]
            for call in ast.walk(node):
                if isinstance(call, ast.Call) and \
                        isinstance(call.func, ast.Name) and \
                        call.func.id == "Envelope" and call.args and \
                        isinstance(call.args[0], ast.Name) and \
                        call.args[0].id in params:
                    wrappers[node.name] = params.index(call.args[0].id)

    for mod in tree.iter(_SCOPE):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name) and f.id == "Envelope":
                kind = None
                if node.args:
                    kind = _const_str(node.args[0])
                for kw in node.keywords:
                    if kw.arg == "kind":
                        kind = _const_str(kw.value)
                if kind is not None and mod.relpath != host_rel:
                    requests.setdefault(kind, (mod.relpath, node.lineno))
            elif isinstance(f, ast.Attribute) and f.attr == "reply" \
                    and node.args:
                kind = _const_str(node.args[0])
                if kind is not None:
                    replies.setdefault(kind, (mod.relpath, node.lineno))
            elif isinstance(f, ast.Attribute) and f.attr in wrappers:
                idx = wrappers[f.attr]
                if idx < len(node.args):
                    kind = _const_str(node.args[idx])
                    if kind is not None:
                        requests.setdefault(kind,
                                            (mod.relpath, node.lineno))
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Compare) and \
                    isinstance(node.left, ast.Attribute) and \
                    node.left.attr == "kind" and len(node.comparators) == 1:
                kind = _const_str(node.comparators[0])
                if kind is not None and mod.relpath != host_rel:
                    reply_refs.setdefault(kind,
                                          (mod.relpath, node.lineno))

    findings: list[Finding] = []
    if not handlers:
        return findings                   # no host in this tree — nothing on
    for kind in sorted(set(requests) - set(handlers)):
        rel, line = requests[kind]
        findings.append(Finding(
            "wire", "missing-handler", rel, line, kind,
            f"Envelope kind {kind!r} is sent but the host has no "
            f"_on_{kind} handler — it will fail on the wire"))
    for kind in sorted(set(handlers) - set(requests)):
        rel, line = handlers[kind]
        findings.append(Finding(
            "wire", "dead-handler", rel, line, kind,
            f"handler _on_{kind} has no sender anywhere in the tree"))
    for kind in sorted(set(reply_refs) - set(replies)):
        rel, line = reply_refs[kind]
        findings.append(Finding(
            "wire", "unknown-reply", rel, line, kind,
            f"client code compares against reply kind {kind!r} which "
            f"no handler ever sends"))
    return findings
