"""Fault-tolerant training loop: bitmap-indexed data, checkpoint cadence,
crash-safe restart, straggler-aware dispatch hooks.

This is the single-host driver used by examples/train_lm.py; on a real
cluster the same loop runs under jax.distributed with the production mesh
(launch/train.py wires that up)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax

from repro.checkpoint.store import (CheckpointManager, latest_step,
                                    restore_checkpoint)
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.optim.adamw import OptimConfig, init_opt_state
from repro.train.step import TrainConfig, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 300
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, lcfg: LoopConfig,
               batches: Callable[[int], Iterator[dict]],
               *, seed: int = 0, log=print) -> dict:
    """Runs to ``total_steps`` with checkpoint/restart.  ``batches(start)``
    must return a deterministic stream starting at ``start`` (the data
    pipeline replays from the checkpointed step — see data/pipeline.py)."""
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = init_opt_state(params, tcfg.optim)
    start = 0

    resume = latest_step(lcfg.ckpt_dir)
    if resume is not None:
        like = {"params": jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
            "opt": jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt)}
        restored, start = restore_checkpoint(lcfg.ckpt_dir, like)
        params, opt = restored["params"], restored["opt"]
        log(f"[restart] resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    mgr = CheckpointManager(lcfg.ckpt_dir, every_steps=lcfg.ckpt_every)
    it = batches(start)
    t0 = time.time()
    metrics = {}
    for step in range(start + 1, lcfg.total_steps + 1):
        batch = next(it)
        params, opt, metrics = step_fn(params, opt, batch)
        if step % lcfg.log_every == 0 or step == lcfg.total_steps:
            dt = time.time() - t0
            log(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                f"lr={float(metrics['lr']):.2e} "
                f"gnorm={float(metrics['grad_norm']):.2f} "
                f"({dt / max(step - start, 1):.2f}s/step)")
        mgr.maybe_save(step, {"params": params, "opt": opt})
    mgr.maybe_save(lcfg.total_steps, {"params": params, "opt": opt},
                   force=True)
    mgr.wait()
    return {"params": params, "opt": opt,
            "final_loss": float(metrics.get("loss", float("nan")))}
