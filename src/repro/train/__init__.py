from repro.train.step import make_train_step, TrainConfig  # noqa: F401
