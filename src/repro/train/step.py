"""Sharded training step: mixed-precision loss + grad, AdamW update,
optional gradient-accumulation microbatching (pipelines arbitrarily large
global batches through fixed activation memory)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import lm_loss, param_logical
from repro.optim.adamw import OptimConfig, apply_updates
from repro.parallel.sharding import constrain


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optim: OptimConfig = OptimConfig()
    accum_steps: int = 1          # gradient-accumulation microbatches


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Pure function of its inputs — jit/pjit it at the call site
    with the shardings from parallel.sharding."""

    grad_fn = jax.value_and_grad(lm_loss, has_aux=True)
    p_logical = param_logical(cfg)

    def shard_grads(grads):
        # Pin every gradient to its parameter's sharding: without this the
        # embedding-scatter gradient materializes replicated (V, d) f32
        # buffers per microstep — GBs per step at 256k vocabs.
        return {k: constrain(g, p_logical[k]) for k, g in grads.items()}

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, cfg, batch)
        return loss, metrics, shard_grads(grads)

    def accumulate(params, batch):
        # Unrolled (not lax.scan): microbatches are sequentially dependent
        # through the running sum, so activation liveness — and therefore
        # peak memory — matches a scan, while XLA's cost analysis (which
        # counts loop bodies once) stays exact for the roofline report.
        n = tcfg.accum_steps

        def micro(i):
            # Strided slicing (every n-th row) keeps each microbatch evenly
            # spread across the data-parallel shards — a contiguous slice
            # would put a whole microbatch on one device and reshard.
            def take(x, ax):
                if x.ndim < 2 or x.shape[ax] % n:
                    return x
                shp = (*x.shape[:ax], x.shape[ax] // n, n, *x.shape[ax + 1:])
                return jax.lax.index_in_dim(x.reshape(shp), i, axis=ax + 1,
                                            keepdims=False)
            return {k: take(x, 1 if k == "mrope_positions" else 0)
                    for k, x in batch.items()}

        grads = None
        loss_sum = jnp.zeros((), jnp.float32)
        p = params
        for i in range(n):
            loss, _, g = single(p, micro(i))
            grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
            loss_sum = loss_sum + loss
            # Serialize microsteps: the barrier ties the params used by
            # microstep i+1 to the completion of microstep i's grads, so
            # peak activation memory is one microbatch, not all of them.
            grads, loss_sum, p = jax.lax.optimization_barrier(
                (grads, loss_sum, p))
        grads = jax.tree.map(lambda g: g / n, grads)
        return loss_sum / n, {"loss": loss_sum / n}, grads

    def train_step(params: Any, opt_state: dict, batch: dict):
        if tcfg.accum_steps > 1:
            loss, metrics, grads = accumulate(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, tcfg.optim)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step
