"""Typed expression DSL: schema-aware predicates that lower to the engine's
:class:`repro.engine.planner.Pred` trees.

::

    from repro.db import col

    q = (col("city") == "SF") & col("temp").between(10, 25) & \\
        ~col("tag").isin(["flagged", "dup"])

Expressions are immutable and hashable — a :class:`repro.db.BitmapDB`
caches the lowered plan per expression, so a serving loop re-submitting the
same query never re-plans.  :func:`lower` maps an expression onto a schema:

  * ``col(c) == v``      -> ``key(schema.key_of(c, v))`` (for a binned
    column, the bin containing ``v``);
  * ``col(c) != v``      -> the negation of the above;
  * ``col(c).isin(vs)``  -> OR over the value keys (empty ``vs`` is a
    provable contradiction — the planner serves it as constant zeros);
  * ``col(c).between(lo, hi)`` (closed interval; also ``<``/``<=``/``>``/
    ``>=`` sugar on binned columns) -> OR over the overlapping bin keys;
  * ``& | ~``            -> ``And`` / ``Or`` / ``Not``.

Raw :class:`repro.engine.planner.Pred` trees (integer ``key(i)`` literals)
pass through :func:`lower` untouched — the compatibility shim for callers
that address key rows directly.
"""
from __future__ import annotations

import dataclasses
from typing import Union

from repro.db.schema import Schema
from repro.engine import planner


class Expr:
    """Base schema-level predicate; combine with ``&``, ``|``, ``~``."""

    def __and__(self, other: "Expr") -> "Expr":
        return AndExpr((self, _check(other)))

    def __or__(self, other: "Expr") -> "Expr":
        return OrExpr((self, _check(other)))

    def __invert__(self) -> "Expr":
        return NotExpr(self)


def _check(e) -> "Expr":
    if not isinstance(e, (Expr, planner.Pred)):
        raise TypeError(f"cannot combine an expression with {e!r}; did you "
                        "mean col(...) == value / .isin(...) / .between(...)?")
    return e


@dataclasses.dataclass(frozen=True)
class Eq(Expr):
    column: str
    value: object


@dataclasses.dataclass(frozen=True)
class In(Expr):
    column: str
    values: tuple


@dataclasses.dataclass(frozen=True)
class Between(Expr):
    """Closed interval [lo, hi] over a column's values."""
    column: str
    lo: object
    hi: object


@dataclasses.dataclass(frozen=True)
class AndExpr(Expr):
    children: tuple


@dataclasses.dataclass(frozen=True)
class OrExpr(Expr):
    children: tuple


@dataclasses.dataclass(frozen=True)
class NotExpr(Expr):
    child: object


@dataclasses.dataclass(frozen=True)
class ColumnRef:
    """``col(name)`` — build typed predicates with comparison operators."""
    name: str

    def __eq__(self, value) -> Expr:          # type: ignore[override]
        if isinstance(value, ColumnRef):
            raise TypeError("column-to-column comparison is not a bitmap "
                            "operation; compare against a value")
        return Eq(self.name, value)

    def __ne__(self, value) -> Expr:          # type: ignore[override]
        return NotExpr(Eq(self.name, value))

    def __hash__(self) -> int:                # __eq__ override drops it
        return hash(("ColumnRef", self.name))

    def isin(self, values) -> Expr:
        return In(self.name, tuple(values))

    def between(self, lo, hi) -> Expr:
        return Between(self.name, lo, hi)

    # range sugar (binned columns; lowered via Between against the edges)
    def __lt__(self, value) -> Expr:
        return Between(self.name, float("-inf"), _open_below(value))

    def __le__(self, value) -> Expr:
        return Between(self.name, float("-inf"), value)

    def __gt__(self, value) -> Expr:
        return Between(self.name, _open_above(value), float("inf"))

    def __ge__(self, value) -> Expr:
        return Between(self.name, value, float("inf"))


def _open_below(value):
    """Largest float strictly below ``value`` — turns an open bound into
    the closed interval Between models."""
    import math
    return math.nextafter(float(value), float("-inf"))


def _open_above(value):
    import math
    return math.nextafter(float(value), float("inf"))


def col(name: str) -> ColumnRef:
    """Reference a schema column by name."""
    return ColumnRef(str(name))


AnyQuery = Union[Expr, planner.Pred]


def _or_keys(keys) -> planner.Pred:
    """OR over key rows; an empty key set lowers to a provable
    contradiction (the planner simplifies ``k & ~k`` to zero clauses and
    serves it as constant zeros with no kernel pass)."""
    keys = list(keys)
    if not keys:
        return planner.key(0) & ~planner.key(0)
    if len(keys) == 1:
        return planner.key(keys[0])
    return planner.Or(tuple(planner.key(k) for k in keys))


def lower(expr: AnyQuery, schema: Schema | None) -> planner.Pred:
    """Lower a schema expression to an engine predicate tree.  Raw ``Pred``
    literals pass through, and mixed trees (``key(3) & (col("c") == v)``)
    lower branch by branch."""
    if isinstance(expr, planner.Key):
        return expr
    if isinstance(expr, (planner.Not, NotExpr)):
        return planner.Not(lower(expr.child, schema))
    if isinstance(expr, (planner.And, AndExpr)):
        return planner.And(tuple(lower(c, schema) for c in expr.children))
    if isinstance(expr, (planner.Or, OrExpr)):
        return planner.Or(tuple(lower(c, schema) for c in expr.children))
    if not isinstance(expr, Expr):
        raise TypeError(f"not a query expression: {expr!r}")
    if schema is None:
        raise ValueError("schema-level expressions need a Schema; this "
                         "session was opened without one (raw key(i) "
                         "predicates still work)")
    return _lower(expr, schema)


def _lower(e: Expr, s: Schema) -> planner.Pred:
    if isinstance(e, Eq):
        return planner.key(s.key_of(e.column, e.value))
    if isinstance(e, In):
        keys = [s.key_of(e.column, v) for v in e.values]
        return _or_keys(dict.fromkeys(keys))    # dedup, keep order
    if isinstance(e, Between):
        return _or_keys(s[e.column].keys_between(e.lo, e.hi))
    raise TypeError(f"not a query expression: {e!r}")
