"""``repro.db`` — one schema-aware database facade over engine + store +
serve.

The paper's BIC core is valuable because it hides packing, carry-splicing,
and power-mode detail behind one simple ingest/query port; this package is
that port for the reproduction stack (the same argument bulk bitwise
engines make: bulk operators get adopted through a small declarative
interface, not per-pass plumbing).  Four pieces:

  * :class:`Schema` / :class:`Column` — named, typed columns (categorical
    values, binned numerics) mapped onto bitmap-index key rows.
  * :func:`col` — the typed expression DSL (``col("city") == "SF"``,
    ``col("temp").between(10, 25)``, ``col("tag").isin([...])``, composed
    with ``& | ~``) lowering to engine predicate trees.
  * :class:`BitmapDB` — the session object: streaming ingest with
    auto-spill durability, selectivity-stats-ordered planning, lazy
    :class:`Result` handles, crash recovery via :func:`open`, and
    ``serve_step()`` wrapping the bucketed batch executor.
  * :func:`include_exclude_pred` — the deprecation shim keeping legacy
    ``include=``/``exclude=`` key-list callers byte-identical.

Everything below (``repro.engine``, ``repro.store``, ``repro.serve``)
stays importable on its own; this facade is the documented way in::

    import repro

    db = repro.BitmapDB(schema, path="/data/idx")
    db.ingest({"city": [...], "temp": [...]})
    hot = db.query((repro.col("city") == "SF") &
                   repro.col("temp").between(20, 30))
    print(hot.count, hot.ids[:10])

Symbols resolve lazily (the :mod:`repro.engine` idiom) so importing
``repro.db`` never drags jax-heavy modules in before first use.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # schema
    "Schema": "schema", "Column": "schema",
    # expression DSL
    "col": "expr", "Expr": "expr", "lower": "expr",
    # results
    "Result": "result", "LazyBatch": "result", "ResultBatch": "result",
    # session
    "BitmapDB": "session", "include_exclude_pred": "session",
    "SCHEMA_FILE": "session",
}
_ALIASES = {"open": ("session", "open_db")}

__all__ = sorted(_EXPORTS) + sorted(_ALIASES) + ["schema", "expr",
                                                 "result", "session"]


def __getattr__(name):
    if name in ("schema", "expr", "result", "session"):
        return importlib.import_module(f"{__name__}.{name}")
    if name in _ALIASES:
        mod, attr = _ALIASES[name]
        return getattr(importlib.import_module(f"{__name__}.{mod}"), attr)
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(f"{__name__}.{mod}"), name)


def __dir__():
    return __all__
