"""Lazy query-result handles.

``BitmapDB.query`` / ``query_many`` return :class:`Result` handles instead
of raw arrays: nothing executes until the first ``.rows`` / ``.count`` /
``.ids`` access, and every result of one ``query_many`` batch shares a
single :class:`LazyBatch` — the first materialization runs the WHOLE batch
through the engine's bucketed executors (one dispatch per plan-shape
bucket), exactly as the raw ``engine.batch.execute_many`` path would.
``query_many`` itself returns a :class:`ResultBatch`, a sequence that
builds the per-query :class:`Result` objects on access — submitting a
1000-query batch costs plan lookups, not a thousand handle allocations.
"""
from __future__ import annotations

from collections.abc import Sequence
from typing import Callable

import numpy as np


def unpack_ids(row_bits: np.ndarray, num_records: int) -> np.ndarray:
    """Matching record ordinals (sorted) of ONE packed result row —
    the single bitmap->ordinals extraction every result surface
    (:class:`Result`, :class:`ResultBatch`,
    :class:`repro.serve.service.QueryFuture`) shares."""
    if row_bits.size == 0:
        return np.empty((0,), np.int64)
    ids = np.flatnonzero(
        np.unpackbits(row_bits.view(np.uint8), bitorder="little"))
    # tail bits are masked zero by the engine, but guard anyway
    return ids[ids < num_records]


class LazyBatch:
    """One deferred batched execution shared by a set of results."""

    def __init__(self, run: Callable[[], tuple]):
        self._run = run
        self._out: tuple | None = None

    @property
    def executed(self) -> bool:
        return self._out is not None

    def materialize(self) -> tuple:
        """(rows (Q, Nw) uint32, counts (Q,) int32) — runs once, then
        serves the cached device arrays."""
        if self._out is None:
            self._out = self._run()
        return self._out


class Result:
    """Handle to one query's slice of a (lazily executed) batch.

    * ``.rows``  — the packed uint32 result bitmap (``ceil(N/32)`` words,
      one bit per record, tail bits zero);
    * ``.count`` — matching-record count (int);
    * ``.ids``   — matching record ordinals as a sorted ``np.ndarray``.
    """

    __slots__ = ("_batch", "_qi", "_num_records", "_query")

    def __init__(self, batch: LazyBatch, qi: int, num_records: int,
                 query=None):
        self._batch = batch
        self._qi = qi
        self._num_records = num_records
        self._query = query

    @property
    def rows(self):
        return self._batch.materialize()[0][self._qi]

    @property
    def count(self) -> int:
        return int(self._batch.materialize()[1][self._qi])

    @property
    def raw(self) -> tuple:
        """(packed row, count) as the engine's jax arrays — the
        compatibility surface legacy callers (``BICCore.query``) return."""
        rows, counts = self._batch.materialize()
        return rows[self._qi], counts[self._qi]

    @property
    def ids(self) -> np.ndarray:
        return unpack_ids(np.asarray(self.rows), self._num_records)

    def __len__(self) -> int:
        return self.count

    def __bool__(self) -> bool:
        return self.count > 0

    def __repr__(self) -> str:
        state = (f"count={self.count}" if self._batch.executed
                 else "pending")
        label = repr(self._query) if self._query is not None else ""
        if len(label) > 60:
            label = label[:57] + "..."
        q = f" {label}" if label else ""
        return f"<Result{q} {state} of {self._num_records} records>"


class ResultBatch(Sequence):
    """The sequence ``query_many`` returns: one shared :class:`LazyBatch`,
    with :class:`Result` handles constructed lazily per index."""

    __slots__ = ("_batch", "_num_records", "_queries")

    def __init__(self, batch: LazyBatch, num_records: int, queries):
        self._batch = batch
        self._num_records = num_records
        self._queries = queries

    def __len__(self) -> int:
        return len(self._queries)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        if not -len(self._queries) <= i < len(self._queries):
            raise IndexError(i)
        i = i % len(self._queries)
        return Result(self._batch, i, self._num_records,
                      query=self._queries[i])

    def materialize(self) -> tuple:
        """Force execution; returns the raw (rows (Q, Nw), counts (Q,))."""
        return self._batch.materialize()

    def all_ids(self) -> list[np.ndarray]:
        """Matching record ordinals for EVERY query, in ONE device-to-host
        transfer of the whole (Q, Nw) rows array — per-``Result`` ``.ids``
        would sync once per query, which dominates a burst on a real
        accelerator."""
        rows, _ = self._batch.materialize()
        bits = np.asarray(rows)              # one bulk transfer
        n = self._num_records
        if bits.size == 0:
            return [np.empty((0,), np.int64) for _ in self._queries]
        # iterate the queries, not the rows — a pad_output batch carries
        # extra unspecified rows past the real query count
        return [unpack_ids(bits[qi], n)
                for qi in range(len(self._queries))]

    def __repr__(self) -> str:
        state = "executed" if self._batch.executed else "pending"
        return (f"<ResultBatch of {len(self)} queries ({state}) over "
                f"{self._num_records} records>")
