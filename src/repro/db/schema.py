"""`Schema` — named, typed columns mapped onto bitmap-index key rows.

The engine below this layer knows nothing but integer key rows: a record
is a bag of integer words, key row ``k`` is set for every record containing
word ``k``.  A :class:`Schema` is the dictionary that makes those rows mean
something:

  * a **categorical** column owns one key row per distinct value
    (``city == "SF"`` is exactly one row test);
  * a **binned** numeric column owns one key row per half-open bin
    ``[edges[i], edges[i+1])`` (range predicates become ORs over the
    overlapping bins — the classic bitmap-index binning trade: coarser bins
    -> fewer rows, weaker pruning).

Key rows are assigned contiguously in column order, so a schema with a
3-value categorical followed by a 4-bin numeric occupies rows 0-2 and 3-6.
:meth:`Schema.encode` turns structured rows (dicts, or a column-major
mapping of arrays) into the ``(N, num_columns)`` int32 key-word records the
engine backends index directly — one word per column, each word a global
key id, so per-key value frequencies from :meth:`count_keys` are EXACT
set-bit counts for schema-encoded data.

Schemas serialize to/from JSON (:meth:`to_json` / :meth:`from_json`) so a
:class:`repro.db.BitmapDB` opened with ``path=`` can persist its schema
next to the segment store and ``repro.db.open`` can recover it.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import json
from typing import Iterable, Mapping, Sequence

import numpy as np

CATEGORICAL = "categorical"
BINNED = "binned"


@dataclasses.dataclass(frozen=True)
class Column:
    """One named column and its key-row mapping (``base`` is assigned by
    the owning :class:`Schema`)."""
    name: str
    kind: str                          # CATEGORICAL | BINNED
    values: tuple = ()                 # categorical: distinct values
    edges: tuple = ()                  # binned: ascending bin edges
    base: int = 0                      # first key row owned by this column

    @staticmethod
    def categorical(name: str, values: Iterable) -> "Column":
        vals = tuple(values)
        if not vals:
            raise ValueError(f"column {name!r} needs at least one value")
        if len(set(vals)) != len(vals):
            raise ValueError(f"column {name!r} has duplicate values")
        return Column(name, CATEGORICAL, values=vals)

    @staticmethod
    def binned(name: str, edges: Iterable[float]) -> "Column":
        e = tuple(float(x) for x in edges)
        if len(e) < 2 or any(a >= b for a, b in zip(e, e[1:])):
            raise ValueError(f"column {name!r} needs >= 2 strictly "
                             "ascending bin edges")
        return Column(name, BINNED, edges=e)

    @property
    def cardinality(self) -> int:
        """Key rows this column owns."""
        return (len(self.values) if self.kind == CATEGORICAL
                else len(self.edges) - 1)

    # ------------------------------------------------------- value -> key
    @functools.cached_property
    def _value_keys(self) -> dict:
        """value -> key row lookup (cached_property writes the instance
        ``__dict__`` directly, so it coexists with frozen=True)."""
        return {v: self.base + i for i, v in enumerate(self.values)}

    def key_of(self, value) -> int:
        """The single key row testing ``value`` (a categorical value, or
        the bin containing a numeric value)."""
        if self.kind == CATEGORICAL:
            try:
                return self._value_keys[value]
            except KeyError:
                raise KeyError(f"column {self.name!r} has no value "
                               f"{value!r}") from None
            except TypeError:              # unhashable probe value
                raise KeyError(f"column {self.name!r} has no value "
                               f"{value!r}") from None
        v = float(value)
        if not self.edges[0] <= v <= self.edges[-1]:
            raise KeyError(f"column {self.name!r}: {value!r} outside "
                           f"binned range [{self.edges[0]}, "
                           f"{self.edges[-1]}]")
        # right edge of the last bin is inclusive (it would otherwise map
        # to a nonexistent bin)
        bin_i = min(bisect.bisect_right(self.edges, v) - 1,
                    self.cardinality - 1)
        return self.base + bin_i

    def keys_between(self, lo, hi) -> tuple[int, ...]:
        """Key rows whose value set can intersect the CLOSED interval
        ``[lo, hi]`` — for binned columns the overlapping bins, for
        categoricals the values inside the interval."""
        if lo > hi:
            return ()
        if self.kind == CATEGORICAL:
            return tuple(self.base + i for i, v in enumerate(self.values)
                         if lo <= v <= hi)
        nbins = self.cardinality
        if float(lo) > self.edges[-1] or float(hi) < self.edges[0]:
            return ()
        first = min(max(bisect.bisect_right(self.edges, float(lo)) - 1, 0),
                    nbins - 1)
        last = min(max(bisect.bisect_right(self.edges, float(hi)) - 1, 0),
                   nbins - 1)
        return tuple(self.base + i for i in range(first, last + 1))

    def key_label(self, key_id: int) -> str:
        i = key_id - self.base
        if self.kind == CATEGORICAL:
            return f"{self.name}={self.values[i]!r}"
        return f"{self.name}∈[{self.edges[i]}, {self.edges[i + 1]})"


class Schema:
    """An ordered set of :class:`Column` s sharing one key-row space."""

    def __init__(self, columns: Sequence[Column]):
        if not columns:
            raise ValueError("a Schema needs at least one column")
        names = [c.name for c in columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate column names in {names}")
        out, base = [], 0
        for c in columns:
            out.append(dataclasses.replace(c, base=base))
            base += c.cardinality
        self.columns: tuple[Column, ...] = tuple(out)
        self.num_keys: int = base
        self._by_name = {c.name: c for c in self.columns}

    def __getitem__(self, name: str) -> Column:
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(f"schema has no column {name!r}; columns: "
                           f"{sorted(self._by_name)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def __eq__(self, other) -> bool:
        return (isinstance(other, Schema)
                and self.columns == other.columns)

    def __hash__(self) -> int:
        return hash(self.columns)

    def __repr__(self) -> str:
        cols = ", ".join(f"{c.name}:{c.kind}[{c.cardinality}]"
                         for c in self.columns)
        return f"Schema({cols}; {self.num_keys} keys)"

    def key_of(self, column: str, value) -> int:
        return self[column].key_of(value)

    def key_label(self, key_id: int) -> str:
        """Human name of one key row (reverse mapping, for repr/debug)."""
        for c in self.columns:
            if c.base <= key_id < c.base + c.cardinality:
                return c.key_label(key_id)
        raise KeyError(f"key id {key_id} outside schema "
                       f"({self.num_keys} keys)")

    # ------------------------------------------------------------- encode
    def encode(self, rows) -> np.ndarray:
        """Structured rows -> ``(N, num_columns)`` int32 key-word records.

        ``rows`` is either column-major (a mapping ``{name: values}``, all
        the same length) or row-major (an iterable of per-row mappings).
        Every column must be present in every row — a bitmap index has no
        NULL; model optional attributes as an explicit category."""
        if isinstance(rows, Mapping):
            cols = {}
            n = None
            for c in self.columns:
                if c.name not in rows:
                    raise KeyError(f"encode: missing column {c.name!r}")
                vals = list(rows[c.name])
                if n is None:
                    n = len(vals)
                elif len(vals) != n:
                    raise ValueError(
                        f"encode: column {c.name!r} has {len(vals)} values, "
                        f"expected {n}")
                cols[c.name] = vals
            extra = set(rows) - set(cols)
            if extra:
                raise KeyError(f"encode: unknown columns {sorted(extra)}")
            out = np.empty((n or 0, len(self.columns)), np.int32)
            for j, c in enumerate(self.columns):
                out[:, j] = [c.key_of(v) for v in cols[c.name]]
            return out
        rows = list(rows)
        out = np.empty((len(rows), len(self.columns)), np.int32)
        for i, r in enumerate(rows):
            extra = set(r) - set(self._by_name)
            if extra:
                raise KeyError(f"encode: unknown columns {sorted(extra)} "
                               f"in row {i}")
            for j, c in enumerate(self.columns):
                if c.name not in r:
                    raise KeyError(f"encode: row {i} missing column "
                                   f"{c.name!r}")
                out[i, j] = c.key_of(r[c.name])
        return out

    def count_keys(self, encoded: np.ndarray) -> np.ndarray:
        """Per-key occurrence counts over encoded records (int64,
        ``num_keys`` long).  Exact set-bit counts when every record's
        words are distinct — always true for :meth:`encode` output (one
        word per column, disjoint key ranges); an upper bound for raw
        key-word records that may repeat a key within a record."""
        enc = np.asarray(encoded)
        words = enc[(enc >= 0) & (enc < self.num_keys)]
        return np.bincount(words, minlength=self.num_keys).astype(np.int64)

    # ----------------------------------------------------------- serialize
    def to_json(self) -> str:
        return json.dumps({
            "version": 1,
            "columns": [{"name": c.name, "kind": c.kind,
                         "values": list(c.values), "edges": list(c.edges)}
                        for c in self.columns]})

    @classmethod
    def from_json(cls, text: str) -> "Schema":
        data = json.loads(text)
        cols = []
        for c in data["columns"]:
            if c["kind"] == CATEGORICAL:
                vals = [tuple(v) if isinstance(v, list) else v
                        for v in c["values"]]
                cols.append(Column.categorical(c["name"], vals))
            else:
                cols.append(Column.binned(c["name"], c["edges"]))
        return cls(cols)
