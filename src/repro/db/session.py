"""`BitmapDB` — the one schema-aware session object over engine + store.

The paper's silicon hides packing, carry-splicing, and power-mode detail
behind a simple ingest/query port; this class is that port for the whole
reproduction stack.  One object owns:

  * **ingest** — :meth:`ingest` / :meth:`append` encode structured rows
    through the :class:`repro.db.Schema` and stream them into a
    :class:`repro.engine.runtime.StreamingIndexer` (jitted shift/carry
    splice, no rebuild); :meth:`append_encoded` takes pre-encoded key-word
    records directly (the data-pipeline path).
  * **durability** — opened with ``path=``, every append is WAL-logged
    before the in-memory splice and the tail auto-spills as immutable
    segments past ``spill_records`` (:mod:`repro.store`); :meth:`snapshot`
    force-spills, and :func:`BitmapDB.open` recovers a crashed session
    bit-identically from manifest + WAL (the schema persists as
    ``SCHEMA.json`` next to the segments).
  * **query** — :meth:`query` / :meth:`query_many` accept DSL expressions
    (``col("city") == "SF"``), raw engine predicates (``key(3) & ~key(5)``),
    or pre-built plans; lowering and planning cache per expression, plans
    order their DNF clauses by the session's live per-key selectivity
    stats (:class:`repro.engine.planner.KeyStats`), and execution runs
    through the engine's bucketed batch executors.  Results come back as
    lazy :class:`repro.db.Result` handles.
  * **serving** — :meth:`serve_step` wraps the bucketed batch executor as
    a raw ``(rows, counts)`` step function for serving loops
    (:mod:`repro.serve.step` routes through it).

Read-only sessions wrap an existing index: :meth:`BitmapDB.from_index`
accepts an in-memory :class:`repro.engine.policy.BitmapIndex` or a
segment-backed :class:`repro.store.StoredIndex` (served segment-parallel,
stacked into one vmapped dispatch when word counts are uniform).
"""
from __future__ import annotations

import os
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.db import expr as expr_mod
from repro.db.result import LazyBatch, Result, ResultBatch
from repro.db.schema import Schema
from repro.obs import metrics as obs_metrics
from repro.engine import (backends, batch as engine_batch, costmodel,
                          planner, policy)
from repro.engine.runtime import StreamingIndexer

SCHEMA_FILE = "SCHEMA.json"


def include_exclude_pred(include: Sequence[int] = (),
                         exclude: Sequence[int] = ()) -> planner.Pred:
    """Deprecation shim for the legacy ``include=``/``exclude=`` call
    surface: AND of positive/negated key-row literals, byte-identical to
    what those callers always got.  Callers that passed NEITHER list get
    the original empty-query ValueError, no warning — they used nothing
    deprecated."""
    if include or exclude:
        warnings.warn(
            "include=/exclude= key lists are deprecated; use a repro.db "
            "expression (col(...) == value) or an engine predicate "
            "(key(i) & ~key(j))", DeprecationWarning, stacklevel=3)
    return planner.from_include_exclude(include, exclude)


def _popcounts(packed) -> np.ndarray:
    """Exact per-key set-bit counts of a packed (M, W) array."""
    arr = np.asarray(jax.device_get(packed))
    if arr.size == 0:
        return np.zeros((arr.shape[0],), np.int64)
    return np.bitwise_count(arr).sum(axis=1, dtype=np.int64)


class BitmapDB:
    """One bitmap-index database session (see module docstring)."""

    def __init__(self, schema: Schema | None = None, *,
                 num_keys: int | None = None, path: str | None = None,
                 backend: str = "auto", spill_records: int | None = 4096,
                 capacity_words: int = 16, _restore: bool = False):
        if schema is None and num_keys is None:
            raise ValueError("BitmapDB needs a Schema (or num_keys= for a "
                             "raw key-addressed session)")
        if schema is not None and num_keys is not None \
                and num_keys != schema.num_keys:
            raise ValueError(f"num_keys={num_keys} contradicts the schema "
                             f"({schema.num_keys} keys)")
        self.schema = schema
        # "auto" stays UNRESOLVED: the query path hands it to the engine,
        # where the measured cost model picks per wave.  Index creation is
        # one fixed bulk pass, so that side pins a concrete backend now.
        self.backend = ("auto" if backend == "auto"
                        else backends.resolve_backend(backend))
        self._create_backend = backends.resolve_backend(backend)
        self.path = path
        m = schema.num_keys if schema is not None else int(num_keys)
        self._keys = jnp.arange(m, dtype=jnp.int32)
        self._index = None                     # read-only sessions only
        self._counts = np.zeros((m,), np.int64)
        self._plans: dict = {}
        self._plans_by_id: dict = {}       # id(expr) fast path (see _plan_for)
        # typed counters in a per-session registry; cache_stats() is a
        # view over these (services attach the registry as their "db"
        # subtree for one exportable metric tree)
        self.registry = obs_metrics.Registry()
        self._cache_counters = {
            k: self.registry.counter(f"plan_cache_{k}_total")
            for k in ("id_hits", "value_hits", "misses",
                      "id_evictions", "value_evictions")}
        self._stats_cache: tuple[int, planner.KeyStats] | None = None
        self._view_cache = None            # (buf, n, BitmapIndex) snapshot
        if path is None:
            self._si = StreamingIndexer(self._keys,
                                        backend=self._create_backend,
                                        capacity_words=capacity_words)
            return
        from repro.store import SegmentStore
        store = SegmentStore(path)
        self._persist_schema(path)
        if _restore:
            self._si = StreamingIndexer.restore(
                store, self._keys, backend=self._create_backend,
                capacity_words=capacity_words, flush_records=spill_records)
            self._counts = _popcounts(self._si.index.packed)
            return
        self._si = StreamingIndexer(self._keys,
                                    backend=self._create_backend,
                                    capacity_words=capacity_words)
        try:
            self._si.attach_store(store, flush_records=spill_records)
        except ValueError as e:
            raise ValueError(
                f"{path} already holds a durable index; resume it with "
                f"repro.db.open({path!r}) instead of BitmapDB(path=...)"
            ) from e

    # ------------------------------------------------------------ open/wrap
    @classmethod
    def open(cls, path: str, schema: Schema | None = None, *,
             num_keys: int | None = None, backend: str = "auto",
             spill_records: int | None = 4096,
             capacity_words: int = 16) -> "BitmapDB":
        """Recover a durable session from ``path``: committed segments +
        surviving WAL blocks replay into a live index bit-identical to the
        pre-crash one, with per-key stats recounted exactly from the
        recovered packed rows.  The schema is loaded from the persisted
        ``SCHEMA.json`` when not given (and verified against it when it
        is); ``num_keys=`` opens a raw key-addressed store that never had
        one."""
        sf = os.path.join(path, SCHEMA_FILE)
        if schema is None and os.path.exists(sf):
            with open(sf) as f:           # noqa: PLW1514 (ascii json)
                schema = Schema.from_json(f.read())
        if schema is None and num_keys is None:
            raise FileNotFoundError(
                f"{sf} not found — pass schema= or num_keys= to open a "
                "store created without a persisted schema")
        return cls(schema, num_keys=None if schema is not None else num_keys,
                   path=path, backend=backend, spill_records=spill_records,
                   capacity_words=capacity_words, _restore=True)

    @classmethod
    def from_index(cls, index, schema: Schema | None = None, *,
                   backend: str = "auto") -> "BitmapDB":
        """Wrap an existing index as a READ-ONLY query session: an
        in-memory :class:`repro.engine.policy.BitmapIndex` or a
        segment-backed :class:`repro.store.StoredIndex` (served
        segment-parallel).  Appends raise; stats come from exact popcounts
        on first use."""
        m = int(index.num_keys)
        if schema is not None and schema.num_keys != m:
            raise ValueError(f"index has {m} key rows but the schema "
                             f"defines {schema.num_keys}")
        db = cls(schema, num_keys=m if schema is None else None,
                 backend=backend)
        db._si = None
        db._index = index
        db._counts = None                  # lazily popcounted
        return db

    # ----------------------------------------------------------- properties
    @property
    def num_keys(self) -> int:
        return int(self._keys.shape[0])

    @property
    def num_records(self) -> int:
        if self._si is not None:
            return self._si.num_records
        return int(self._index.num_records)

    @property
    def index(self) -> policy.BitmapIndex:
        """The live contiguous index (read-only StoredIndex sessions stay
        segment-parallel — materialize explicitly if you must)."""
        if self._si is not None:
            return self._si.index
        if isinstance(self._index, policy.BitmapIndex):
            return self._index
        raise TypeError(
            "this session serves a segment-backed StoredIndex; use "
            "query()/query_many(), or index.to_bitmap_index() to "
            "materialize")

    @property
    def store(self) -> "SegmentStore":
        return self._si.store if self._si is not None else None

    @property
    def indexer(self) -> "StreamingIndexer":
        """The live :class:`repro.engine.runtime.StreamingIndexer` (None
        for read-only ``from_index`` sessions) — the hook point service
        maintenance uses to move spills off the append path."""
        return self._si

    @property
    def stats(self) -> planner.KeyStats:
        """Live per-key set-bit counts (exact) as planner cardinality
        estimates."""
        if self._counts is None:           # read-only: popcount on demand
            idx = self._index
            if hasattr(idx, "parts"):      # StoredIndex
                c = np.zeros((self.num_keys,), np.int64)
                for part, _ in idx.parts:
                    c += _popcounts(part)
                self._counts = c
            else:
                self._counts = _popcounts(idx.packed)
        n = self.num_records
        if self._stats_cache is None or self._stats_cache[0] != n:
            self._stats_cache = (n, planner.KeyStats(
                tuple(int(c) for c in self._counts), n))
        return self._stats_cache[1]

    # --------------------------------------------------------------- ingest
    def ingest(self, rows) -> int:
        """Bulk-load structured rows (see :meth:`repro.db.Schema.encode`
        for accepted shapes); returns the new total record count."""
        return self.append(rows)

    def append(self, rows) -> int:
        """Stream structured rows into the live index (auto-spilling past
        the ``spill_records`` threshold when opened with ``path=``)."""
        if self.schema is None:
            raise ValueError("this session has no Schema; use "
                             "append_encoded with raw key-word records")
        return self.append_encoded(self.schema.encode(rows))

    def append_encoded(self, records) -> int:
        """Stream pre-encoded key-word records (N, W): each int word is a
        global key id (words outside [0, num_keys) match no key)."""
        if self._si is None:
            raise RuntimeError("read-only session (from_index) — open a "
                               "BitmapDB with a schema/path to ingest")
        records = jnp.asarray(records, jnp.int32)
        if records.ndim != 2:
            raise ValueError(f"records must be (N, W), got "
                             f"{records.shape}")
        if records.shape[0]:
            block = backends.get_backend(self._create_backend).create_index(
                records, self._keys)
            self._si.append_indexed(records, block)
            self._counts += _popcounts(block)
        return self.num_records

    # ----------------------------------------------------------- durability
    def snapshot(self) -> None:
        """Force-spill the in-memory tail as an immutable segment (atomic
        manifest commit); a no-op when nothing new arrived."""
        if self._si is None or self._si.store is None:
            raise RuntimeError("no store attached — open the BitmapDB "
                               "with path= to make it durable")
        self._si.spill()

    def _persist_schema(self, path: str) -> None:
        if self.schema is None:
            return
        from repro.store import format as fmt
        os.makedirs(path, exist_ok=True)
        sf = os.path.join(path, SCHEMA_FILE)
        if os.path.exists(sf):
            with open(sf) as f:
                stored = Schema.from_json(f.read())
            if stored != self.schema:
                raise ValueError(
                    f"{path} was created with a different schema "
                    f"({stored!r}); one store persists ONE schema")
        else:
            fmt.write_bytes_atomic(sf, self.schema.to_json().encode())

    # ---------------------------------------------------------------- query
    #: cache entries above this are dropped wholesale — bounds memory for
    #: workloads that build every expression object fresh (id cache) or
    #: never repeat a value (value cache); both limits are deliberately
    #: the same so neither cache can outgrow the other.
    _ID_CACHE_LIMIT = 65536
    _VALUE_CACHE_LIMIT = 65536

    def _plan_for(self, q):
        # serving loops re-submit the same expression OBJECTS: an identity
        # hit skips even the value-hash of a nested tree.  Entries keep a
        # strong reference to the query, so a cached id can never be a
        # recycled object's — a hit IS the same object.
        c = self._cache_counters
        hit = self._plans_by_id.get(id(q))
        if hit is not None:
            c["id_hits"].inc()
            return hit[1]
        if isinstance(q, (planner.QueryPlan, planner.FactoredPlan,
                          planner.CompositePlan)):
            return q
        pl = self._plans.get(q)
        if pl is None:
            c["misses"].inc()
            pred = expr_mod.lower(q, self.schema)
            planner.check_key_range(planner.key_indices(pred),
                                    self.num_keys)
            # stats ordering is opportunistic: live sessions maintain
            # counts incrementally; a read-only wrapper only pays the
            # popcount if the caller already asked for .stats
            stats = self.stats if self._counts is not None else None
            pl = planner.plan(pred, stats=stats)
            if len(self._plans) >= self._VALUE_CACHE_LIMIT:
                c["value_evictions"].add(len(self._plans))
                self._plans.clear()
            self._plans[q] = pl
        else:
            c["value_hits"].inc()
        if len(self._plans_by_id) >= self._ID_CACHE_LIMIT:
            c["id_evictions"].add(len(self._plans_by_id))
            self._plans_by_id.clear()
        self._plans_by_id[id(q)] = (q, pl)
        return pl

    def cache_stats(self) -> dict:
        """Plan-cache health for service metrics: hit/miss/eviction
        counters plus the live sizes of the identity-keyed and
        value-keyed caches (both bounded at 64k entries, dropped
        wholesale at the limit)."""
        out = {k: c.value for k, c in self._cache_counters.items()}
        out["id_size"] = len(self._plans_by_id)
        out["value_size"] = len(self._plans)
        return out

    def replan(self) -> None:
        """Drop the per-expression plan cache so future queries re-order
        their clauses against the CURRENT selectivity stats (ordering is a
        perf detail — cached plans stay correct forever)."""
        self._plans.clear()
        self._plans_by_id.clear()
        self._stats_cache = None

    def _execute(self, plans: Sequence, view, pad_output: bool = False,
                 backend: str | None = None) -> tuple:
        # live sessions hand their exact per-key stats to the cost model
        # (read-only wrappers only once the caller has paid for .stats)
        stats = self.stats if self._counts is not None else None
        be = backend if backend is not None else self.backend
        if hasattr(view, "parts"):              # StoredIndex
            return engine_batch.execute_many_segments(
                view.parts, plans, backend=be, stats=stats)
        return engine_batch.execute_many(
            view.packed, plans, num_records=view.num_records,
            backend=be, pad_output=pad_output, stats=stats)

    def _view(self):
        """Immutable snapshot the lazy batch executes against — a query
        sees the db as of query() time even if materialized after later
        appends (packed buffers are functional jax arrays).  The packed
        slice out of the indexer's capacity buffer is cached per
        (buffer, record count): a steady-state serving loop re-queries
        without re-copying the index."""
        if self._si is None:
            return self._index
        buf, n = self._si.view()           # consistent under appends
        c = self._view_cache
        if c is not None and c[0] is buf and c[1] == n:
            return c[2]
        idx = policy.BitmapIndex(buf[:, :policy.num_words(n)], n)
        self._view_cache = (buf, n, idx)
        return idx

    def query(self, q) -> Result:
        """One expression / predicate / plan -> a lazy :class:`Result`."""
        return self.query_many([q])[0]

    def explain(self, q) -> dict:
        """How this session would run ``q`` — without running it.

        Returns a plain dict: the cached plan object (``plan``), its
        lowered pass ``program`` and canonical padded ``bucket_shape``
        (None for composite fallbacks / contradictions), the KeyStats
        selectivity estimate (``est_matches`` / ``est_selectivity``, None
        without stats), the ``backend`` a dispatch would land on right
        now, and — when the session runs ``auto`` — the full cost-model
        ``decision``: per-candidate time ``estimates``, the chosen
        factoring/stacking, and the model's input ``terms``.  Purely
        observational: no device work, no cache perturbation beyond plan
        lowering.
        """
        pl = self._plan_for(q)
        view = self._view()
        if hasattr(view, "parts"):              # StoredIndex
            segments = len(view.parts)
            num_words = max((p.shape[1] for p, _ in view.parts), default=0)
        else:
            segments = 1
            num_words = view.packed.shape[1]
        stats = self.stats if self._counts is not None else None
        out: dict = {
            "plan": pl,
            "program": None,
            "bucket_shape": None,
            "num_records": self.num_records,
            "num_words": num_words,
            "segments": segments,
            "est_matches": None,
            "est_selectivity": None,
        }
        if isinstance(pl, planner.CompositePlan):
            out["fallback"] = "composite"       # served via planner.execute
        else:
            prog, shape, _, _ = engine_batch._lowered(pl)
            out["program"] = prog
            out["bucket_shape"] = shape
            if shape is None:
                out["fallback"] = "contradiction"   # constant all-zeros
        em = costmodel.estimate_matches([pl], stats)
        if em is not None:
            out["est_matches"] = em
            out["est_selectivity"] = (em / self.num_records
                                      if self.num_records else 0.0)
        if self.backend == "auto":
            decision = costmodel.decide(
                [pl], num_words=num_words, num_segments=segments,
                num_keys=self.num_keys, stats=stats)
            out["backend"] = decision.backend
            out["decision"] = {
                "backend": decision.backend,
                "factor": decision.factor,
                "stack_uniform": decision.stack_uniform,
                "estimates": dict(decision.estimates),
                "terms": dict(decision.terms),
            }
        else:
            out["backend"] = self.backend
            out["decision"] = None
        return out

    def query_many(self, queries: Sequence, *, pad_output: bool = False,
                   backend: str | None = None) -> ResultBatch:
        """A batch of expressions in ONE lazily executed bucketed dispatch
        set; returns a :class:`ResultBatch` (sequence of lazy
        :class:`Result` handles, in input order).  ``pad_output=True``
        pads the materialized arrays' query axis to a power of two
        (handles still cover exactly the submitted queries) — the
        serving scheduler uses this so varying coalesced batch sizes
        reuse compiled shapes instead of retracing.  ``backend=``
        overrides the session backend for this one batch — the serving
        path's circuit breaker uses it to route a wave to its fallback
        backend without touching session state."""
        if not isinstance(queries, (list, tuple)):
            queries = list(queries)
        # inlined _plan_for fast path: submission of a steady-state
        # serving batch costs one dict probe per query
        byid = self._plans_by_id
        plan_for = self._plan_for
        plans = []
        append = plans.append
        fast_hits = 0
        for q in queries:
            hit = byid.get(id(q))
            if hit is not None:
                fast_hits += 1
                append(hit[1])
            else:
                append(plan_for(q))
        if fast_hits:
            self._cache_counters["id_hits"].add(fast_hits)
        view = self._view()
        batch_run = LazyBatch(
            lambda: self._execute(plans, view, pad_output, backend))
        return ResultBatch(batch_run, self.num_records, queries)

    def serve_step(self):
        """The bucketed batch executor as a serving-loop step function:
        ``step(queries) -> (rows (Q, Nw) uint32, counts (Q,) int32)``,
        eager, in request order (see
        :func:`repro.serve.step.make_bitmap_query_step`)."""
        def query_step(queries: Sequence):
            return self.query_many(queries).materialize()
        return query_step

    def serve(self, **config):
        """Open a :class:`repro.serve.service.BitmapService` over this
        session: an async ``submit()/drain()/close()`` port whose
        micro-batch scheduler coalesces concurrently submitted queries
        into the bucketed executors, runs store maintenance (spill /
        compaction / gc) on a background thread, and duty-cycles into a
        standby state when idle — the paper's operating model as a
        serving API.  Keyword arguments go to
        :class:`repro.serve.service.ServiceConfig`."""
        from repro.serve.service import BitmapService
        return BitmapService.open(self, **config)

    def __repr__(self) -> str:
        mode = ("live" if self._si is not None and self.store is None
                else "durable" if self._si is not None else "read-only")
        sch = self.schema or f"{self.num_keys} raw keys"
        return (f"<BitmapDB {mode} {sch} records={self.num_records} "
                f"backend={self.backend}>")


def open_db(path: str, schema: Schema | None = None, *,
            num_keys: int | None = None, backend: str = "auto",
            spill_records: int | None = 4096,
            capacity_words: int = 16) -> BitmapDB:
    """Functional alias of :meth:`BitmapDB.open` — exported as
    ``repro.db.open`` / ``repro.open`` (the documented entry point); named
    ``open_db`` here so this module keeps the ``open`` builtin."""
    return BitmapDB.open(path, schema, num_keys=num_keys, backend=backend,
                         spill_records=spill_records,
                         capacity_words=capacity_words)
