from repro.data.pipeline import (  # noqa: F401
    BitmapIndexedDataset, SyntheticCorpus, DataConfig,
)
