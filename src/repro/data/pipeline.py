"""Bitmap-indexed data pipeline — the paper's technique as a first-class
feature of the training stack, served through the :mod:`repro.db` facade.

Documents carry attributes (domain, language, quality bucket, tags ...).
At ingest, each corpus shard streams into a per-shard
:class:`repro.db.BitmapDB`: every attribute value is one schema key, every
document one record.  Data selection for training ("code documents, high
quality, not flagged") is then a declarative query — either the typed DSL
(``col("domain").isin([0, 1]) & (col("quality") == 2)``) or a raw engine
predicate tree — executed as streaming bitwise passes, the exact economics
the paper builds silicon for, applied to the data plane of an LM training
run.

The corpus itself is synthetic (the assignment ships no data), but the
pipeline is real: sharded ingest, BIC indexing, query-driven sampling,
deterministic restart (the sampler state is part of the checkpoint), and
``store_dir=`` durability (per-shard ``BitmapDB`` stores reload
CRC-verified instead of re-indexing the corpus).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Sequence, Union

import numpy as np

import jax.numpy as jnp

from repro.core.bic import BICCore, BICConfig, BitmapIndex
from repro.db.expr import Expr
from repro.db.schema import Column, Schema
from repro.engine.planner import Pred

ATTR_WORDS = 8        # attribute words per document "record"

#: a selection query: a typed repro.db expression or a raw predicate tree
Query = Union[Expr, Pred]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    docs_per_shard: int = 2048
    num_shards: int = 4
    num_attributes: int = 64        # distinct attribute values (BIC keys)
    seed: int = 0


def attribute_schema(cfg: DataConfig) -> Schema | None:
    """The corpus attribute layout as a :class:`repro.db.Schema`: domains
    own keys 0-7, languages 8-15, quality buckets 16-23, and free-form
    tags the remaining rows — matching the raw key-id words
    :class:`SyntheticCorpus` emits, so encoded shards ingest directly.
    Returns None when ``num_attributes`` leaves no room for the tag rows
    (the dataset then runs a raw key-addressed session; the legacy
    integer-key queries keep working either way)."""
    if cfg.num_attributes <= 24:
        return None
    return Schema([
        Column.categorical("domain", range(8)),
        Column.categorical("lang", range(8)),
        Column.categorical("quality", range(8)),
        Column.categorical("tag", range(24, cfg.num_attributes)),
    ])


class SyntheticCorpus:
    """Deterministic synthetic corpus: documents of tokens + attribute words.

    Attribute words are drawn so that structured queries have non-trivial
    selectivity (mixtures of domains / quality buckets)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def shard(self, shard_id: int) -> tuple[np.ndarray, np.ndarray]:
        """Returns (tokens (D, seq_len+1) int32, attrs (D, ATTR_WORDS))."""
        c = self.cfg
        rng = np.random.default_rng(c.seed * 1000 + shard_id)
        tokens = rng.integers(0, c.vocab_size,
                              size=(c.docs_per_shard, c.seq_len + 1),
                              dtype=np.int32)
        # attributes: word 0 = domain (0..7), word 1 = lang (8..15),
        # word 2 = quality (16..23), rest random tags
        attrs = np.zeros((c.docs_per_shard, ATTR_WORDS), np.int32)
        attrs[:, 0] = rng.integers(0, 8, c.docs_per_shard)
        attrs[:, 1] = 8 + rng.integers(0, 8, c.docs_per_shard)
        attrs[:, 2] = 16 + rng.integers(0, 8, c.docs_per_shard)
        tag_lo = min(24, max(c.num_attributes - 1, 1))
        attrs[:, 3:] = rng.integers(tag_lo, c.num_attributes,
                                    size=(c.docs_per_shard, ATTR_WORDS - 3))
        return tokens, attrs


class BitmapIndexedDataset:
    """Corpus shards + per-shard :class:`repro.db.BitmapDB` sessions +
    query-driven batching.

    ``store_dir`` makes the per-shard indexes durable: each shard's index
    persists as a segment store under ``<store_dir>/shard-<id>``, so a
    restarted pipeline reopens (CRC-verified) through ``repro.db.open``
    instead of re-running the BIC build over the corpus."""

    def __init__(self, cfg: DataConfig, bic: BICCore | None = None, *,
                 store_dir: str | None = None):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        self.bic = bic or BICCore(BICConfig(
            num_keys=cfg.num_attributes,
            num_records=cfg.docs_per_shard,
            words_per_record=ATTR_WORDS))
        self.schema = attribute_schema(cfg)
        self.store_dir = store_dir
        self._shards: dict[int, tuple[np.ndarray, "object"]] = {}
        self._services: dict[int, "object"] = {}
        self._fabric: "object | None" = None

    def _shard_path(self, shard_id: int) -> str:
        return os.path.join(self.store_dir, f"shard-{shard_id:04d}")

    def _open_or_ingest(self, attrs: np.ndarray, shard_id: int):
        """One durable (or in-memory) BitmapDB per shard."""
        from repro import db as _db
        kw = dict(backend=self.bic.config.backend)
        if self.schema is None:
            kw["num_keys"] = self.cfg.num_attributes
        if self.store_dir is None:
            db = _db.BitmapDB(self.schema, **kw)
            db.append_encoded(attrs)
            return db
        from repro.store import SegmentStore
        path = self._shard_path(shard_id)
        st = SegmentStore(path)
        try:
            populated = bool(st.durable_records or st.replay_wal())
            if populated and st.num_keys is not None \
                    and st.num_keys != self.cfg.num_attributes:
                raise ValueError(
                    f"store shard-{shard_id:04d} holds {st.num_keys}-key "
                    f"segments but the config says "
                    f"{self.cfg.num_attributes} attributes — stale "
                    "store_dir?")
        finally:
            st.close()
        if populated:
            db = _db.BitmapDB.open(path, self.schema, **kw)
            if db.num_records != self.cfg.docs_per_shard:
                raise ValueError(
                    f"store shard-{shard_id:04d} holds {db.num_records} "
                    f"records but the config says "
                    f"{self.cfg.docs_per_shard} — stale store_dir?")
            return db
        db = _db.BitmapDB(self.schema, path=path, spill_records=None, **kw)
        db.append_encoded(attrs)
        db.snapshot()                     # one committed segment per shard
        return db

    def _ensure_db(self, shard_id: int):
        if shard_id not in self._shards:
            tokens, attrs = self.corpus.shard(shard_id)
            self._shards[shard_id] = (tokens,
                                      self._open_or_ingest(attrs, shard_id))
        return self._shards[shard_id]

    def _ensure_shard(self, shard_id: int) -> tuple[np.ndarray, BitmapIndex]:
        """(tokens, live BitmapIndex) — the legacy accessor shape."""
        tokens, db = self._ensure_db(shard_id)
        return tokens, db.index

    def db(self, shard_id: int):
        """The shard's :class:`repro.db.BitmapDB` session (for direct DSL
        queries, stats, or serving)."""
        return self._ensure_db(shard_id)[1]

    def select(self, shard_id: int, include: Sequence[int] = (),
               exclude: Sequence[int] = (), *,
               where: Query | None = None) -> np.ndarray:
        """Document ids in ``shard_id`` matching the attribute query.

        ``where`` accepts a typed expression over :func:`attribute_schema`
        (``col("domain").isin([0, 1]) & (col("quality") == 2) &
        ~(col("tag") == 30)``) or a raw predicate tree over integer key
        rows; ``include``/``exclude`` express the legacy AND-of-literals
        (kept working through the :mod:`repro.db` deprecation shim)."""
        from repro import db as _db
        if where is None:
            where = _db.include_exclude_pred(include, exclude)
        elif include or exclude:
            raise ValueError("pass either include/exclude or where=, "
                             "not both")
        return self.select_many(shard_id, [where])[0]

    def select_many(self, shard_id: int,
                    wheres: Sequence[Query]) -> list[np.ndarray]:
        """Serve a burst of selections against one shard in a handful of
        bucketed dispatches (one lazily shared ``query_many`` batch, one
        bulk device-to-host transfer) instead of one planner dispatch —
        and one device sync — per query.  Returns the matching
        document-id array per query, in input order."""
        db = self.db(shard_id)
        return db.query_many(list(wheres)).all_ids()

    # -------------------------------------------------------- async prefetch
    def service(self, shard_id: int, **config):
        """The shard's :class:`repro.serve.service.BitmapService` (opened
        lazily; ``config`` keywords apply on first open).  Selections
        submitted through it execute on the service's scheduler thread,
        coalesced with any other caller's — the prefetch path.  Shard
        stores spill synchronously at ingest (``snapshot()``), so
        background maintenance stays off by default here."""
        if shard_id not in self._services:
            config.setdefault("max_delay_ms", 1.0)
            config.setdefault("maintenance", False)
            self._services[shard_id] = self.db(shard_id).serve(**config)
        return self._services[shard_id]

    def select_many_async(self, shard_id: int, wheres: Sequence[Query]
                          ) -> list:
        """Non-blocking :meth:`select_many`: submit the burst to the
        shard's service and return its
        :class:`repro.serve.service.QueryFuture` list immediately —
        ``.ids`` on each future blocks only for ITS micro-batch, so
        submission overlaps with consumption (and with ingest of the
        next shard in :meth:`batches`).  Ids are bit-identical to the
        synchronous path."""
        return self.service(shard_id).submit_many(list(wheres))

    # ------------------------------------------------------- fabric plane
    def fabric(self, **kw):
        """ONE query plane over every corpus shard: a loopback
        :class:`repro.fabric.client.FabricClient` whose shard map blocks
        the global document-ordinal space by shard (document gid =
        ``shard_id * docs_per_shard + local_id``).  A selection
        submitted here scatters to every per-shard session, executes
        coalesced on each shard's service scheduler, and merges back
        OR-spliced — the same ``submit()``/future surface as one
        :class:`~repro.serve.service.BitmapService`, so callers route
        unchanged (and the same client drives REAL worker processes via
        ``FabricClient.connect``; see benchmarks/fabric.py).  Don't
        ingest through it: the corpus shards are append-complete by
        construction."""
        if self._fabric is None:
            from repro.fabric import FabricClient, ShardMap
            c = self.cfg
            dbs = [self._ensure_db(s)[1] for s in range(c.num_shards)]
            sm = ShardMap.blocked(c.num_shards,
                                  block_size=c.docs_per_shard)
            gids = [np.arange(s * c.docs_per_shard,
                              (s + 1) * c.docs_per_shard, dtype=np.int64)
                    for s in range(c.num_shards)]
            kw.setdefault("max_delay_ms", 1.0)
            self._fabric = FabricClient.local(dbs, sm, gids=gids, **kw)
        return self._fabric

    def select_global(self, wheres: Sequence[Query]) -> list[np.ndarray]:
        """GLOBAL document ids (gid = shard * docs_per_shard + local)
        matching each query, across the whole corpus in one
        scatter/merge per micro-batch wave — equal to concatenating
        :meth:`select` over shards with the shard offsets added."""
        fc = self.fabric()
        futs = fc.submit_many(list(wheres))
        fc.drain()
        return [np.asarray(f.ids) for f in futs]

    def close(self) -> None:
        """Close every shard service (drains in-flight selections)."""
        for svc in self._services.values():
            svc.close()
        self._services.clear()
        if self._fabric is not None:
            self._fabric.close()
            self._fabric = None

    def batches(self, batch_size: int, include: Sequence[int] = (),
                exclude: Sequence[int] = (), *, where: Query | None = None,
                seed: int = 0, start_step: int = 0,
                prefetch: bool = False) -> Iterator[dict]:
        """Infinite deterministic batch stream over the selected subset.

        ``start_step`` resumes mid-stream after a restart (the training
        loop checkpoints its step counter — see train/loop.py).

        ``prefetch=True`` pipelines shard selection: each shard's query
        is submitted to its service the moment the shard is ingested and
        executes on the scheduler thread while the NEXT shard ingests;
        futures are consumed afterwards.  Ids — and therefore the batch
        stream — are bit-identical to the synchronous path.  Opt-in: it
        opens one service (scheduler thread) per shard, which lives
        until :meth:`close`."""
        from repro import db as _db
        if where is None:
            query: Query = _db.include_exclude_pred(include, exclude)
        elif include or exclude:
            raise ValueError("pass either include/exclude or where=, "
                             "not both")
        else:
            query = where
        rng = np.random.default_rng(seed)
        pools = []
        if prefetch:
            futs = []
            for s in range(self.cfg.num_shards):
                self._ensure_db(s)
                futs.append(self.select_many_async(s, [query])[0])
            for s, fut in enumerate(futs):
                ids = fut.ids
                tokens, _ = self._shards[s]
                if len(ids):
                    pools.append(tokens[ids])
        else:
            for s in range(self.cfg.num_shards):
                ids = self.select(s, where=query)
                tokens, _ = self._ensure_db(s)
                if len(ids):
                    pools.append(tokens[ids])
        if not pools:
            raise ValueError("query selected zero documents")
        pool = np.concatenate(pools, axis=0)
        order = rng.permutation(len(pool))
        step = 0
        while True:
            take = [(order[(step * batch_size + i) % len(pool)])
                    for i in range(batch_size)]
            if step >= start_step:
                seqs = pool[take]
                yield {"tokens": jnp.asarray(seqs[:, :-1]),
                       "labels": jnp.asarray(seqs[:, 1:])}
            step += 1
