"""Bitmap-indexed data pipeline — the paper's technique as a first-class
feature of the training stack.

Documents carry attributes (domain, language, quality bucket, dedup key,
...).  At ingest, the BIC core indexes each corpus shard: every attribute
value becomes one key, every document one record, and the result is a
key-major packed bitmap.  Data selection for training ("code documents, high
quality, not flagged") is then a streaming bitwise query — the exact
economics the paper builds silicon for, applied to the data plane of an LM
training run.

The corpus itself is synthetic (the assignment ships no data), but the
pipeline is real: sharded ingest, BIC indexing, query-driven sampling,
deterministic restart (the sampler state is part of the checkpoint).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bic import BICCore, BICConfig, BitmapIndex
from repro.engine.planner import Pred

ATTR_WORDS = 8        # attribute words per document "record"


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    docs_per_shard: int = 2048
    num_shards: int = 4
    num_attributes: int = 64        # distinct attribute values (BIC keys)
    seed: int = 0


class SyntheticCorpus:
    """Deterministic synthetic corpus: documents of tokens + attribute words.

    Attribute words are drawn so that structured queries have non-trivial
    selectivity (mixtures of domains / quality buckets)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def shard(self, shard_id: int) -> tuple[np.ndarray, np.ndarray]:
        """Returns (tokens (D, seq_len+1) int32, attrs (D, ATTR_WORDS))."""
        c = self.cfg
        rng = np.random.default_rng(c.seed * 1000 + shard_id)
        tokens = rng.integers(0, c.vocab_size,
                              size=(c.docs_per_shard, c.seq_len + 1),
                              dtype=np.int32)
        # attributes: word 0 = domain (0..7), word 1 = lang (8..15),
        # word 2 = quality (16..23), rest random tags
        attrs = np.zeros((c.docs_per_shard, ATTR_WORDS), np.int32)
        attrs[:, 0] = rng.integers(0, 8, c.docs_per_shard)
        attrs[:, 1] = 8 + rng.integers(0, 8, c.docs_per_shard)
        attrs[:, 2] = 16 + rng.integers(0, 8, c.docs_per_shard)
        tag_lo = min(24, max(c.num_attributes - 1, 1))
        attrs[:, 3:] = rng.integers(tag_lo, c.num_attributes,
                                    size=(c.docs_per_shard, ATTR_WORDS - 3))
        return tokens, attrs


class BitmapIndexedDataset:
    """Corpus shards + per-shard bitmap indexes + query-driven batching."""

    def __init__(self, cfg: DataConfig, bic: BICCore | None = None):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        self.bic = bic or BICCore(BICConfig(
            num_keys=cfg.num_attributes,
            num_records=cfg.docs_per_shard,
            words_per_record=ATTR_WORDS))
        self._shards: dict[int, tuple[np.ndarray, BitmapIndex]] = {}

    def _ensure_shard(self, shard_id: int):
        if shard_id not in self._shards:
            tokens, attrs = self.corpus.shard(shard_id)
            keys = jnp.arange(self.cfg.num_attributes, dtype=jnp.int32)
            index = self.bic.create(jnp.asarray(attrs), keys)
            self._shards[shard_id] = (tokens, index)
        return self._shards[shard_id]

    def select(self, shard_id: int, include: Sequence[int] = (),
               exclude: Sequence[int] = (), *,
               where: Pred | None = None) -> np.ndarray:
        """Document ids in ``shard_id`` matching the attribute query.

        ``include``/``exclude`` express AND-of-literals; ``where`` accepts an
        arbitrary predicate tree, e.g.
        ``where=(key(0) | key(1)) & key(18) & ~key(30)`` for
        "(domain 0 or domain 1) and quality bucket 2 and not tag 30" — the
        engine planner fuses it into minimal bitmap passes."""
        tokens, index = self._ensure_shard(shard_id)
        row, _ = self.bic.query(index, include=include, exclude=exclude,
                                where=where)
        bits = np.asarray(jax.device_get(row))
        ids = np.flatnonzero(
            np.unpackbits(bits.view(np.uint8), bitorder="little"))
        return ids[ids < tokens.shape[0]]

    def batches(self, batch_size: int, include: Sequence[int] = (),
                exclude: Sequence[int] = (), *, where: Pred | None = None,
                seed: int = 0, start_step: int = 0) -> Iterator[dict]:
        """Infinite deterministic batch stream over the selected subset.

        ``start_step`` resumes mid-stream after a restart (the training
        loop checkpoints its step counter — see train/loop.py)."""
        rng = np.random.default_rng(seed)
        pools = []
        for s in range(self.cfg.num_shards):
            ids = self.select(s, include, exclude, where=where)
            tokens, _ = self._ensure_shard(s)
            if len(ids):
                pools.append(tokens[ids])
        if not pools:
            raise ValueError("query selected zero documents")
        pool = np.concatenate(pools, axis=0)
        order = rng.permutation(len(pool))
        step = 0
        while True:
            take = [(order[(step * batch_size + i) % len(pool)])
                    for i in range(batch_size)]
            if step >= start_step:
                seqs = pool[take]
                yield {"tokens": jnp.asarray(seqs[:, :-1]),
                       "labels": jnp.asarray(seqs[:, 1:])}
            step += 1
