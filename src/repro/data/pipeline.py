"""Bitmap-indexed data pipeline — the paper's technique as a first-class
feature of the training stack.

Documents carry attributes (domain, language, quality bucket, dedup key,
...).  At ingest, the BIC core indexes each corpus shard: every attribute
value becomes one key, every document one record, and the result is a
key-major packed bitmap.  Data selection for training ("code documents, high
quality, not flagged") is then a streaming bitwise query — the exact
economics the paper builds silicon for, applied to the data plane of an LM
training run.

The corpus itself is synthetic (the assignment ships no data), but the
pipeline is real: sharded ingest, BIC indexing, query-driven sampling,
deterministic restart (the sampler state is part of the checkpoint).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bic import BICCore, BICConfig, BitmapIndex
from repro.engine.planner import Pred, from_include_exclude

ATTR_WORDS = 8        # attribute words per document "record"


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    docs_per_shard: int = 2048
    num_shards: int = 4
    num_attributes: int = 64        # distinct attribute values (BIC keys)
    seed: int = 0


class SyntheticCorpus:
    """Deterministic synthetic corpus: documents of tokens + attribute words.

    Attribute words are drawn so that structured queries have non-trivial
    selectivity (mixtures of domains / quality buckets)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def shard(self, shard_id: int) -> tuple[np.ndarray, np.ndarray]:
        """Returns (tokens (D, seq_len+1) int32, attrs (D, ATTR_WORDS))."""
        c = self.cfg
        rng = np.random.default_rng(c.seed * 1000 + shard_id)
        tokens = rng.integers(0, c.vocab_size,
                              size=(c.docs_per_shard, c.seq_len + 1),
                              dtype=np.int32)
        # attributes: word 0 = domain (0..7), word 1 = lang (8..15),
        # word 2 = quality (16..23), rest random tags
        attrs = np.zeros((c.docs_per_shard, ATTR_WORDS), np.int32)
        attrs[:, 0] = rng.integers(0, 8, c.docs_per_shard)
        attrs[:, 1] = 8 + rng.integers(0, 8, c.docs_per_shard)
        attrs[:, 2] = 16 + rng.integers(0, 8, c.docs_per_shard)
        tag_lo = min(24, max(c.num_attributes - 1, 1))
        attrs[:, 3:] = rng.integers(tag_lo, c.num_attributes,
                                    size=(c.docs_per_shard, ATTR_WORDS - 3))
        return tokens, attrs


class BitmapIndexedDataset:
    """Corpus shards + per-shard bitmap indexes + query-driven batching.

    ``store_dir`` makes the per-shard indexes durable: each shard's packed
    index persists as a :class:`repro.store.SegmentStore` segment under
    ``<store_dir>/shard-<id>``, so a restarted pipeline reloads
    (CRC-verified) instead of re-running the BIC build over the corpus."""

    def __init__(self, cfg: DataConfig, bic: BICCore | None = None, *,
                 store_dir: str | None = None):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        self.bic = bic or BICCore(BICConfig(
            num_keys=cfg.num_attributes,
            num_records=cfg.docs_per_shard,
            words_per_record=ATTR_WORDS))
        self.store_dir = store_dir
        self._shards: dict[int, tuple[np.ndarray, BitmapIndex]] = {}

    def _load_or_index(self, attrs: np.ndarray,
                       keys: jax.Array, shard_id: int) -> BitmapIndex:
        if self.store_dir is None:
            return self.bic.create(jnp.asarray(attrs), keys)
        from repro.store import SegmentStore
        st = SegmentStore(os.path.join(self.store_dir,
                                       f"shard-{shard_id:04d}"))
        try:
            if st.durable_records == self.cfg.docs_per_shard:
                if st.num_keys != self.cfg.num_attributes:
                    raise ValueError(
                        f"store shard-{shard_id:04d} holds "
                        f"{st.num_keys}-key segments but the config says "
                        f"{self.cfg.num_attributes} attributes — stale "
                        "store_dir?")
                packed, n = st.load_packed()
                return BitmapIndex(jnp.asarray(packed), n)
            if st.durable_records:
                raise ValueError(
                    f"store shard-{shard_id:04d} holds "
                    f"{st.durable_records} records but the config says "
                    f"{self.cfg.docs_per_shard} — stale store_dir?")
            index = self.bic.create(jnp.asarray(attrs), keys)
            st.ensure_keys(np.asarray(jax.device_get(keys)))
            st.write_segment(np.asarray(jax.device_get(index.packed)),
                             index.num_records, 0)
            return index
        finally:
            st.close()

    def _ensure_shard(self, shard_id: int):
        if shard_id not in self._shards:
            tokens, attrs = self.corpus.shard(shard_id)
            keys = jnp.arange(self.cfg.num_attributes, dtype=jnp.int32)
            index = self._load_or_index(attrs, keys, shard_id)
            self._shards[shard_id] = (tokens, index)
        return self._shards[shard_id]

    def select(self, shard_id: int, include: Sequence[int] = (),
               exclude: Sequence[int] = (), *,
               where: Pred | None = None) -> np.ndarray:
        """Document ids in ``shard_id`` matching the attribute query.

        ``include``/``exclude`` express AND-of-literals; ``where`` accepts an
        arbitrary predicate tree, e.g.
        ``where=(key(0) | key(1)) & key(18) & ~key(30)`` for
        "(domain 0 or domain 1) and quality bucket 2 and not tag 30" — the
        engine planner fuses it into minimal bitmap passes."""
        if where is None:
            where = from_include_exclude(include, exclude)
        elif include or exclude:
            raise ValueError("pass either include/exclude or where=, "
                             "not both")
        return self.select_many(shard_id, [where])[0]

    def select_many(self, shard_id: int,
                    wheres: Sequence[Pred]) -> list[np.ndarray]:
        """Serve a burst of predicate selections against one shard in a
        handful of bucketed dispatches (``engine.batch`` plan-shape
        bucketing) instead of one planner dispatch per predicate — the
        data-plane twin of ``BICCore.query_many``.  Returns the matching
        document-id array per predicate, in input order."""
        tokens, index = self._ensure_shard(shard_id)
        rows, _ = self.bic.query_many(index, list(wheres))
        bits = np.asarray(jax.device_get(rows))
        out = []
        for qi in range(bits.shape[0]):
            ids = np.flatnonzero(
                np.unpackbits(bits[qi].view(np.uint8), bitorder="little"))
            out.append(ids[ids < tokens.shape[0]])
        return out

    def batches(self, batch_size: int, include: Sequence[int] = (),
                exclude: Sequence[int] = (), *, where: Pred | None = None,
                seed: int = 0, start_step: int = 0) -> Iterator[dict]:
        """Infinite deterministic batch stream over the selected subset.

        ``start_step`` resumes mid-stream after a restart (the training
        loop checkpoints its step counter — see train/loop.py)."""
        rng = np.random.default_rng(seed)
        pools = []
        for s in range(self.cfg.num_shards):
            ids = self.select(s, include, exclude, where=where)
            tokens, _ = self._ensure_shard(s)
            if len(ids):
                pools.append(tokens[ids])
        if not pools:
            raise ValueError("query selected zero documents")
        pool = np.concatenate(pools, axis=0)
        order = rng.permutation(len(pool))
        step = 0
        while True:
            take = [(order[(step * batch_size + i) % len(pool)])
                    for i in range(batch_size)]
            if step >= start_step:
                seqs = pool[take]
                yield {"tokens": jnp.asarray(seqs[:, :-1]),
                       "labels": jnp.asarray(seqs[:, 1:])}
            step += 1
