"""Deterministic fault-injection fabric.

The store and serving layers carry injection *seams* (named sites fired
through :mod:`repro.fault.seam` — one global ``None`` check when the
fabric is off); this package supplies the scheduled faults that flow
through them:

  * :class:`FaultPlan` — a seed-reproducible, JSON-serializable fault
    schedule (torn writes, ENOSPC, EIO, read-side bit flips, failed
    fsyncs, I/O stalls, transient dispatch/maintenance errors).
  * :class:`FaultInjector` — installs a plan behind the seam, executes
    it deterministically, and logs every fault that actually fired (the
    chaos harness's failure artifact).

Stdlib-only, below everything: :mod:`repro.store.format` fires the seam
without importing anything heavier than it already does.
"""
from repro.fault.inject import (FaultInjector, FaultPlan,  # noqa: F401
                                FaultSpec, InjectedFault, InjectedOSError,
                                SITE_KINDS)
from repro.fault import seam  # noqa: F401

__all__ = ["FaultInjector", "FaultPlan", "FaultSpec", "InjectedFault",
           "InjectedOSError", "SITE_KINDS", "seam"]
