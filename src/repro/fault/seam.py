"""The injection seam every hooked module fires through.

This module is deliberately trivial and dependency-free (stdlib only) so
it can be imported from the very bottom of the stack
(:mod:`repro.store.format` imports nothing above stdlib + numpy) without
creating a cycle or a heavyweight import.  With no injector installed the
fast path is one global read and a ``None`` check — the production cost
of the whole fault fabric.

``fire(site, **ctx)`` returns whatever the installed hook returns (sites
that can transform data, like ``format.read``, use the return value;
most sites ignore it).  Hooks communicate faults by RAISING — an injected
``OSError(ENOSPC)`` travels the exact error path a real full disk would.

Known sites (the contract the fabric and the hooked modules share)::

    format.write     path, size          atomic array/manifest file writes
    format.read      path, data          array-file reads (may return
                                         mutated bytes -> CRC failure)
    log.append       path, size          one framed-log entry write
    wal.append       path, start, size   WAL block append (pre-write)
    engine.dispatch  backend, queries    one batched wave dispatch
    maintenance.task kind                one background maintenance task
    rpc.send         path, kind, size    one fabric envelope leaving a
                                         transport (may return
                                         drop/duplicate/hold directives)
    rpc.recv         path, kind, size    one fabric envelope arriving
"""
from __future__ import annotations

from typing import Any, Callable

#: the installed injector's fire callback (None = fabric disabled)
HOOK: Callable[[str, dict], Any] | None = None


def fire(site: str, **ctx) -> Any:
    """Fire one site occurrence through the installed hook (no-op without
    one).  The hook may raise (the injected fault) or return a value the
    site knows how to use (e.g. mutated read bytes)."""
    hook = HOOK
    if hook is None:
        return None
    return hook(site, ctx)


def install(hook: Callable[[str, dict], Any]) -> None:
    global HOOK
    if HOOK is not None and HOOK is not hook:
        raise RuntimeError("a fault injector is already installed")
    HOOK = hook


def uninstall(hook: Callable[[str, dict], Any] | None = None) -> None:
    """Remove the installed hook (idempotent; passing the hook asserts
    ownership so one injector cannot tear down another's)."""
    global HOOK
    if hook is not None and HOOK is not None and HOOK is not hook:
        raise RuntimeError("refusing to uninstall another injector's hook")
    HOOK = None
