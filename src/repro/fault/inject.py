"""Deterministic fault injection: seeded schedules over named sites.

The store/serve stack claims to survive torn writes, full disks, flaky
reads, and backend hiccups; this module is how those claims get tested
instead of asserted.  A :class:`FaultPlan` is a reproducible *schedule*
— "on the 3rd WAL append, fail the fsync; on the 2nd segment read, flip
bit 12345" — and a :class:`FaultInjector` installs it behind the
zero-cost seam (:mod:`repro.fault.seam`) that the hooked modules fire
through.  Determinism contract: given the same plan, the same site
occurrence always draws the same fault, regardless of wall-clock or
thread interleaving (which occurrence a given thread's call lands on can
still vary with scheduling — the schedule is deterministic, the
workload's interleaving is the workload's business).

Fault kinds and where they may fire:

=================  =================================  ======================
kind               effect                              sites
=================  =================================  ======================
``enospc``         ``OSError(ENOSPC)`` before write   format.write,
                                                      log.append, wal.append
``eio``            ``OSError(EIO)``                   format.write,
                                                      format.read
``torn``           prefix of the bytes reaches disk,  format.write,
                   then ``OSError(EIO)`` — the        log.append
                   crash-mid-write debris state
``fsync_error``    payload written, fsync raises      log.append
                   ``OSError(EIO)`` (a "dropped"
                   fsync surfaced as failure — the
                   writer must treat the entry as
                   not durable)
``bitflip``        one seeded bit of the read bytes   format.read
                   flips (CRC catches it downstream)
``stall``          ``stall_s`` sleep (slow I/O /       every site
                   slow dispatch)
``dispatch_error`` ``InjectedFault`` from a batched   engine.dispatch
                   wave (transient backend failure)
``task_error``     ``InjectedFault`` from a           maintenance.task
                   maintenance task body
``drop``           the message vanishes (the seam     rpc.send, rpc.recv
                   returns ``{"drop": True}`` and
                   the transport discards the frame)
``duplicate``      the message is delivered twice     rpc.send, rpc.recv
``reorder``        the message is held back and      rpc.send, rpc.recv
                   delivered after the next one
                   (``{"hold": True}``)
=================  =================================  ======================

Only stdlib: this module sits below everything (the seam is fired from
``repro.store.format``).
"""
from __future__ import annotations

import dataclasses
import errno
import json
import random
import threading
import time

from repro.obs import trace as _obs_trace

__all__ = ["FaultSpec", "FaultPlan", "FaultInjector", "InjectedFault",
           "InjectedOSError", "SITE_KINDS"]


class InjectedFault(RuntimeError):
    """A transient, injected non-I/O failure (dispatch/task errors)."""


class InjectedOSError(OSError):
    """An injected I/O failure — a real ``OSError`` (errno and all) so it
    travels the exact handling path the genuine article would, but
    type-distinguishable in assertions."""


#: which fault kinds are meaningful at which seam site
SITE_KINDS: dict[str, tuple[str, ...]] = {
    "format.write": ("enospc", "eio", "torn", "stall"),
    "format.read": ("eio", "bitflip", "stall"),
    "log.append": ("enospc", "torn", "fsync_error", "stall"),
    "wal.append": ("enospc", "stall"),
    "engine.dispatch": ("dispatch_error", "stall"),
    "maintenance.task": ("task_error", "stall"),
    "rpc.send": ("drop", "duplicate", "reorder", "stall"),
    "rpc.recv": ("drop", "duplicate", "reorder", "stall"),
}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire ``kind`` on occurrences
    ``[occurrence, occurrence + count)`` of ``site`` calls whose context
    matches ``path_substr`` / ``match`` (each spec keeps its own
    occurrence counter over its *matching* calls, so "the 2nd write of a
    seg- file" means exactly that)."""
    site: str
    kind: str
    occurrence: int = 1          # 1-based, over matching calls
    count: int = 1               # consecutive matching occurrences
    path_substr: str | None = None
    match: tuple[tuple[str, str], ...] = ()   # ctx key -> str(value) equals
    stall_s: float = 0.0
    torn_frac: float = 0.5       # fraction of the payload that lands
    bit: int = 0                 # bitflip position seed (mod payload bits)

    def __post_init__(self):
        if self.site not in SITE_KINDS:
            raise ValueError(f"unknown site {self.site!r} "
                             f"(known: {sorted(SITE_KINDS)})")
        if self.kind not in SITE_KINDS[self.site]:
            raise ValueError(f"kind {self.kind!r} cannot fire at "
                             f"{self.site!r} (allowed: "
                             f"{SITE_KINDS[self.site]})")
        if self.occurrence < 1 or self.count < 1:
            raise ValueError("occurrence and count are 1-based positives")

    def matches(self, ctx: dict) -> bool:
        if self.path_substr is not None \
                and self.path_substr not in str(ctx.get("path", "")):
            return False
        return all(str(ctx.get(k)) == v for k, v in self.match)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["match"] = [list(kv) for kv in self.match]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        d = dict(d)
        d["match"] = tuple((k, v) for k, v in d.get("match", ()))
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, JSON-serializable fault schedule (the chaos-harness
    artifact: a failing run uploads exactly this)."""
    specs: tuple[FaultSpec, ...] = ()
    seed: int | None = None      # provenance when drawn by :meth:`random`

    #: site pools per profile for :meth:`random`
    PROFILES = {
        "storage": ("format.write", "format.read", "log.append",
                    "wal.append"),
        "serve": ("engine.dispatch", "maintenance.task", "format.read"),
        "all": ("format.write", "format.read", "log.append", "wal.append",
                "engine.dispatch", "maintenance.task"),
        # NOTE: rpc sites live in their own profile — folding them into
        # "all" would shift every existing seeded schedule.
        "network": ("rpc.send", "rpc.recv"),
    }

    @classmethod
    def random(cls, seed: int, *, profile: str = "all", n_faults: int = 12,
               max_occurrence: int = 24, max_stall_s: float = 0.005
               ) -> "FaultPlan":
        """Draw a reproducible schedule: ``n_faults`` specs over the
        profile's sites, occurrences in ``[1, max_occurrence]``, stalls
        bounded by ``max_stall_s``.  Same seed -> same schedule, always
        (``random.Random``, not the global RNG)."""
        if profile not in cls.PROFILES:
            raise ValueError(f"unknown profile {profile!r}")
        rng = random.Random(seed)
        specs = []
        for _ in range(n_faults):
            site = rng.choice(cls.PROFILES[profile])
            kind = rng.choice(SITE_KINDS[site])
            specs.append(FaultSpec(
                site=site, kind=kind,
                occurrence=rng.randint(1, max_occurrence),
                count=rng.randint(1, 2),
                stall_s=(rng.uniform(0.0005, max_stall_s)
                         if kind == "stall" else 0.0),
                torn_frac=rng.uniform(0.05, 0.95),
                bit=rng.randrange(1 << 30)))
        return cls(tuple(specs), seed=seed)

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "specs": [s.to_dict() for s in self.specs]},
                          indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        return cls(tuple(FaultSpec.from_dict(s) for s in d["specs"]),
                   seed=d.get("seed"))


class FaultInjector:
    """Installs a :class:`FaultPlan` behind the seam and executes it.

    Context manager::

        with FaultInjector(plan) as inj:
            ... workload ...
        inj.events            # every fault that actually fired

    Thread-safe: sites fire from append threads, the maintenance worker,
    and the service scheduler concurrently; per-spec occurrence counters
    and the event log are lock-protected.  The decision (which fault, if
    any) happens under the lock; the *effect* (sleep, raise, mutate)
    happens outside it, so a stall never serializes unrelated sites.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._seen = [0] * len(plan.specs)      # matching calls per spec
        self.events: list[dict] = []            # faults that fired
        self._installed = False
        # one stable bound-method object: seam ownership checks use
        # identity, and ``self._fire`` makes a fresh wrapper per access
        self._hook = self._fire

    # ------------------------------------------------------------ lifecycle
    def install(self) -> "FaultInjector":
        from repro.fault import seam
        seam.install(self._hook)
        self._installed = True
        return self

    def uninstall(self) -> None:
        from repro.fault import seam
        if self._installed:
            seam.uninstall(self._hook)
            self._installed = False

    def __enter__(self) -> "FaultInjector":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()

    # ------------------------------------------------------------ reporting
    def fired(self, site: str | None = None) -> list[dict]:
        with self._lock:
            return [e for e in self.events
                    if site is None or e["site"] == site]

    def report_json(self) -> str:
        """Schedule + what actually fired — the debugging artifact a
        failing chaos run uploads."""
        with self._lock:
            events = list(self.events)
        return json.dumps({"seed": self.plan.seed,
                           "specs": [s.to_dict() for s in self.plan.specs],
                           "fired": events}, indent=2, sort_keys=True)

    # ------------------------------------------------------------ execution
    def _fire(self, site: str, ctx: dict):
        hit: FaultSpec | None = None
        ev: dict | None = None
        with self._lock:
            for i, spec in enumerate(self.plan.specs):
                if spec.site != site or not spec.matches(ctx):
                    continue
                self._seen[i] += 1
                if hit is None and (spec.occurrence <= self._seen[i]
                                    < spec.occurrence + spec.count):
                    hit = spec
                    ev = {"site": site, "kind": spec.kind,
                          "occurrence": self._seen[i],
                          "path": str(ctx.get("path", "")),
                          "t": time.monotonic()}
                    self.events.append(ev)
        if hit is None:
            return None
        # land the fired fault in the trace (outside the lock): a chaos
        # run's span tree then shows exactly which operation each fault
        # interrupted, and the event record carries the join keys back
        tracer = _obs_trace.TRACER
        if tracer is not None:
            sp = tracer.event(f"fault.{hit.kind}", site=site,
                              occurrence=ev["occurrence"], path=ev["path"])
            ev["trace"] = sp.trace_id
            ev["span"] = sp.parent_id       # the span the fault landed in
        return self._execute(hit, ctx)

    def _execute(self, spec: FaultSpec, ctx: dict):
        kind = spec.kind
        if kind == "stall":
            time.sleep(spec.stall_s)
            return None
        if kind == "enospc":
            raise InjectedOSError(errno.ENOSPC,
                                  f"injected ENOSPC at {spec.site}")
        if kind == "eio":
            raise InjectedOSError(errno.EIO,
                                  f"injected EIO at {spec.site}")
        if kind == "torn":
            size = int(ctx.get("size", 0))
            return {"torn_bytes": max(0, min(size - 1,
                                             int(size * spec.torn_frac)))}
        if kind == "fsync_error":
            return {"fail_fsync": True}
        if kind == "bitflip":
            data = ctx.get("data", b"")
            if not data:
                return None
            pos = spec.bit % (len(data) * 8)
            out = bytearray(data)
            out[pos // 8] ^= 1 << (pos % 8)
            return {"data": bytes(out)}
        if kind in ("dispatch_error", "task_error"):
            raise InjectedFault(f"injected {kind} at {spec.site} "
                                f"({dict(ctx, data=None)})")
        if kind == "drop":
            return {"drop": True}
        if kind == "duplicate":
            return {"duplicate": True}
        if kind == "reorder":
            return {"hold": True}
        raise AssertionError(f"unhandled fault kind {kind!r}")
