"""AdamW with warmup+cosine schedule, global-norm clipping and optional
distributed-optimization tricks:

  * ``moment_dtype=bfloat16`` — halves optimizer-state HBM (8-bit-Adam-lite);
    states are sharded like their params so this stacks with ZeRO-3.
  * ``grad_compression="int8"`` — per-tensor symmetric int8 quantization of
    gradients before the update.  Under pjit the cross-replica reduction is
    implicit, so on real hardware this is paired with a reduce-scatter of
    the quantized payload; here it faithfully models the *numerics* of
    compressed gradients (documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"        # float32 | bfloat16
    grad_compression: str = "none"       # none | int8


def learning_rate(cfg: OptimConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(math.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any, cfg: OptimConfig) -> dict:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(params: Any, cfg: OptimConfig) -> dict:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {"m": jax.tree.map(sds, params),
            "v": jax.tree.map(sds, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _compress_int8(g: Array) -> Array:
    """Symmetric per-tensor int8 quantize/dequantize (stochastic-free)."""
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(g.dtype) * scale


def apply_updates(params: Any, grads: Any, state: dict, cfg: OptimConfig
                  ) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (params, state, metrics)."""
    if cfg.grad_compression == "int8":
        grads = jax.tree.map(_compress_int8, grads)

    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    step = state["step"] + 1
    lr = learning_rate(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
