from repro.optim.adamw import (  # noqa: F401
    OptimConfig, init_opt_state, apply_updates, learning_rate,
)
