"""Mamba2 (SSD — state-space duality) blocks.

Three execution forms of the same recurrence
    h_t = exp(dt_t A) h_{t-1} + dt_t * (B_t ⊗ x_t),   y_t = C_t · h_t + D x_t
  * ``ssd_chunked``    — training/prefill: intra-chunk quadratic (MXU
                         friendly) + inter-chunk scan over chunk states.
  * ``ssd_recurrent``  — decode: O(1) per-token state update.
  * ``ssd_sequential`` — pure scan oracle used by the test suite.

Shapes: x (B,S,nh,hp), dt (B,S,nh), A (nh,), B/C (B,S,ng,ds), D (nh,).
Heads are grouped: head h uses B/C group h // (nh // ng).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

Array = jax.Array


def _expand_groups(bc: Array, nh: int) -> Array:
    """(B, S, ng, ds) -> (B, S, nh, ds) by repeating groups."""
    ng = bc.shape[2]
    return jnp.repeat(bc, nh // ng, axis=2)


def ssd_sequential(x, dt, A, B, C, D, *, h0=None):
    """Oracle: step-by-step recurrence.  Returns (y, final_state)."""
    Bt, S, nh, hp = x.shape
    ds = B.shape[-1]
    Bh, Ch = _expand_groups(B, nh), _expand_groups(C, nh)
    h = jnp.zeros((Bt, nh, hp, ds), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t * A)[..., None, None]            # (B,nh,1,1)
        upd = (dt_t[..., None] * x_t)[..., None] * b_t[:, :, None, :]
        h = h * decay + upd                                   # (B,nh,hp,ds)
        y = jnp.einsum("bhps,bhs->bhp", h, c_t) + D[None, :, None] * x_t
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Ch, 1, 0).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def ssd_recurrent(h, x_t, dt_t, A, B_t, C_t, D):
    """One decode step.  h (B,nh,hp,ds); x_t (B,nh,hp); dt_t (B,nh);
    B_t/C_t (B,ng,ds).  Returns (y_t, h_new)."""
    nh = x_t.shape[1]
    b = _expand_groups(B_t[:, None], nh)[:, 0]
    c = _expand_groups(C_t[:, None], nh)[:, 0]
    decay = jnp.exp(dt_t.astype(jnp.float32) * A)[..., None, None]
    upd = (dt_t[..., None] * x_t).astype(jnp.float32)[..., None] * \
        b.astype(jnp.float32)[:, :, None, :]
    h = h * decay + upd
    y = jnp.einsum("bhps,bhs->bhp", h, c.astype(jnp.float32))
    y = y + D[None, :, None] * x_t.astype(jnp.float32)
    return y.astype(x_t.dtype), h


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int = 128, h0=None):
    """Chunked SSD.  Returns (y, final_state).  S % chunk == 0 (callers pad).
    """
    Bt, S, nh, hp = x.shape
    ds = B.shape[-1]
    assert S % chunk == 0
    nc, cl = S // chunk, chunk
    f32 = jnp.float32

    xr = x.reshape(Bt, nc, cl, nh, hp).astype(f32)
    dtr = dt.reshape(Bt, nc, cl, nh).astype(f32)
    Br = _expand_groups(B, nh).reshape(Bt, nc, cl, nh, ds).astype(f32)
    Cr = _expand_groups(C, nh).reshape(Bt, nc, cl, nh, ds).astype(f32)

    dA = dtr * A                                            # (B,nc,cl,nh)
    cum = jnp.cumsum(dA, axis=2)                            # inclusive
    # decay from position j (exclusive) to i (inclusive), i >= j:
    #   exp(cum_i - cum_j)  — matches h_i = prod_{t=j+1..i} exp(dA_t) h_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,i,j,nh)
    ii = jnp.arange(cl)
    tri = (ii[:, None] >= ii[None, :])
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: y[i] += C_i . sum_{j<=i} L_ij dt_j (B_j ⊗ x_j)
    cb = jnp.einsum("bnihs,bnjhs->bnijh", Cr, Br)           # (B,nc,i,j,nh)
    y_diag = jnp.einsum("bnijh,bnijh,bnjh,bnjhp->bnihp",
                        cb, L, dtr, xr)

    # chunk states: contribution of chunk c to the state at its end
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,cl,nh)
    states = jnp.einsum("bnjh,bnjh,bnjhs,bnjhp->bnhps",
                        decay_to_end, dtr, Br, xr)          # (B,nc,nh,hp,ds)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,nh)
    h_init = (jnp.zeros((Bt, nh, hp, ds), f32) if h0 is None
              else h0.astype(f32))

    def chunk_step(h, inp):
        st, dec = inp
        h_prev = h
        h = h * dec[..., None, None] + st
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        chunk_step, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # (B,nc,nh,hp,ds)

    # off-diagonal: y[i] += C_i . (h_prev decayed to i)
    state_decay = jnp.exp(cum)                              # (B,nc,cl,nh)
    y_off = jnp.einsum("bnihs,bnih,bnhps->bnihp",
                       Cr, state_decay, h_prevs)

    y = (y_diag + y_off).reshape(Bt, S, nh, hp)
    y = y + (D[None, None, :, None] * x.astype(f32))
    return y.astype(x.dtype), h_final


# ------------------------------------------------------------ full block ops
def causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv1d.  x (B, S, C), w (K, C), b (C,)."""
    K, Cdim = w.shape
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],       # (K, 1, C)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=Cdim)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv_step(conv_state: Array, x_t: Array, w: Array, b: Array
              ) -> tuple[Array, Array]:
    """One decode step of the causal conv.  conv_state (B, K-1, C),
    x_t (B, C).  Returns (y_t (B, C), new_state)."""
    K, _ = w.shape
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = (window.astype(jnp.float32) * w.astype(jnp.float32)[None]).sum(axis=1)
    y = (y + b.astype(jnp.float32)).astype(x_t.dtype)
    return y, window[:, 1:]


def mamba2_mix(p: dict, x: Array, cfg, *, mode: str,
               state: dict | None = None):
    """The Mamba2 mixer (replaces attention).  x (B, S, d).

    mode: "full" (train/prefill; returns (y, new_state)) or
          "step" (decode; S == 1, requires ``state``).
    state = {"conv": (B, K-1, conv_dim), "ssm": (B, nh, hp, ds)}.
    """
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nh = d_inner // s.head_dim
    ds, ng = s.d_state, s.n_groups
    conv_dim = d_inner + 2 * ng * ds
    B_, S_, _ = x.shape

    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)

    if mode == "step":
        conv_out, conv_state = conv_step(state["conv"], xbc[:, 0],
                                         p["conv_w"], p["conv_b"])
        xbc = jax.nn.silu(conv_out)[:, None]
    else:
        xbc = jax.nn.silu(causal_conv(xbc, p["conv_w"], p["conv_b"]))

    xs, Bc, Cc = jnp.split(xbc, [d_inner, d_inner + ng * ds], axis=-1)
    xs = xs.reshape(B_, S_, nh, s.head_dim)
    Bc = Bc.reshape(B_, S_, ng, ds)
    Cc = Cc.reshape(B_, S_, ng, ds)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    D = p["D"].astype(jnp.float32)

    if mode == "step":
        y, h = ssd_recurrent(state["ssm"], xs[:, 0], dt[:, 0], A,
                             Bc[:, 0], Cc[:, 0], D)
        y = y[:, None]
        new_state = {"conv": conv_state, "ssm": h}
    else:
        pad = -S_ % s.chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        h0 = state["ssm"] if state is not None else None
        y, h = ssd_chunked(xs, dt, A, Bc, Cc, D, chunk=s.chunk, h0=h0)
        y = y[:, :S_]
        # conv decode-state: last K-1 pre-activation xbc inputs
        xbc_pre = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)[1]
        K = s.conv_width
        tail = xbc_pre[:, -(K - 1):] if S_ >= K - 1 else jnp.pad(
            xbc_pre, ((0, 0), (K - 1 - S_, 0), (0, 0)))
        new_state = {"conv": tail, "ssm": h}

    y = y.reshape(B_, S_, d_inner)
    y = constrain(y, ("batch", None, "heads"))
    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    dtp = y.dtype
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + p["norm"].astype(jnp.float32))).astype(dtp)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype)), new_state
